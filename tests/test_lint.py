"""repro.lint: rule fixtures, suppression handling, CLI formats, and the
self-check that src/repro is clean at HEAD.

The fixture modules under ``tests/lint_fixtures/`` deliberately violate
rules — the directory is in :data:`repro.lint.EXCLUDED_DIRS` so repo-wide
lint runs skip it; these tests hand files to :func:`lint_file` directly.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro.lint import (
    EXCLUDED_DIRS,
    RULES,
    STATIC_ALLOWLIST,
    lint_file,
    lint_paths,
)
from repro.lint.findings import (
    Finding,
    active,
    diff_summaries,
    format_github,
    format_text,
)

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(TESTS_DIR)
FIXTURES = os.path.join(TESTS_DIR, "lint_fixtures")
SRC_REPRO = os.path.join(REPO_ROOT, "src", "repro")


def fixture(name: str) -> str:
    return os.path.join(FIXTURES, name)


def active_rules(name: str) -> set[str]:
    res = lint_file(fixture(name))
    assert not res.parse_errors, res.parse_errors
    return {f.rule for f in active(res.findings)}


def run_cli(*argv: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *argv],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT,
    )


# ---------------------------------------------------------------------------
# per-rule fixtures: every rule has a catching and a passing fixture
# ---------------------------------------------------------------------------

CATCH = [
    ("rpl001_bad.py", "RPL001"),
    ("rpl002_bad.py", "RPL002"),
    ("rpl003_bad.py", "RPL003"),
    ("rpl004_bad.py", "RPL004"),
    ("rpl005_bad.py", "RPL005"),
    ("rpl006_bad.py", "RPL006"),
    ("kernel_bad.py", "RPL002"),
    ("kernel_bad.py", "RPL004"),
]

PASS = [
    ("rpl001_good.py", "RPL001"),
    ("rpl002_good.py", "RPL002"),
    ("rpl003_good.py", "RPL003"),
    ("rpl004_good.py", "RPL004"),
    ("rpl005_good.py", "RPL005"),
    ("rpl006_good.py", "RPL006"),
    ("kernel_good.py", "RPL002"),
]


@pytest.mark.parametrize("name,rule", CATCH)
def test_rule_catches(name, rule):
    assert rule in active_rules(name)


@pytest.mark.parametrize("name,rule", PASS)
def test_rule_passes(name, rule):
    assert rule not in active_rules(name)


def test_good_fixtures_fully_clean():
    # the negative fixtures are clean under EVERY rule, not just their own
    for name, _ in PASS:
        assert active_rules(name) == set(), name


def test_rpl002_augassign_retains_taint():
    # `n += 1` reads n: a clean rhs must not launder the taint away
    # (regression: AugAssign used to clear it, a false negative)
    src = (
        "import jax\n"
        "\n"
        "@jax.jit\n"
        "def f(n):\n"
        "    n += 1\n"
        "    if n > 0:\n"
        "        return n\n"
        "    return -n\n"
    )
    res = lint_file("augassign_case.py", source=src)
    assert {f.rule for f in active(res.findings)} == {"RPL002"}


def test_rpl002_plain_reassign_still_clears_taint():
    src = (
        "import jax\n"
        "\n"
        "@jax.jit\n"
        "def f(n):\n"
        "    n = 3\n"
        "    if n > 0:\n"
        "        return 1.0\n"
        "    return 0.0\n"
    )
    res = lint_file("reassign_case.py", source=src)
    assert active(res.findings) == []


def test_rpl004_details():
    res = lint_file(fixture("rpl004_bad.py"))
    msgs = "\n".join(f.message for f in active(res.findings))
    assert "time.time" in msgs          # host clock
    assert "zeros" in msgs              # host numpy
    assert "random.random" in msgs      # stdlib randomness


def test_rpl003_cost_field_message_names_the_contract():
    res = lint_file(fixture("rpl003_bad.py"))
    (f,) = active(res.findings)
    assert f.rule == "RPL003"
    assert "beta_on" in f.message and "no-recompile" in f.message


def test_every_registered_rule_has_fixtures():
    covered = {rule for _, rule in CATCH} & {rule for _, rule in PASS}
    assert covered == set(RULES)


def test_static_allowlist_has_no_cost_fields():
    assert not {"P", "beta_on", "beta_off", "delta", "slack"} & set(
        STATIC_ALLOWLIST
    )


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------

def test_suppressions_silence_but_still_count():
    res = lint_file(fixture("suppressed.py"))
    assert active(res.findings) == []
    suppressed = {f.rule for f in res.findings if f.suppressed}
    assert suppressed == {"RPL003", "RPL006"}
    assert res.ok and res.strict_ok()


def test_file_level_suppression_covers_every_hit():
    res = lint_file(fixture("suppressed_file.py"))
    assert active(res.findings) == []
    assert sum(f.suppressed for f in res.findings) == 2


def test_unknown_suppression_is_strict_only():
    res = lint_file(fixture("unknown_suppression.py"))
    assert res.ok  # default mode: clean
    assert not res.strict_ok()
    (f,) = res.unknown_suppressions
    assert "RPL999" in f.message


def test_parse_error_becomes_finding():
    res = lint_file(fixture("parse_error.py"))
    assert not res.ok
    (f,) = res.parse_errors
    assert f.rule == "parse-error"


# ---------------------------------------------------------------------------
# CLI: formats, exit codes, the seeded-violation gate
# ---------------------------------------------------------------------------

def test_cli_seeded_rpl003_violation_fails():
    proc = run_cli(fixture("rpl003_bad.py"))
    assert proc.returncode == 1
    assert "RPL003" in proc.stdout


def test_cli_github_format():
    proc = run_cli(fixture("rpl003_bad.py"), "--format", "github")
    assert proc.returncode == 1
    line = proc.stdout.strip().splitlines()[0]
    assert line.startswith("::error file=")
    assert ",line=8," in line and "title=RPL003" in line


def test_cli_json_format_and_json_out(tmp_path):
    out = tmp_path / "lint.json"
    proc = run_cli(
        fixture("rpl003_bad.py"), "--format", "json", "--json-out", str(out)
    )
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc["schema"] == "repro.lint/v1"
    assert doc["rules"]["RPL003"]["count"] == 1
    assert json.loads(out.read_text()) == doc


def test_cli_strict_exit_2_on_unknown_suppression():
    assert run_cli(fixture("unknown_suppression.py")).returncode == 0
    proc = run_cli(fixture("unknown_suppression.py"), "--strict")
    assert proc.returncode == 2
    assert "RPL999" in proc.stderr


def test_cli_select_subset():
    # RPL003 deselected -> the rpl003 fixture is clean under RPL006 alone
    proc = run_cli(fixture("rpl003_bad.py"), "--select", "RPL006")
    assert proc.returncode == 0
    proc = run_cli(fixture("rpl003_bad.py"), "--select", "RPL999")
    assert proc.returncode == 2  # argparse error on unknown id


def test_cli_diff_is_informational(tmp_path):
    base = tmp_path / "base.json"
    run_cli(fixture("rpl006_good.py"), "--json-out", str(base))
    proc = run_cli(fixture("rpl003_bad.py"), "--diff", str(base))
    assert proc.returncode == 1  # findings still gate
    assert "RPL003: count 0 -> 1" in proc.stderr
    clean = run_cli(fixture("rpl006_good.py"), "--diff", str(base))
    assert clean.returncode == 0  # drift alone never gates


# ---------------------------------------------------------------------------
# library-level formatting helpers
# ---------------------------------------------------------------------------

def test_format_github_escapes_workflow_reserved_chars():
    f = Finding("a.py", 3, 0, "RPL001", "100% sure\nsecond line")
    out = format_github([f])
    assert "%25" in out and "%0A" in out and "\n" not in out


def test_format_github_escapes_property_separators():
    # file=/title= values additionally reserve , and : — a path containing
    # them must not corrupt the annotation's parameter list
    f = Finding("dir,x/a:b.py", 3, 0, "RPL001", "msg with , and : kept")
    (line,) = format_github([f]).splitlines()
    assert "file=dir%2Cx/a%3Ab.py" in line
    # message values keep , and : literal (only %, \r, \n are reserved)
    assert line.endswith("::msg with , and : kept")


def test_format_text_hides_suppressed():
    shown = Finding("a.py", 1, 0, "RPL001", "m1")
    hidden = Finding("a.py", 2, 0, "RPL002", "m2", suppressed=True)
    assert "m2" not in format_text([shown, hidden])


def test_diff_summaries_reports_per_rule_drift():
    old = {"files": 1, "findings_total": 0, "suppressed_total": 0,
           "rules": {"RPL001": {"count": 0, "suppressed": 0}}}
    new = {"files": 2, "findings_total": 2, "suppressed_total": 1,
           "rules": {"RPL001": {"count": 2, "suppressed": 1}}}
    out = diff_summaries(old, new)
    assert "files 1 -> 2" in out
    assert "RPL001: count 0 -> 2, suppressed 0 -> 1" in out
    assert "unchanged" in diff_summaries(new, new)


# ---------------------------------------------------------------------------
# self-check: the engine source is clean at HEAD, fixtures stay excluded
# ---------------------------------------------------------------------------

def test_src_repro_is_lint_clean_at_head():
    res = lint_paths([SRC_REPRO])
    assert res.files > 50
    assert active(res.findings) == [], format_text(res.findings)
    assert res.parse_errors == []
    assert res.strict_ok()


def test_directory_walk_skips_fixture_and_cache_dirs():
    assert "lint_fixtures" in EXCLUDED_DIRS
    res = lint_paths([TESTS_DIR])
    assert not any("lint_fixtures" in f.path for f in res.findings)
    assert res.parse_errors == []  # parse_error.py fixture was skipped


def test_nonexistent_path_argument_gates():
    # a typo'd CI path must fail the run, not quietly lint nothing
    res = lint_paths(["no/such/dir"])
    assert not res.ok
    (f,) = res.parse_errors
    assert f.rule == "path-error" and f.path == "no/such/dir"
    proc = run_cli("no/such/dir")
    assert proc.returncode == 1
    assert "path-error" in proc.stdout


def test_static_side_is_stdlib_only():
    # the CI lint job installs no jax: importing repro.lint (and running
    # the CLI) must not pull in jax; only the sanitizer re-exports do,
    # lazily, and they still resolve through the package namespace
    code = (
        "import sys\n"
        "import repro.lint\n"
        "assert 'jax' not in sys.modules, 'repro.lint imported jax eagerly'\n"
        "from repro.lint import tracer_sanitizer\n"
        "assert 'jax' in sys.modules\n"
        "assert tracer_sanitizer is repro.lint.sanitize.tracer_sanitizer\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr
