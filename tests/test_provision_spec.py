"""Property tests for the declarative ProvisionSpec API.

Covers the heterogeneous-cost reduction laws (per-level arrays that all
share ``PAPER_COSTS`` must reproduce the homogeneous ``fluid_cost`` /
``fluid_scan`` / ``schedule_cost`` numbers), the per-level-group
decomposition of genuinely heterogeneous fleets, and the deprecated
loose-kwargs wrappers (must warn AND return bit-identical results).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # property tests skip; the rest of the file still runs
    given = None

from repro.core import (
    CostModel,
    PAPER_COSTS,
    PolicySpec,
    ProvisionSpec,
    Workload,
    fluid_cost,
    fluid_scan,
    on_matrix_cost,
    provision,
    schedule_cost,
)
from repro.core.stepfn import StepFn


def spec_for(a, costs, policy="A1", window=0, windows=None, key=None,
             n_levels=None, predicted=None):
    return ProvisionSpec(
        costs=costs,
        workload=Workload(
            demand=jnp.asarray(a, jnp.int32),
            predicted=None if predicted is None else jnp.asarray(predicted, jnp.int32),
        ),
        policy=PolicySpec(policy, window=window, windows=windows, key=key),
        n_levels=n_levels if n_levels is not None else int(np.asarray(a).max()) + 1,
    )


def hetero_paper_costs(n_levels):
    """A per-level CostModel where every level is PAPER_COSTS."""
    return CostModel(
        P=np.full(n_levels, 1.0),
        beta_on=np.full(n_levels, 3.0),
        beta_off=np.full(n_levels, 3.0),
    )


# ---------------------------------------------------------------------------
# Heterogeneous arrays that are secretly homogeneous == the scalar numbers
# (hypothesis property tests; the reduction law itself, one fixed example
# each, also runs without hypothesis below)
# ---------------------------------------------------------------------------

def check_reduces_to_fluid_scan_a1(a, window):
    n = int(a.max()) + 1
    res = provision(spec_for(a, hetero_paper_costs(n), "A1", window=window))
    want = fluid_scan(a, "A1", PAPER_COSTS, window=window)
    np.testing.assert_array_equal(np.asarray(res.x), want.x)
    assert float(res.cost) == pytest.approx(want.cost, rel=1e-6)
    # and the schedule x(t), priced as a step function (paper eq. 5 boundary),
    # carries the same homogeneous schedule_cost
    x = np.asarray(res.x, np.float64)
    fn = StepFn(times=[float(t) for t in range(len(x))], values=list(x),
                horizon=float(len(x)))
    assert schedule_cost(fn, PAPER_COSTS, final_level=float(a[-1])) == \
        pytest.approx(float(res.cost), rel=1e-6)


def check_reduces_to_fluid_cost_offline(a):
    n = int(a.max()) + 1
    res = provision(spec_for(a, hetero_paper_costs(n), "offline"))
    want = fluid_cost(a, "offline", PAPER_COSTS).cost
    assert float(res.cost) == pytest.approx(want, rel=1e-6)


def check_matches_scalar_model_randomized(a, window, seed):
    """Same key => A2/A3 under the per-level array model are bit-identical to
    the scalar model (not just in expectation)."""
    n = int(a.max()) + 1
    key = jax.random.key(seed)
    het = provision(spec_for(a, hetero_paper_costs(n), "A3", window=window, key=key))
    homog = provision(spec_for(a, PAPER_COSTS, "A3", window=window, key=key))
    np.testing.assert_array_equal(np.asarray(het.x), np.asarray(homog.x))
    np.testing.assert_array_equal(np.asarray(het.level_cost),
                                  np.asarray(homog.level_cost))


if given is not None:
    traces = st.lists(st.integers(min_value=0, max_value=6), min_size=8,
                      max_size=40).map(lambda xs: np.asarray(xs, np.int64))

    @settings(max_examples=25, deadline=None)
    @given(a=traces, window=st.integers(min_value=0, max_value=6))
    def test_hetero_paper_costs_reduce_to_fluid_scan_a1(a, window):
        check_reduces_to_fluid_scan_a1(a, window)

    @settings(max_examples=25, deadline=None)
    @given(a=traces)
    def test_hetero_paper_costs_reduce_to_fluid_cost_offline(a):
        check_reduces_to_fluid_cost_offline(a)

    @settings(max_examples=15, deadline=None)
    @given(a=traces, window=st.integers(min_value=0, max_value=4),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_hetero_paper_costs_match_scalar_model_randomized(a, window, seed):
        check_matches_scalar_model_randomized(a, window, seed)


def test_hetero_reduction_fixed_examples():
    """The reduction laws on fixed traces (runs even without hypothesis)."""
    rng = np.random.default_rng(30)
    for window in (0, 3, 6):
        check_reduces_to_fluid_scan_a1(rng.integers(0, 7, size=40), window)
    check_reduces_to_fluid_cost_offline(rng.integers(0, 7, size=40))
    check_matches_scalar_model_randomized(rng.integers(0, 7, size=40), 2, 77)


# ---------------------------------------------------------------------------
# Genuinely heterogeneous fleets decompose per level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["A1", "A2", "offline", "delayedoff"])
def test_hetero_level_groups_match_their_homogeneous_engine(policy):
    """Levels are independent ski-rental instances: a two-class fleet's
    per-level costs must equal the matching columns of single-class runs."""
    rng = np.random.default_rng(3)
    a = rng.integers(0, 8, size=120)
    n = int(a.max()) + 1
    beta = np.where(np.arange(n) % 2 == 0, 3.0, 1.5)       # Delta 6 / 3
    key = jax.random.key(5) if policy == "A2" else None
    het = provision(spec_for(a, CostModel(P=1.0, beta_on=beta, beta_off=beta),
                             policy, window=2, key=key))
    for half in (3.0, 1.5):
        homog = provision(spec_for(
            a, CostModel(P=1.0, beta_on=np.full(n, half), beta_off=np.full(n, half)),
            policy, window=2, key=key))
        cols = np.flatnonzero(beta == half)
        np.testing.assert_allclose(
            np.asarray(het.level_cost)[cols], np.asarray(homog.level_cost)[cols],
            rtol=1e-6,
        )


def test_all_policies_run_heterogeneous_end_to_end():
    """Acceptance: one (n_levels,) CostModel through every policy, as one
    jitted program each — schedule covers demand, costs decompose."""
    rng = np.random.default_rng(4)
    a = rng.integers(0, 10, size=100)
    n = int(a.max()) + 1
    costs = CostModel(
        P=np.linspace(0.8, 1.2, n),
        beta_on=np.linspace(1.0, 4.0, n),
        beta_off=np.linspace(1.0, 4.0, n)[::-1].copy(),
    )
    for policy in ("A1", "A2", "A3", "offline", "delayedoff"):
        key = jax.random.key(9) if policy in ("A2", "A3") else None
        res = provision(spec_for(a, costs, policy, window=2, key=key))
        assert (np.asarray(res.x) >= a).all(), policy
        assert float(res.cost) == pytest.approx(float(res.level_cost.sum()), rel=1e-6)
        assert np.isfinite(np.asarray(res.level_cost)).all(), policy


def test_cost_model_validation():
    assert PAPER_COSTS.delta == 6.0 and not PAPER_COSTS.is_heterogeneous
    het = CostModel(P=np.array([1.0, 2.0]), beta_on=np.array([3.0, 4.0]),
                    beta_off=np.array([3.0, 4.0]))
    np.testing.assert_allclose(np.asarray(het.delta), [6.0, 4.0])
    assert het.is_heterogeneous and het.n_levels == 2 and het.delta_slots() == 6
    with pytest.raises(ValueError, match="pinned to 2 levels"):
        het.per_level(3)
    with pytest.raises(ValueError, match="inconsistent"):
        CostModel(P=np.ones(2), beta_on=np.ones(3)).n_levels
    # n_levels defaults to the cost model's own length
    res = provision(ProvisionSpec(
        costs=het,
        workload=Workload(demand=jnp.asarray([1, 2, 1, 0, 0, 1], jnp.int32)),
        policy=PolicySpec("A1"),
    ))
    assert res.level_cost.shape == (2,)


# ---------------------------------------------------------------------------
# Deprecated wrappers: warn, and forward bit-identically
# ---------------------------------------------------------------------------

def _no_warn_provision(spec):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        return provision(spec)


def test_provision_schedule_wrapper_warns_and_matches():
    from repro.core import provision_schedule

    rng = np.random.default_rng(20)
    a = rng.integers(0, 7, size=80)
    n = int(a.max()) + 1
    key = jax.random.key(1)
    for policy in ("A1", "A3", "offline", "delayedoff"):
        k = key if policy == "A3" else None
        with pytest.warns(DeprecationWarning, match="^deprecated"):
            old = provision_schedule(jnp.asarray(a, jnp.int32), n_levels=n,
                                     delta=6, window=2, policy=policy, key=k)
        new = _no_warn_provision(spec_for(a, PAPER_COSTS, policy, window=2,
                                          key=k, n_levels=n))
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new.x))


def test_provision_sweep_wrappers_warn_and_match():
    from repro.core import provision_sweep, provision_sweep_costs

    rng = np.random.default_rng(21)
    ab = rng.integers(0, 6, size=(3, 60))
    windows = jnp.arange(4)
    key = jax.random.key(2)
    with pytest.warns(DeprecationWarning, match="^deprecated"):
        old_x = provision_sweep(jnp.asarray(ab, jnp.int32), n_levels=6, delta=6,
                                windows=windows, policy="A3", key=key)
    with pytest.warns(DeprecationWarning, match="^deprecated"):
        old_c = provision_sweep_costs(jnp.asarray(ab, jnp.int32), n_levels=6,
                                      delta=6, windows=windows, policy="A3",
                                      key=key, P=1.0, beta_on=3.0, beta_off=3.0)
    new = _no_warn_provision(spec_for(ab, PAPER_COSTS, "A3", windows=windows,
                                      key=key, n_levels=6))
    np.testing.assert_array_equal(np.asarray(old_x), np.asarray(new.x))
    np.testing.assert_array_equal(np.asarray(old_c), np.asarray(new.cost))


def test_provision_sweep_costs_rejects_inconsistent_delta():
    from repro.core import provision_sweep_costs

    with pytest.warns(DeprecationWarning, match="^deprecated"):
        with pytest.raises(ValueError, match="disagrees"):
            provision_sweep_costs(jnp.ones((10,), jnp.int32), n_levels=2,
                                  delta=7, windows=jnp.arange(2),
                                  P=1.0, beta_on=3.0, beta_off=3.0)


def test_provision_cost_wrapper_warns_and_matches():
    from repro.core import provision_cost
    from repro.core.jax_provision import _level_schedule

    rng = np.random.default_rng(22)
    a = rng.integers(0, 6, size=50)
    ons = _level_schedule(jnp.asarray(a, jnp.int32), 6, 6, 1, "A1")
    with pytest.warns(DeprecationWarning, match="^deprecated"):
        old = provision_cost(jnp.asarray(a), ons, 1.0, 3.0, 3.0)
    new = on_matrix_cost(jnp.asarray(a), ons, PAPER_COSTS)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_provision_schedule_sharded_wrapper_warns_and_matches():
    from repro.core import provision_schedule_sharded

    rng = np.random.default_rng(23)
    a = rng.integers(0, 6, size=60)
    n = int(a.max()) + 1
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    with pytest.warns(DeprecationWarning, match="^deprecated"):
        old = provision_schedule_sharded(mesh, jnp.asarray(a, jnp.int32),
                                         n_levels=n, delta=6, window=2)
    new = _no_warn_provision(dataclasses.replace(
        spec_for(a, PAPER_COSTS, "A1", window=2, n_levels=n), mesh=mesh))
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new.x))


# ---------------------------------------------------------------------------
# Serving front-end
# ---------------------------------------------------------------------------

def test_fleet_provisioner_takes_policy_spec():
    from repro.serving import FleetProvisioner

    a = np.random.default_rng(24).integers(0, 5, size=80)
    planner = FleetProvisioner(
        PAPER_COSTS, policy=PolicySpec("A1", window=2), max_replicas=8,
    )
    res = planner.plan(a)
    want = provision(spec_for(a, PAPER_COSTS, "A1", window=2, n_levels=8))
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(want.x))
    assert float(res.cost) == pytest.approx(float(want.cost))


def test_fleet_provisioner_mesh_sweeps_and_batches():
    """The planner's mesh= path now takes batched demand and windows sweeps
    (it used to raise): same cells, level axis sharded, bit-exact."""
    from repro.serving import FleetProvisioner

    ab = np.random.default_rng(25).integers(0, 5, size=(2, 60))
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    meshed = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=8, mesh=mesh)
    plain = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=8)
    windows = np.arange(3)
    np.testing.assert_array_equal(
        meshed.plan_sweep(ab, windows), plain.plan_sweep(ab, windows)
    )
    np.testing.assert_allclose(
        meshed.sweep_costs(ab, windows), plain.sweep_costs(ab, windows),
        rtol=1e-6,
    )


def test_unknown_policy_value_errors_name_valid_set():
    from repro.serving import FleetProvisioner
    from repro.serving.autoscaler import ReplicaAutoscaler

    with pytest.raises(ValueError, match="valid policies"):
        FleetProvisioner(PAPER_COSTS, policy="A7")
    with pytest.raises(ValueError, match="valid policies"):
        ReplicaAutoscaler(4, PAPER_COSTS, policy="nope")


# ---------------------------------------------------------------------------
# Typed server groups: CostModel.from_groups reduction laws
# ---------------------------------------------------------------------------

from repro.core import ServerGroup  # noqa: E402


def _single_group(n):
    return CostModel.from_groups(
        ServerGroup("std", n, P=1.0, beta_on=3.0, beta_off=3.0))


def check_typed_d1_reduces_to_untyped(a, policy, window, seed):
    """One group with the untyped scalar parameters == the untyped engine,
    bit-exact (schedule, per-level cost, PRNG stream)."""
    from repro.core.jax_provision import KEYED

    n = int(a.max()) + 1
    key = jax.random.key(seed) if policy in KEYED else None
    typed = provision(spec_for(a, _single_group(n), policy, window=window,
                               key=key, n_levels=n))
    untyped = provision(spec_for(a, PAPER_COSTS, policy, window=window,
                                 key=key, n_levels=n))
    np.testing.assert_array_equal(np.asarray(typed.x), np.asarray(untyped.x))
    np.testing.assert_array_equal(np.asarray(typed.level_cost),
                                  np.asarray(untyped.level_cost))


def check_merging_identical_types_cost_invariant(a, sizes, window):
    """Splitting one server type into several identically-parameterized
    groups is pure relabeling: schedule and total cost are unchanged, and
    the split group_cost columns sum to the merged one."""
    n = sum(sizes)
    a = np.minimum(a, n)
    merged = CostModel.from_groups(
        ServerGroup("all", n, P=1.0, beta_on=3.0, beta_off=3.0))
    split = CostModel.from_groups(*(
        ServerGroup(f"g{i}", s, P=1.0, beta_on=3.0, beta_off=3.0)
        for i, s in enumerate(sizes)
    ))
    rm = provision(spec_for(a, merged, "A1", window=window, n_levels=n))
    rs = provision(spec_for(a, split, "A1", window=window, n_levels=n))
    np.testing.assert_array_equal(np.asarray(rm.x), np.asarray(rs.x))
    np.testing.assert_array_equal(np.asarray(rm.level_cost),
                                  np.asarray(rs.level_cost))
    np.testing.assert_allclose(
        np.asarray(rs.group_cost).sum(axis=-1),
        np.asarray(rm.group_cost)[..., 0], rtol=1e-6)


if given is not None:
    typed_traces = st.lists(
        st.integers(min_value=0, max_value=6), min_size=8, max_size=40
    ).map(lambda xs: np.asarray(xs, np.int64))

    @settings(max_examples=15, deadline=None)
    @given(a=typed_traces,
           policy=st.sampled_from(["A1", "A3", "offline", "delayedoff",
                                   "AQ-det", "AQ-rand"]),
           window=st.integers(min_value=0, max_value=4),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_typed_d1_reduces_to_untyped(a, policy, window, seed):
        check_typed_d1_reduces_to_untyped(a, policy, window, seed)

    @settings(max_examples=15, deadline=None)
    @given(a=typed_traces,
           sizes=st.lists(st.integers(min_value=1, max_value=4),
                          min_size=2, max_size=4),
           window=st.integers(min_value=0, max_value=3))
    def test_merging_identical_types_cost_invariant(a, sizes, window):
        check_merging_identical_types_cost_invariant(a, tuple(sizes), window)


def test_typed_reduction_fixed_examples():
    """The typed reduction laws on fixed traces (runs without hypothesis)."""
    rng = np.random.default_rng(31)
    a = rng.integers(0, 7, size=40)
    for policy in ("A1", "AQ-det", "AQ-rand"):
        check_typed_d1_reduces_to_untyped(a, policy, 2, 99)
    check_merging_identical_types_cost_invariant(a, (3, 2, 2), 1)


def test_from_groups_orders_by_energy_and_validates():
    eff = ServerGroup("eff", 2, P=1.0, beta_on=2.0, beta_off=2.0)
    leg = ServerGroup("leg", 3, P=1.5, beta_on=4.5, beta_off=4.5)
    cm = CostModel.from_groups(leg, eff)          # any order in...
    assert cm.group_names == ("eff", "leg")       # ...ascending P out
    assert cm.group_sizes == (2, 3)
    assert cm.n_groups == 2 and cm.n_levels == 5
    assert cm.group_offsets == (0, 2)
    assert cm.groups == (eff, leg)                # reconstructs the inputs
    np.testing.assert_allclose(np.asarray(cm.P), [1.0, 1.0, 1.5, 1.5, 1.5])
    with pytest.raises(ValueError, match="duplicate group names"):
        CostModel.from_groups(eff, dataclasses.replace(leg, name="eff"))
    with pytest.raises(ValueError, match="n_servers"):
        ServerGroup("empty", 0).validate()
    with pytest.raises(ValueError, match="P"):
        ServerGroup("free", 1, P=0.0).validate()


def test_group_cost_sums_to_total():
    from repro.core.jax_provision import KEYED

    cm = CostModel.from_groups(
        ServerGroup("eff", 4, P=1.0, beta_on=2.0, beta_off=2.0),
        ServerGroup("leg", 3, P=1.5, beta_on=4.5, beta_off=4.5),
    )
    a = np.random.default_rng(32).integers(0, cm.n_levels + 1, size=60)
    for policy in ("A1", "AQ-det", "AQ-rand"):
        key = jax.random.key(1) if policy in KEYED else None
        res = provision(spec_for(a, cm, policy, key=key,
                                 n_levels=cm.n_levels))
        gc = np.asarray(res.group_cost)
        assert gc.shape[-1] == 2
        np.testing.assert_allclose(gc.sum(axis=-1), np.asarray(res.cost),
                                   rtol=1e-6)
        # each column is exactly that group's slice of level_cost
        lc = np.asarray(res.level_cost)
        np.testing.assert_allclose(gc[..., 0], lc[..., :4].sum(axis=-1),
                                   rtol=1e-6)
        np.testing.assert_allclose(gc[..., 1], lc[..., 4:].sum(axis=-1),
                                   rtol=1e-6)


def test_fleet_provisioner_pins_typed_fleet_size():
    from repro.serving import FleetProvisioner

    cm = CostModel.from_groups(
        ServerGroup("eff", 6, P=1.0, beta_on=2.0, beta_off=2.0),
        ServerGroup("leg", 4, P=1.5, beta_on=4.5, beta_off=4.5),
    )
    planner = FleetProvisioner(cm, policy="AQ-det")
    assert planner.max_replicas == 10             # pinned by the model
    res = planner.plan(np.array([0, 3, 8, 8, 2, 0]))
    assert np.asarray(res.group_cost).shape == (2,)
    with pytest.raises(ValueError, match="pinned fleet size"):
        FleetProvisioner(cm, policy="A1", max_replicas=12)
    # scalar models keep the old planning default
    assert FleetProvisioner(PAPER_COSTS, policy="A1").max_replicas == 1024
