"""Hillclimb levers: correctness of local attention + f8 cache + sp specs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.ref import flash_attention_ref
from repro.models.attention import _expand_kv, _local_attention


def test_local_attention_matches_masked_reference():
    B, S, H, KVH, hd, W = 2, 256, 4, 2, 32, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)
    got = _local_attention(q, _expand_kv(k, H), _expand_kv(v, H), W, jnp.float32)
    want = flash_attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_hymba_local_attention_end_to_end():
    """hymba forward with local_attention on == off (same logits)."""
    from repro.models import init_params, logits_fn

    base = get_config("hymba-1.5b", reduced=True).replace(remat="none")
    cfg_off = base.replace(local_attention=False)
    cfg_on = base.replace(local_attention=True)
    params = init_params(cfg_off, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 64)), jnp.int32)}
    lo = jax.jit(lambda p, b: logits_fn(p, cfg_off, b))(params, batch)
    lh = jax.jit(lambda p, b: logits_fn(p, cfg_on, b))(params, batch)
    np.testing.assert_allclose(np.asarray(lo, np.float32),
                               np.asarray(lh, np.float32), rtol=2e-2, atol=2e-2)


def test_f8_kv_cache_decode_close_to_bf16():
    """float8 KV cache: decode logits stay close to the bf16-cache logits."""
    from repro.models import decode_fn, init_cache, init_params, prefill_fn

    cfg16 = get_config("llama3.2-1b", reduced=True).replace(remat="none")
    cfg8 = cfg16.replace(kv_cache_dtype=jnp.float8_e4m3fn)
    params = init_params(cfg16, jax.random.key(1))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, 512, (2, 16)), jnp.int32)

    outs = {}
    for tag, cfg in (("bf16", cfg16), ("f8", cfg8)):
        cache = init_cache(cfg, 2, 24)
        _, cache = jax.jit(lambda p, b, c: prefill_fn(p, cfg, b, c))(
            params, {"tokens": tokens[:, :-1]}, cache)
        logits, _ = jax.jit(lambda p, t, n, c: decode_fn(p, cfg, t, n, c))(
            params, tokens[:, -1], jnp.int32(15), cache)
        outs[tag] = np.asarray(logits, np.float32)
    # f8 introduces quantization noise but ranking should be stable-ish
    corr = np.corrcoef(outs["bf16"].ravel(), outs["f8"].ravel())[0, 1]
    assert corr > 0.98, corr
