"""Shared fixtures: the repro.lint runtime sanitizer, pytest-flavoured.

``tracer_sanitizer`` is the one compile/leak gate for the whole suite
(replacing the per-test hand-rolled ``_cache_size`` deltas): a factory for
:func:`repro.lint.sanitize.tracer_sanitizer` context managers that *skips*
the test — instead of silently passing — when JAX's private jit-cache API
is unavailable, matching the behaviour of the gates it replaced.
"""
from __future__ import annotations

import contextlib

import pytest

from repro.lint.sanitize import tracer_sanitizer as _tracer_sanitizer
from repro.obs import CompileWatcher


@pytest.fixture(name="tracer_sanitizer")
def tracer_sanitizer_fixture():
    """Factory: ``with tracer_sanitizer(fns=(jitted,)) as w: ...`` hard-fails
    on any recompile in the region (``max_compiles=0`` default — pass
    ``exact_compiles=1`` for cold-compile gates) and on tracer leaks."""

    @contextlib.contextmanager
    def gate(fns=None, **kwargs):
        if not CompileWatcher(fns=fns).available:
            pytest.skip("private jit _cache_size API unavailable")
        with _tracer_sanitizer(fns=fns, **kwargs) as watcher:
            yield watcher

    return gate
