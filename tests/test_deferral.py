"""Slack-aware deferral: the water-filling transform's conservation laws,
the queueing accounting, the rigid fixed point (slack 0 bit-exact through
provision() on both engine routes), caps, the spec sweep axes, and the
serving planner's deferral mode.

The laws are written as ``check_*`` functions and driven two ways: a
seeded numpy sweep that always runs, and hypothesis ``@given`` wrappers
over the same checks when hypothesis is installed (the container CI image
may lack it — the laws must not silently vanish with it)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PAPER_COSTS,
    DeferralSpec,
    PolicySpec,
    ProvisionSpec,
    Workload,
    provision,
)
from repro.deferral import RULES, defer_demand, due_envelope, queue_scan

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False


def _cum(a):
    return np.cumsum(np.asarray(a, np.int64))


def _rand_trace(rng, n=None):
    n = n or int(rng.integers(8, 49))
    burst = (rng.random(n) < 0.08) * rng.integers(10, 25)
    return jnp.asarray(rng.poisson(rng.uniform(2, 12), n) + burst, jnp.int32)


# ---------------------------------------------------------------------------
# the laws
# ---------------------------------------------------------------------------

def check_defer_conservation_causality_deadlines(a, slack):
    d = np.asarray(defer_demand(a, slack))
    assert (d >= 0).all()
    assert d.sum() == int(np.asarray(a).sum())              # conservation
    assert (_cum(d) <= _cum(a)).all()                       # causality
    # due_envelope is already cumulative: L(t) = work due by slot t
    assert (_cum(d) >= np.asarray(due_envelope(a, slack))).all()  # feasibility


def check_defer_never_roughens(a, slack):
    """Deferral only makes the provisioning game easier: the peak and the
    total variation of the deferred profile never exceed the raw trace's."""
    a_np = np.asarray(a, np.int64)
    d = np.asarray(defer_demand(a, slack), np.int64)
    assert d.max() <= a_np.max()
    assert np.abs(np.diff(d, prepend=0)).sum() \
        <= np.abs(np.diff(a_np, prepend=0)).sum()


def check_zero_slack_identity(a):
    np.testing.assert_array_equal(np.asarray(defer_demand(a, 0)),
                                  np.asarray(a))


def check_peak_monotone_in_slack(a, slack):
    lo = np.asarray(defer_demand(a, slack - 1), np.int64)
    hi = np.asarray(defer_demand(a, slack), np.int64)
    assert hi.max() <= lo.max()


def check_feasible_cap_conserves(a, slack):
    """A cap at the raw peak is always feasible; the deferred profile still
    conserves work and respects the ceiling."""
    cap = max(int(np.asarray(a).max()), 1)
    d = np.asarray(defer_demand(a, slack, cap=cap), np.int64)
    assert d.max() <= cap
    assert d.sum() == int(np.asarray(a).sum())


def check_queue_accounting_closes(a, x, slack):
    """served + unserved == total arrivals, under every dispatch rule, even
    against an adversarial (unrelated) schedule."""
    n = min(a.shape[0], x.shape[0])
    a, x = a[:n], x[:n]
    for rule in RULES:
        m = queue_scan(a, x, slack, rule=rule, max_slack=6)
        assert int(m["served_by_age"].sum()) + int(m["unserved"]) \
            == int(np.asarray(a).sum())
        assert int(m["backlog"][-1]) == int(m["unserved"])
        assert (np.asarray(m["backlog"]) >= 0).all()


def check_edf_serves_within_slack(a, slack):
    """Provisioning exactly the deferred profile and dispatching EDF meets
    every deadline: zero misses, zero unserved, max delay <= slack."""
    x = defer_demand(a, slack)
    m = queue_scan(a, x, slack, rule="EDF", max_slack=6)
    assert int(m["deadline_misses"]) == 0
    assert int(m["unserved"]) == 0
    assert int(m["max_delay"]) <= slack
    assert int(m["p99_delay"]) <= int(m["max_delay"])


def check_edf_dominates_fifo(a, x, slack):
    """Earliest-deadline-first is deadline-optimal among work-conserving
    rules: on any (arrivals, schedule) pair it misses no more than FIFO."""
    n = min(a.shape[0], x.shape[0])
    a, x = a[:n], x[:n]
    edf = queue_scan(a, x, slack, rule="EDF", max_slack=6)
    fifo = queue_scan(a, x, slack, rule="FIFO", max_slack=6)
    assert int(edf["deadline_misses"]) <= int(fifo["deadline_misses"])


# ---------------------------------------------------------------------------
# seeded sweep: always runs, hypothesis or not
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_deferral_laws_seeded_sweep(seed):
    rng = np.random.default_rng(seed)
    for _ in range(8):
        a = _rand_trace(rng)
        x = _rand_trace(rng, n=a.shape[0])
        slack = int(rng.integers(0, 7))
        check_defer_conservation_causality_deadlines(a, slack)
        check_defer_never_roughens(a, slack)
        check_zero_slack_identity(a)
        check_peak_monotone_in_slack(a, max(slack, 1))
        check_feasible_cap_conserves(a, max(slack, 1))
        check_queue_accounting_closes(a, x, slack)
        check_edf_serves_within_slack(a, slack)
        check_edf_dominates_fifo(a, x, slack)


if HAVE_HYPOTHESIS:
    traces = st.lists(st.integers(0, 30), min_size=8, max_size=48).map(
        lambda v: jnp.asarray(v, jnp.int32)
    )
    slacks = st.integers(0, 6)

    @settings(max_examples=50, deadline=None)
    @given(traces, slacks)
    def test_defer_demand_laws_hypothesis(a, slack):
        check_defer_conservation_causality_deadlines(a, slack)
        check_defer_never_roughens(a, slack)
        check_feasible_cap_conserves(a, max(slack, 1))
        if slack:
            check_peak_monotone_in_slack(a, slack)

    @settings(max_examples=40, deadline=None)
    @given(traces, traces, slacks)
    def test_queue_scan_laws_hypothesis(a, x, slack):
        check_queue_accounting_closes(a, x, slack)
        check_edf_serves_within_slack(a, slack)
        check_edf_dominates_fifo(a, x, slack)


def test_due_envelope_shifts_and_clips():
    a = jnp.asarray([3, 0, 5, 0, 0, 2], jnp.int32)
    # slack 2: arrivals become due two slots later, horizon-clipped
    L = np.asarray(due_envelope(a, 2))
    np.testing.assert_array_equal(L, np.cumsum([0, 0, 3, 0, 5, 2]))
    np.testing.assert_array_equal(np.asarray(due_envelope(a, 0)), _cum(a))


def test_infeasible_cap_is_best_effort_not_silent():
    """A cap below the long-run mean cannot serve everything: the transform
    saturates the cap and the shortfall is visible, never fabricated."""
    a = jnp.asarray([10] * 20, jnp.int32)
    d = np.asarray(defer_demand(a, 4, cap=5), np.int64)
    assert d.max() <= 5
    assert d.sum() == 5 * 20                   # every capped slot saturated
    assert d.sum() < int(np.asarray(a).sum())  # the deficit is explicit


def test_queue_scan_rejects_bad_rule():
    a = jnp.zeros(8, jnp.int32)
    with pytest.raises(ValueError, match="rule"):
        queue_scan(a, a, 2, rule="LIFO", max_slack=4)


# ---------------------------------------------------------------------------
# DeferralSpec: validation, sweep axes, tracer contract
# ---------------------------------------------------------------------------

def test_spec_validation_names_the_field():
    with pytest.raises(ValueError, match="rule"):
        DeferralSpec(slack=2, rule="LIFO").validate()
    with pytest.raises(ValueError, match="slack"):
        DeferralSpec(slack=-1).validate()
    with pytest.raises(ValueError, match="cap"):
        DeferralSpec(slack=2, cap=0).validate()
    DeferralSpec(slack=2, rule="SPT", cap=3).validate()


def test_spec_bound_needs_max_slack_for_tracers():
    assert DeferralSpec(slack=4).bound() == 4
    assert DeferralSpec(slack=jnp.asarray([0, 2, 5])).bound() == 5
    assert DeferralSpec(slack=2, max_slack=8).bound() == 8

    def f(s):
        return DeferralSpec(slack=s).bound()

    with pytest.raises(ValueError, match="max_slack"):
        jax.jit(f)(3)


def test_spec_per_slot_slack_and_sweep_metrics():
    """slack may be per-slot (heterogeneous deadlines); metrics broadcast
    the true arrivals against any (..., B, T) capacity sweep grid.

    The zero-miss guarantee needs *monotone effective deadlines*
    (t + slack[t] non-decreasing, i.e. later work never jumps the queue);
    non-monotone slack is still measured honestly, just without the
    feasibility promise (the prefix envelope is not Hall's condition)."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.poisson(10, (3, 48)), jnp.int32)
    slack_t = jnp.asarray(([5, 4, 3, 2, 1, 0, 0, 3] * 6)[:48], jnp.int32)
    assert (np.diff(np.arange(48) + np.asarray(slack_t)) >= 0).all()
    spec = DeferralSpec(slack=slack_t).validate()
    d = spec.apply(a)
    assert d.shape == a.shape
    np.testing.assert_array_equal(
        np.asarray(d).sum(-1), np.asarray(a).sum(-1))    # conserved per row
    # a sweep-shaped capacity grid keeps its leading axes on every metric
    x = jnp.broadcast_to(d, (2,) + d.shape)
    m = spec.metrics(a, x)
    assert m["p99_delay"].shape == (2, 3)
    assert m["backlog"].shape == (2, 3, 48)
    assert int(np.asarray(m["deadline_misses"]).sum()) == 0
    with pytest.raises(ValueError, match="scalar or a"):
        DeferralSpec(slack=jnp.zeros((2, 2), jnp.int32)).validate()
    with pytest.raises(ValueError, match="48"):
        DeferralSpec(slack=jnp.zeros(7, jnp.int32)).apply(a)


def test_slack_values_share_one_compiled_transform(tracer_sanitizer):
    """slack is pytree data: re-running the transform at a new slack value
    (same shapes, same static cap) must hit the jit cache."""
    from repro.deferral.queue_scan import defer_demand as _jitted

    a = _demand()
    jax.block_until_ready(DeferralSpec(slack=2).apply(a))  # warm
    with tracer_sanitizer(fns=(_jitted,)):
        for slack in (3, 5, jnp.full(96, 4, jnp.int32)):
            jax.block_until_ready(DeferralSpec(slack=slack).apply(a))


# ---------------------------------------------------------------------------
# provision(): the rigid fixed point and the defer-then-provision route
# ---------------------------------------------------------------------------

def _spec(a, deferral=None, mesh=None):
    return ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=a, deferral=deferral),
        policy=PolicySpec("A1", window=2),
        n_levels=40,
        mesh=mesh,
    )


def _demand(b=None, t=96, seed=0):
    rng = np.random.default_rng(seed)
    shape = (t,) if b is None else (b, t)
    base = rng.poisson(12, shape) + (rng.random(shape) < 0.06) * 20
    return jnp.asarray(np.minimum(base, 39), jnp.int32)


@pytest.mark.parametrize("use_mesh", [False, True], ids=["lax_scan", "mesh"])
def test_zero_slack_is_bit_exact_with_rigid(use_mesh):
    """DeferralSpec(slack=0) must be indistinguishable from no deferral at
    all — every result leaf, on the lax.scan AND the Pallas fleet route —
    so leaving deferral wired in can never perturb rigid results."""
    a = _demand()
    mesh = jax.make_mesh((len(jax.devices()),), ("data",)) if use_mesh else None
    rigid = provision(_spec(a, mesh=mesh))
    soft = provision(_spec(a, deferral=DeferralSpec(slack=0), mesh=mesh))
    np.testing.assert_array_equal(np.asarray(rigid.x), np.asarray(soft.x))
    np.testing.assert_array_equal(np.asarray(rigid.cost), np.asarray(soft.cost))
    np.testing.assert_array_equal(np.asarray(rigid.level_cost),
                                  np.asarray(soft.level_cost))
    # the queue columns exist on the deferred result and report a clean SLO
    assert rigid.p99_delay is None
    assert int(soft.p99_delay) == 0 and int(soft.deadline_misses) == 0


def test_deferred_mesh_matches_lax_scan():
    a = _demand(b=2)
    d = DeferralSpec(slack=4)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    plain = provision(_spec(a, deferral=d))
    meshed = provision(_spec(a, deferral=d, mesh=mesh))
    np.testing.assert_array_equal(np.asarray(plain.x), np.asarray(meshed.x))
    np.testing.assert_array_equal(np.asarray(plain.p99_delay),
                                  np.asarray(meshed.p99_delay))


def test_slack_cuts_cost_and_reports_latency():
    a = _demand()
    costs, p99s = [], []
    for slack in (0, 2, 6):
        res = provision(_spec(a, deferral=DeferralSpec(slack=slack,
                                                       max_slack=6)))
        assert int(res.deadline_misses) == 0 and int(res.unserved) == 0
        assert int(res.p99_delay) <= slack
        costs.append(float(res.cost))
        p99s.append(int(res.p99_delay))
    assert costs[-1] <= costs[0]               # slack buys cost off
    assert costs[1] <= costs[0]
    assert p99s[0] == 0


def test_deferred_sweep_axes_compose():
    """The deferral transform rides the (S, W, B) sweep axes like any other
    workload feature: queue metrics get the same leading axes as cost."""
    from repro.core import PredictionNoise

    a = _demand(b=3)
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(
            demand=a,
            noise=PredictionNoise(jnp.asarray([0.0, 0.2]), jax.random.key(0)),
            deferral=DeferralSpec(slack=3),
        ),
        policy=PolicySpec("A1", windows=jnp.arange(2)),
        n_levels=40,
    )
    res = provision(spec)
    assert res.x.shape == (2, 2, 3, 96)
    assert res.cost.shape == (2, 2, 3)
    assert res.p99_delay.shape == (2, 2, 3)
    assert res.backlog.shape == (2, 2, 3, 96)


# ---------------------------------------------------------------------------
# FleetProvisioner: planner-level deferral + the rolling advance() stepper
# ---------------------------------------------------------------------------

def test_planner_deferral_absorbs_over_peak_demand():
    from repro.core import CostModel
    from repro.serving import FleetProvisioner

    costs = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
    a = np.asarray(_demand())
    big = a.copy()
    big[5] = 80                                # above the 64-replica fleet
    rigid = FleetProvisioner(costs, policy="A1", max_replicas=64)
    with pytest.raises(ValueError, match="exceeds max_replicas"):
        rigid.plan(big)
    soft = FleetProvisioner(costs, policy="A1", max_replicas=64,
                            deferral=DeferralSpec(slack=4))
    assert soft.deferral.cap == 64             # cap defaults to the fleet
    res = soft.plan(big)
    assert int(np.asarray(res.x).max()) <= 64
    assert int(res.unserved) == 0

    # zero slack through the planner is the rigid plan, bit-exact
    zero = FleetProvisioner(costs, policy="A1", max_replicas=64,
                            deferral=DeferralSpec(slack=0))
    np.testing.assert_array_equal(np.asarray(zero.plan(a).x),
                                  np.asarray(rigid.plan(a).x))


def test_planner_advance_steps_chunks():
    from repro.core import CostModel
    from repro.serving import FleetProvisioner

    costs = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
    a = np.asarray(_demand())
    p = FleetProvisioner(costs, policy="A1", max_replicas=64,
                         deferral=DeferralSpec(slack=4))
    xs = [p.advance(a[i:i + 32]) for i in range(0, 96, 32)]
    assert [x.shape for x in xs] == [(32,)] * 3
    assert p._history.shape == (96,)
    assert p.last_plan is not None and int(p.last_plan.deadline_misses) == 0
    with pytest.raises(ValueError, match="one fleet"):
        p.advance(a.reshape(2, 48))
