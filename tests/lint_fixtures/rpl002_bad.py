"""RPL002 positive fixture: host `if` on a traced jit argument."""
import jax


@jax.jit
def relu_gate(x):
    if x > 0:  # RPL002: ConcretizationTypeError under jit
        return x
    return x * 0.0
