"""RPL006 positive fixture: a direct `_cache_size` poke outside
obs/jaxwatch.py bypasses CompileWatcher's degradation path."""


def cache_entries(fn):
    return fn._cache_size()  # RPL006
