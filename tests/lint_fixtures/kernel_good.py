"""Kernel negative fixture: branching on functools.partial-bound kwargs is
plain Python at trace time — they are the kernel's static names."""
import functools

import jax
import jax.experimental.pallas as pl


def _good_kernel(a_ref, o_ref, *, causal, bn):
    if causal:  # partial-bound static: concrete Python value
        o_ref[...] = a_ref[...] * bn
    else:
        o_ref[...] = a_ref[...]


def launch(a, bn):
    kernel = functools.partial(_good_kernel, causal=True, bn=bn)
    return pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype)
    )(a)
