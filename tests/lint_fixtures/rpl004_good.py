"""RPL004 negative fixture: dtype-metadata numpy calls are trace-safe, and
host numpy outside traced regions is ordinary host code."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def typed(x):
    dt = np.dtype("float32")  # dtype metadata: concrete, trace-safe
    lo = np.finfo(dt).min
    return jnp.clip(x, lo, None).astype(dt)


def host_setup(n):
    return np.zeros(n)  # not a traced region
