"""RPL003 positive fixture: a cost-model field in static_argnames —
re-pricing recompiles per value, breaking the no-recompile contract."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("beta_on",))  # RPL003
def priced(a, beta_on):
    return a * beta_on
