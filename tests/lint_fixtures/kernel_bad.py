"""Kernel positive fixture: host `if` on a ref-derived value (RPL002) and
host numpy (RPL004) inside a Pallas kernel body discovered through the
`kernel = functools.partial(...)` / `pl.pallas_call(kernel, ...)` idiom."""
import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp
import numpy as np


def _bad_kernel(a_ref, o_ref, *, bn):
    x = a_ref[...]
    if x.sum() > 0:  # RPL002: host branch on traced kernel state
        o_ref[...] = x * bn
    o_ref[...] = jnp.asarray(np.cumsum(x))  # RPL004: host numpy in kernel


def launch(a, bn):
    kernel = functools.partial(_bad_kernel, bn=bn)
    return pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype)
    )(a)
