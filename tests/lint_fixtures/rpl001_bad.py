"""RPL001 positive fixture: one key feeds two samplers, streams alias."""
import jax


def sample(key):
    a = jax.random.uniform(key, (4,))
    b = jax.random.normal(key, (4,))  # RPL001: key reused
    return a + b
