"""Suppression fixture: each violation here is covered by a
`# repro-lint: disable=...` comment (trailing, standalone-above, and
file-level forms are exercised by separate fixtures)."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("slack",))  # repro-lint: disable=RPL003
def legacy(a, slack):
    return a + slack


def peek(fn):
    # repro-lint: disable=RPL006
    return fn._cache_size()
