"""Strict-mode fixture: a suppression naming a rule id that does not
exist — clean under the default exit code, exit 2 under --strict."""


def fine():  # repro-lint: disable=RPL999
    return 0
