"""RPL006 negative fixture: compile accounting through CompileWatcher."""
from repro.obs import CompileWatcher


def cache_delta(fn, run):
    watch = CompileWatcher(fns=(fn,))
    with watch:
        run()
    return watch.added
