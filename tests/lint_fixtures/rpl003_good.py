"""RPL003 negative fixture: allowlisted shape/identity statics only."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("n_levels", "policy"))
def leveled(a, n_levels, policy):
    del policy
    return a[:n_levels]


@functools.partial(jax.jit, static_argnums=(1,))
def numbered(a, max_h):
    return a[:max_h]
