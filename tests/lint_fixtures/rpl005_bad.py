"""RPL005 positive fixture: an array-carrying dataclass with no pytree
registration silently fails to flow through jit/vmap."""
import dataclasses

import jax


@dataclasses.dataclass  # RPL005: no register_dataclass wiring
class State:
    x: jax.Array
    step: int
