"""RPL001 negative fixture: split before each consume, branch-exclusive
consumes, and reassignment all reset the reuse count."""
import jax


def sample(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.uniform(k1, (4,))
    b = jax.random.normal(k2, (4,))
    return a + b


def branchy(key, flag):
    if flag:
        a = jax.random.uniform(key, (2,))
    else:
        a = jax.random.normal(key, (2,))  # exclusive with the if-arm
    return a


def reassigned(key, step):
    a = jax.random.uniform(key, (2,))
    key = jax.random.fold_in(key, step)
    b = jax.random.uniform(key, (2,))
    return a + b
