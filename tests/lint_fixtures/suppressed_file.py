# repro-lint: disable-file=RPL006
"""File-level suppression fixture: every RPL006 hit in this module is
suppressed by the header comment."""


def peek(fn):
    return fn._cache_size()


def peek_again(fn):
    return fn._cache_size()
