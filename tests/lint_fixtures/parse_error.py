"""Parse-error fixture: deliberately unparseable."""
def broken(:
    return
