"""RPL002 negative fixture: branching on static args, trace-time metadata
(.ndim/.shape), identity tests, and traced select via jnp.where are fine."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("policy",))
def dispatch(x, policy):
    if policy == "greedy":  # static arg: concrete at trace time
        return jnp.maximum(x, 0.0)
    if x.ndim > 1:  # metadata: concrete even on a tracer
        x = x.reshape(-1)
    assert x.shape[0] > 0  # shape: concrete
    return jnp.where(x > 0, x, 0.0)  # traced select, not host control flow


@jax.jit
def defaulted(x, aux=None):
    if aux is None:  # identity test: concrete
        return x
    return x + aux
