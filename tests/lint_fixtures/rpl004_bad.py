"""RPL004 positive fixture: host numpy / clock / stdlib-random calls inside
a jitted body execute (and freeze) at trace time."""
import random
import time

import jax
import numpy as np


@jax.jit
def stamped(x):
    t0 = time.time()  # RPL004: host clock frozen at trace time
    noise = np.zeros(x.shape)  # RPL004: host numpy, not traced
    jitter = random.random()  # RPL004: host randomness at trace time
    return x + noise + jitter, t0
