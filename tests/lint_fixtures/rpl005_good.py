"""RPL005 negative fixture: registered array dataclass, plus a scalar-only
dataclass that needs no registration."""
import dataclasses

import jax


@dataclasses.dataclass
class State:
    x: jax.Array
    step: int


jax.tree_util.register_dataclass(
    State, data_fields=["x", "step"], meta_fields=[]
)


@dataclasses.dataclass
class Config:
    n: int
    label: str
