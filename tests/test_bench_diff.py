"""bench_diff: the BENCH_provision.json cell-by-cell regression gate."""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))
from benchmarks.bench_diff import DEFAULT_TOL, cell_key, diff_reports, main
from repro.eval import SCHEMA, EvalReport
from repro.eval.report import CellResult


def _cell(policy="A1", scenario="sinusoidal", noise_std=0.0, window=0,
          mean_cr=1.1, bound_ok=True, **kw):
    return CellResult(
        policy=policy, scenario=scenario, noise_std=noise_std, window=window,
        alpha=0.5, bound=1.5, mean_cr=mean_cr, p95_cr=mean_cr, max_cr=mean_cr,
        mean_cost=10.0, mean_opt_cost=9.0, bound_ok=bound_ok, **kw,
    )


def _report(cells):
    return EvalReport(grid={}, cells=cells, backend="cpu",
                      jit_entries_added=0, expected_compiles=0, elapsed_s=0.0)


def test_identical_reports_diff_clean():
    r = _report([_cell(), _cell(policy="A3", window=2)])
    d = diff_reports(r, r)
    assert not d.regressed
    assert d.n_common == 2 and not d.added and not d.removed


def test_removed_cell_is_a_regression_added_is_not():
    old = _report([_cell(), _cell(policy="A3")])
    new = _report([_cell(), _cell(policy="AQ-det", scenario="replay")])
    d = diff_reports(old, new)
    assert d.regressed
    assert d.removed == [cell_key(old.cells[1])]
    assert d.added == [cell_key(new.cells[1])]
    # the reverse direction only adds — clean
    assert not diff_reports(_report([_cell()]), old).regressed


def test_mean_cr_drift_over_tol_regresses():
    old = _report([_cell(mean_cr=1.10)])
    worse = _report([_cell(mean_cr=1.10 + 1e-3)])
    better = _report([_cell(mean_cr=1.09)])
    assert diff_reports(old, worse).regressed
    assert diff_reports(old, worse, tol=1e-2).n_common == 1
    assert not diff_reports(old, worse, tol=1e-2).regressed
    d = diff_reports(old, better)
    assert not d.regressed and len(d.improved) == 1
    # drift within the default tolerance is noise, not a verdict
    assert not diff_reports(
        old, _report([_cell(mean_cr=1.10 + DEFAULT_TOL / 2)])).regressed


def test_bound_verdict_flip_regresses_both_levels():
    old = _report([_cell(bound_ok=True)])
    assert diff_reports(old, _report([_cell(bound_ok=False)])).regressed
    # per-type verdicts count too (aggregate still ok)
    t_old = _report([_cell(group_bound_ok=[True, True])])
    t_new = _report([_cell(group_bound_ok=[True, False])])
    d = diff_reports(t_old, t_new)
    assert d.regressed and len(d.flipped) == 1
    back = diff_reports(t_new, t_old)
    assert not back.regressed and len(back.unflipped) == 1


def test_duplicate_cell_keys_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        diff_reports(_report([_cell(), _cell()]), _report([_cell()]))


def test_cli_exit_codes(tmp_path, capsys):
    old = _report([_cell(mean_cr=1.10)])
    new = _report([_cell(mean_cr=1.20)])
    p_old, p_new = tmp_path / "old.json", tmp_path / "new.json"
    old.save(p_old)
    new.save(p_new)
    assert main([str(p_old), str(p_old)]) == 0
    assert main([str(p_old), str(p_new)]) == 1
    assert main([str(p_old), str(p_new), "--tol", "0.5"]) == 0
    out = capsys.readouterr().out
    assert "REGRESSION mean CR up" in out


def test_checked_in_baseline_self_diffs_clean():
    """The repo's own BENCH_provision.json must be a valid baseline (the CI
    gate diffs a fresh smoke run against it)."""
    path = pathlib.Path(__file__).parent.parent / "BENCH_provision.json"
    report = EvalReport.load(path)
    assert report.schema == SCHEMA
    assert any(c.group_mean_cr is not None for c in report.cells), (
        "checked-in benchmark lost its multi-type cells")
    dcells = [c for c in report.cells if c.slack is not None]
    assert len(dcells) >= 4, "checked-in benchmark lost its deferral cells"
    assert all(c.slo_ok for c in dcells)
    assert not diff_reports(report, report).regressed


# ---------------------------------------------------------------------------
# v3: deferral coordinates in the cell key, slo_ok flips, p99 drift
# ---------------------------------------------------------------------------

def test_deferral_coordinates_key_distinct_cells():
    rigid = _cell()
    soft = _cell(slack=4, rule="EDF", p99_delay=2, deadline_misses=0,
                 slo_ok=True)
    assert cell_key(rigid) != cell_key(soft)
    assert cell_key(rigid)[4:] == (None, None)      # pre-v3 keys unchanged
    d = diff_reports(_report([rigid, soft]), _report([rigid, soft]))
    assert not d.regressed and d.n_common == 2


def test_slo_verdict_flip_regresses():
    ok = _cell(slack=4, rule="EDF", p99_delay=2, slo_ok=True)
    bad = _cell(slack=4, rule="EDF", p99_delay=9, slo_ok=False)
    d = diff_reports(_report([ok]), _report([bad]))
    assert d.regressed and len(d.flipped) == 1
    back = diff_reports(_report([bad]), _report([ok]))
    assert not back.regressed and len(back.unflipped) == 1


def test_p99_drift_is_informational():
    old = _cell(slack=6, rule="EDF", p99_delay=2, slo_ok=True)
    new = _cell(slack=6, rule="EDF", p99_delay=5, slo_ok=True)
    d = diff_reports(_report([old]), _report([new]))
    assert not d.regressed
    assert d.latency_drift == [(cell_key(old), 2, 5)]
    assert any("p99 delay drift" in line for line in d.lines())


def test_v2_baseline_diffs_cleanly_against_v3():
    """A pre-deferral baseline (no slack columns) gains deferral cells as
    'added' — informational, exit 0."""
    v2_base = _report([_cell()])
    v3_new = _report([_cell(), _cell(slack=4, rule="EDF", slo_ok=True)])
    d = diff_reports(v2_base, v3_new)
    assert not d.regressed
    assert len(d.added) == 1 and d.n_common == 1
    assert "defer[EDF slack=4]" in d.lines()[1]
