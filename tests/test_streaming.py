"""The streaming engine's contracts, end to end.

Three layers, one invariant each:

* ``provision_stream`` (batch planning on arbitrarily long traces) is
  **bit-exact** against monolithic ``provision`` at every chunk size —
  across policies, deferral slacks and typed fleets, because both routes
  run the identical per-slot update (``_slot_update``) on the identical
  CRN wait tables and only the tiling differs.
* the kernel carry (``provision_scan_stream``) chains across calls: two
  half-trace calls with the carry threaded equal one whole-trace call.
* ``FleetProvisioner.advance()`` (the O(1)-state serving stepper) is
  chunk-size **invariant** for the no-peek policies, matches ``plan()``
  when handed the whole trace at once, and replays one compiled program
  across any chunk-size mix inside a warmed pow2 bucket (the
  zero-steady-state-recompile gate).
"""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    CostModel,
    PolicySpec,
    ProvisionSpec,
    ServerGroup,
    Workload,
    provision,
    provision_stream,
)
from repro.core.costs import PAPER_COSTS  # noqa: E402
from repro.deferral import (  # noqa: E402
    DeferralSpec,
    defer_demand,
    defer_stream,
    defer_stream_init,
    queue_scan,
    queue_stream,
    queue_stream_finalize,
    queue_stream_init,
)
from repro.serving import (  # noqa: E402
    FleetProvisioner,
    pow2_bucket,
    stepper,
)

T = 96
KEY = jax.random.PRNGKey(11)


@pytest.fixture(scope="module")
def demand():
    rng = np.random.default_rng(5)
    return jnp.asarray(rng.integers(0, 18, size=(T,)), jnp.int32)


def _assert_same(r0, r1, *, record=False):
    """Every populated ProvisionResult field bit-identical."""
    assert (np.asarray(r0.x) == np.asarray(r1.x)).all()
    for f in ("cost", "energy", "toggle_cost", "level_cost", "group_cost",
              "backlog", "max_delay", "p99_delay", "deadline_misses",
              "unserved"):
        v0, v1 = getattr(r0, f), getattr(r1, f)
        assert (v0 is None) == (v1 is None), f
        if v0 is not None:
            assert (np.asarray(v0) == np.asarray(v1)).all(), f
    if record:
        assert r1.decisions is None      # streaming records aggregates only
        for k in r0.decision_counts:
            assert (np.asarray(r0.decision_counts[k])
                    == np.asarray(r1.decision_counts[k])).all(), k


# --------------------------------------------------------------- batch route
@pytest.mark.parametrize("policy", ["A1", "A2", "A3", "delayedoff",
                                    "AQ-det", "AQ-rand"])
def test_provision_stream_bitexact_across_policies_and_slacks(policy, demand):
    """The tentpole exactness matrix: every online policy × rigid/deferred
    × chunk sizes that split waits mid-flight (t_chunk=1 splits *every*
    pending wait across a boundary; 13 is coprime to everything)."""
    for slack in (None, 3):
        d = None if slack is None else DeferralSpec(slack=slack)
        spec = ProvisionSpec(
            costs=PAPER_COSTS,
            workload=Workload(demand=demand, deferral=d),
            policy=PolicySpec(name=policy, window=2, key=KEY),
            n_levels=18,
        )
        ref = provision(spec)
        for tc in (1, 13, T):
            _assert_same(ref, provision_stream(spec, t_chunk=tc))


def test_provision_stream_typed_fleet_with_record(demand):
    costs = CostModel.from_groups(
        ServerGroup("small", 8, P=1.0, beta_on=2.0, beta_off=2.0),
        ServerGroup("big", 10, P=2.5, beta_on=4.0, beta_off=4.0),
    )
    spec = ProvisionSpec(
        costs=costs,
        workload=Workload(demand=demand),
        policy=PolicySpec(name="AQ-rand", key=KEY),
    )
    ref = provision(spec, record_decisions=True)
    got = provision_stream(spec, t_chunk=17, record_decisions=True)
    _assert_same(ref, got, record=True)
    assert got.group_cost.shape == (2,)


def test_provision_stream_mesh_route_matches(demand):
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=demand, deferral=DeferralSpec(slack=2)),
        policy=PolicySpec(name="A1", windows=jnp.arange(2)),
        n_levels=18,
        mesh=mesh,
    )
    _assert_same(provision(spec), provision_stream(spec, t_chunk=23))


def test_provision_stream_rejects_offline(demand):
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=demand),
        policy=PolicySpec(name="offline"),
        n_levels=18,
    )
    with pytest.raises(ValueError, match="online-only"):
        provision_stream(spec)


# ------------------------------------------------------------- kernel carry
def test_kernel_stream_carry_chains_across_calls(demand):
    """Two half-trace kernel calls with the carry threaded == one call."""
    from repro.kernels.provision_scan import provision_scan_stream

    n = 18
    ab = demand[None, :]
    thr = jnp.full((1, 1, n), 4.0, jnp.float32)
    z = jnp.zeros((1,), jnp.int32)
    x_full, _, _ = provision_scan_stream(
        ab, ab, thr, z, z, z, z, horizon=2, t_chunk=16, n_levels=n)
    cut = 41                            # mid-chunk AND mid-wait boundary
    xa, _, carry = provision_scan_stream(
        ab[:, :cut], ab[:, :cut], thr, z, z, z, z,
        horizon=2, t_chunk=16, n_levels=n)
    xb, _, _ = provision_scan_stream(
        ab[:, cut:], ab[:, cut:], thr, z, z, z, z,
        horizon=2, t_chunk=16, n_levels=n, carry=carry)
    got = np.concatenate([np.asarray(xa), np.asarray(xb)], axis=1)
    # the second call cannot see demand before its own range: the peek at
    # the first call's tail reads quiet, so only the carried state (not
    # the x values near the seam's peek window) must agree exactly
    assert (got == np.asarray(x_full)).all()


def test_interpret_env_override_and_telemetry_gauge(monkeypatch):
    from repro.kernels.provision_scan import _resolve_interpret
    from repro.obs.telemetry import telemetry_session

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    with telemetry_session() as tel:
        assert _resolve_interpret(None) is True
        assert tel.gauge_value("kernels/pallas_interpret") == 1.0
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    with telemetry_session() as tel:
        assert _resolve_interpret(None) is False
        assert tel.gauge_value("kernels/pallas_interpret") == 0.0
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "sideways")
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        _resolve_interpret(None)
    # an explicit argument wins over the env var
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert _resolve_interpret(True) is True


# ----------------------------------------------------------- deferral carry
def test_defer_stream_chunk_invariant_and_causal(demand):
    a = demand
    for K in (1, 4):
        full, _ = defer_stream(a, defer_stream_init(K), slack=K)
        st = defer_stream_init(K)
        outs = []
        for lo, hi in ((0, 1), (1, 40), (40, T)):
            o, st = defer_stream(a[lo:hi], st, slack=K)
            outs.append(np.asarray(o))
        assert (np.concatenate(outs) == np.asarray(full)).all()
        A, S = np.cumsum(np.asarray(a)), np.cumsum(np.asarray(full))
        assert (S <= A).all()                  # causal: never serves early
        assert (S[K:] >= A[:T - K]).all()      # every deadline met
    # the documented divergence from the batch rule: OA water-filling is
    # anticipative (it sees the t=2 burst at t=0), the stream rule is not
    burst = jnp.asarray([3, 0, 300], jnp.int32)
    oa = np.asarray(defer_demand(burst, 2))
    causal, _ = defer_stream(burst, defer_stream_init(2), slack=2)
    assert oa[0] == 3 and int(causal[0]) < 3


def test_queue_stream_matches_queue_scan_chunked(demand):
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(0, 18, size=(T,)), jnp.int32)
    for rule in ("EDF", "SPT"):
        K = 5
        ref = queue_scan(demand, x, K, rule=rule, max_slack=K)
        st = queue_stream_init(K)
        outs = []
        for lo, hi in ((0, 7), (7, 55), (55, T)):
            o, st = queue_stream(demand[lo:hi], x[lo:hi], st,
                                 rule=rule, max_slack=K)
            outs.append(np.asarray(o))
        assert (np.concatenate(outs) == np.asarray(ref["backlog"])).all()
        fin = queue_stream_finalize(st, max_slack=K)
        for k in ("served_by_age", "deadline_misses", "unserved",
                  "max_delay", "p99_delay"):
            assert (np.asarray(fin[k]) == np.asarray(ref[k])).all(), (rule, k)


# ----------------------------------------------------------------- stepper
def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (1, 7, 8, 9, 64, 65, 1000)] == \
        [8, 8, 8, 16, 64, 128, 1024]


def test_advance_one_shot_matches_plan(demand):
    a = np.asarray(demand)
    for policy, w in (("A1", 0), ("A1", 3), ("delayedoff", 0), ("AQ-det", 0)):
        prov = FleetProvisioner(PAPER_COSTS, policy=policy, window=w,
                                max_replicas=18)
        got = prov.advance(a)
        ref = FleetProvisioner(PAPER_COSTS, policy=policy, window=w,
                               max_replicas=18).plan(a)
        assert (got == np.asarray(ref.x)).all(), (policy, w)


def test_advance_chunk_invariant_no_peek_splits_pending_waits(demand):
    """delayedoff holds each idle level for Δ = 6 slots, so slot-by-slot
    advancing splits every pending wait across a chunk boundary — the
    carried (r, on, wait) state must make the schedule identical."""
    a = np.asarray(demand)
    for policy in ("delayedoff", "AQ-rand"):
        key = KEY if policy == "AQ-rand" else None
        full = FleetProvisioner(PAPER_COSTS, policy=policy, max_replicas=18,
                                key=key).advance(a)
        for sizes in ((1,) * T, (5, 3, 88), (41, 55)):
            prov = FleetProvisioner(PAPER_COSTS, policy=policy,
                                    max_replicas=18, key=key)
            pos, outs = 0, []
            for s in sizes:
                outs.append(prov.advance(a[pos:pos + s]))
                pos += s
            assert (np.concatenate(outs) == full).all(), (policy, sizes)


def test_advance_chunk_cost_plus_final_off_matches_plan(demand):
    """The stepper's chunk-local cost omits only the forced end-of-trace
    off toggles (the trace has not ended); adding them reproduces plan()'s
    total exactly."""
    a = np.asarray(demand)
    prov = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=18)
    prov.advance(a)
    ref = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=18).plan(a)
    final_off = int((np.asarray(prov.state.on)
                     & ~(a[-1] > np.arange(18))).sum())
    got = float(prov.last_plan.cost) + PAPER_COSTS.beta_off * final_off
    assert got == pytest.approx(float(ref.cost))


def test_advance_zero_recompiles_in_warmed_bucket(demand, tracer_sanitizer):
    """The satellite gate: after one warmup call, three *different* chunk
    sizes inside the same pow2 bucket add zero jit traces."""
    a = np.asarray(demand)
    prov = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=18)
    prov.advance(a[:8])                             # warmup owns bucket 8
    with tracer_sanitizer(fns=(stepper.stepper_chunk,)):
        prov.advance(a[8:13])                       # 5 -> bucket 8
        prov.advance(a[13:16])                      # 3 -> bucket 8
        prov.advance(a[16:24])                      # 8 -> bucket 8
    assert prov.metrics.plans == 4


def test_advance_deferral_mid_flight_backlog_chunk_invariant():
    """A burst pushes work into the queue; chunk boundaries cut straight
    through the live backlog and the schedule must not notice."""
    rng = np.random.default_rng(13)
    a = rng.integers(0, 6, size=(T,))
    a[30:34] = 40                                   # burst >> fleet absorbs
    spec = DeferralSpec(slack=4)
    full_p = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=24,
                              deferral=spec)
    full = full_p.advance(a)
    assert int(np.asarray(full_p.last_plan.backlog).max()) > 0
    for sizes in ((31, 2, 63), (1,) * T):
        prov = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=24,
                                deferral=spec)
        pos, outs = 0, []
        for s in sizes:
            outs.append(prov.advance(a[pos:pos + s]))
            pos += s
        assert (np.concatenate(outs) == full).all(), sizes
        assert int(prov.last_plan.deadline_misses) == 0
        assert (np.asarray(prov.last_plan.backlog)
                == np.asarray(full_p.last_plan.backlog)[pos - sizes[-1]:pos]).all()


def test_advance_rejections_and_reset(demand):
    a = np.asarray(demand)
    with pytest.raises(ValueError, match="hindsight"):
        FleetProvisioner(PAPER_COSTS, policy="offline",
                         max_replicas=18).advance(a[:8])
    with pytest.raises(ValueError, match="scalar slack"):
        FleetProvisioner(
            PAPER_COSTS, policy="A1", max_replicas=64,
            deferral=DeferralSpec(slack=np.ones(T, np.int32), max_slack=4),
        ).advance(a[:8])
    prov = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=18)
    first = prov.advance(a[:16])
    prov.reset()
    assert prov.state is None and prov._history.size == 0
    assert (prov.advance(a[:16]) == first).all()    # fresh trace, same plan
