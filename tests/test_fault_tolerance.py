"""Checkpoint/restart, preemption, elastic resharding, grad compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.configs import get_config
from repro.distributed.compression import (
    compress_grads,
    init_error_feedback,
)
from repro.distributed.fault_tolerance import StragglerDetector
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture
def tiny_cfg():
    return get_config("llama3.2-1b", reduced=True).replace(remat="none")


def test_checkpoint_roundtrip(tmp_path, tiny_cfg):
    from repro.models import init_params

    params = init_params(tiny_cfg, jax.random.key(0))
    save(tmp_path, 7, params)
    assert latest_step(tmp_path) == 7
    got = restore(tmp_path, 7, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_tmp_never_latest(tmp_path, tiny_cfg):
    from repro.models import init_params

    params = init_params(tiny_cfg, jax.random.key(0))
    save(tmp_path, 1, params)
    # simulate a crashed write
    (tmp_path / "step_00000002.tmp").mkdir()
    assert latest_step(tmp_path) == 1


def test_async_checkpointer_retention(tmp_path, tiny_cfg):
    from repro.models import init_params

    params = init_params(tiny_cfg, jax.random.key(1))
    ck = Checkpointer(tmp_path, keep=2)
    for s in (10, 20, 30):
        ck.save_async(s, params)
    ck.wait()
    steps = sorted(
        int(p.name.split("_")[1]) for p in tmp_path.iterdir() if p.is_dir()
    )
    assert steps == [20, 30]


def test_crash_restart_resumes_bitwise(tmp_path, tiny_cfg):
    """Train 20 steps; crash at 12 after ckpt@10; restart; compare to a
    clean uninterrupted run — final loss must match bitwise (deterministic
    data + state restore)."""
    tc = TrainerConfig(total_steps=20, batch=2, seq=32, ckpt_every=10,
                       ckpt_dir=str(tmp_path / "a"), log_every=5)
    t1 = Trainer(tiny_cfg, tc)
    with pytest.raises(RuntimeError):
        t1.run(fail_at_step=12)
    t1b = Trainer(tiny_cfg, tc)
    out_resumed = t1b.run()

    tc2 = TrainerConfig(total_steps=20, batch=2, seq=32, ckpt_every=10,
                        ckpt_dir=str(tmp_path / "b"), log_every=5)
    out_clean = Trainer(tiny_cfg, tc2).run()

    for a, b in zip(jax.tree.leaves(out_resumed["params"]),
                    jax.tree.leaves(out_clean["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_compression_error_feedback():
    grads = {"w": jnp.linspace(-1, 1, 1024).reshape(32, 32)}
    ef = init_error_feedback(grads)
    total = jnp.zeros_like(grads["w"])
    acc_true = jnp.zeros_like(grads["w"])
    for _ in range(50):
        g, ef, _ = compress_grads(grads, ef)
        total = total + g["w"]
        acc_true = acc_true + grads["w"]
    # error feedback: accumulated compressed grads track the true sum
    rel = float(jnp.linalg.norm(total - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 1e-2, rel


def test_training_with_compression_converges(tiny_cfg, tmp_path):
    tc = TrainerConfig(total_steps=30, batch=2, seq=32, ckpt_every=1000,
                       ckpt_dir=str(tmp_path / "c"), log_every=10,
                       grad_compression=True)
    out = Trainer(tiny_cfg, tc).run()
    losses = [loss for _, loss in out["history"]]
    assert losses[-1] < losses[0], losses


def test_straggler_detector():
    d = StragglerDetector(threshold=2.0)
    for w in range(8):
        for _ in range(5):
            d.observe(w, 1.0 if w != 3 else 5.0)
    assert d.stragglers() == [3]


def test_elastic_reshard_across_meshes(tmp_path):
    """Save on a (4,2) mesh, restore onto (2,4) — run in a subprocess with 8
    host devices so the dry-run flag doesn't leak into this process."""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.configs import get_config
from repro.models import init_params, model_zoo as zoo
from repro.checkpoint import save
from repro.distributed.elastic import reshard_restore
from repro.distributed.sharding import param_shardings

cfg = get_config("llama3.2-1b", reduced=True)
params = init_params(cfg, jax.random.key(0))
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
sh_a = param_shardings(zoo.abstract_params(cfg), mesh_a)
params_a = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh_a)
save(r"{tmp_path}", 5, params_a)

mesh_b = jax.make_mesh((2, 4), ("data", "model"))
got = reshard_restore(r"{tmp_path}", 5, params, mesh_b)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
sh_b = param_shardings(zoo.abstract_params(cfg), mesh_b)
for leaf, s in zip(jax.tree.leaves(got), jax.tree.leaves(sh_b)):
    assert leaf.sharding.is_equivalent_to(s, leaf.ndim), (leaf.sharding, s)
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=os.getcwd(), timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
