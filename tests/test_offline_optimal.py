"""Theorems 4 & 5: the construction and A0 are optimal (vs DP oracle)."""
import numpy as np
import pytest

from repro.core import (
    CostModel,
    OfflinePolicy,
    a0_cost,
    a0_schedule,
    dp_optimal_cost,
    fluid_cost,
    generate_brick_trace,
    optimal_schedule_constructed,
    schedule_cost,
    simulate,
    trace_from_intervals,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


@pytest.mark.parametrize("seed", range(12))
def test_a0_equals_constructed_schedule(seed):
    """Theorem 5: the decentralized A0 reproduces the constructed optimum."""
    rng = np.random.default_rng(seed)
    tr = generate_brick_trace(rng, horizon=50.0, rate=0.7, mean_duration=4.0)
    xa = a0_schedule(tr, COSTS)
    xc = optimal_schedule_constructed(tr, COSTS)
    ca = schedule_cost(xa, COSTS, final_level=float(tr.final_count()))
    cc = schedule_cost(xc, COSTS, final_level=float(tr.final_count()))
    assert ca == pytest.approx(cc, rel=1e-9), (
        f"A0 schedule cost {ca} != constructed optimal {cc}"
    )


@pytest.mark.parametrize("seed", range(12))
def test_a0_closed_form_matches_schedule_cost(seed):
    rng = np.random.default_rng(seed)
    tr = generate_brick_trace(rng, horizon=50.0, rate=0.7, mean_duration=4.0)
    x = a0_schedule(tr, COSTS)
    assert a0_cost(tr, COSTS) == pytest.approx(
        schedule_cost(x, COSTS, final_level=float(tr.final_count())), rel=1e-9
    )


@pytest.mark.parametrize("seed", range(12))
def test_offline_simulator_matches_a0_cost(seed):
    rng = np.random.default_rng(seed)
    tr = generate_brick_trace(rng, horizon=40.0, rate=0.8, mean_duration=3.0)
    res = simulate(tr, OfflinePolicy(), COSTS)
    assert res.cost == pytest.approx(a0_cost(tr, COSTS), rel=1e-9)


@pytest.mark.parametrize("seed", range(10))
def test_fluid_offline_equals_dp_oracle(seed):
    """Per-level decomposition == brute-force DP on random fluid traces."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 6, size=40)
    got = fluid_cost(a, "offline", COSTS).cost
    want = dp_optimal_cost(a, COSTS)
    assert got == pytest.approx(want, rel=1e-9), f"level-decomp {got} != DP {want}"


@pytest.mark.parametrize(
    "beta_on,beta_off", [(1.0, 1.0), (3.0, 3.0), (5.0, 1.0), (0.5, 4.5), (10.0, 2.0)]
)
def test_fluid_offline_equals_dp_oracle_cost_sweep(beta_on, beta_off):
    rng = np.random.default_rng(123)
    costs = CostModel(P=1.0, beta_on=beta_on, beta_off=beta_off)
    for _ in range(4):
        a = rng.integers(0, 5, size=30)
        got = fluid_cost(a, "offline", costs).cost
        want = dp_optimal_cost(a, costs)
        assert got == pytest.approx(want, rel=1e-9)


def test_brick_optimal_on_hand_example():
    """Two short jobs with a gap > Delta: server must power-cycle."""
    costs = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)  # Delta = 6
    tr = trace_from_intervals([(1.0, 2.0), (10.0, 11.0)], 20.0)
    # initial turn-on (3) + busy 2.0 + gap 8 > 6 -> beta (6) + trailing off (3)
    assert a0_cost(tr, costs) == pytest.approx(3.0 + 2.0 + 6.0 + 3.0)


def test_brick_optimal_keeps_idle_for_short_gap():
    costs = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
    tr = trace_from_intervals([(1.0, 2.0), (6.0, 7.0)], 20.0)
    # initial turn-on (3) + busy 2 + gap 4 <= 6 stays idle (4) + trailing off (3)
    assert a0_cost(tr, costs) == pytest.approx(3.0 + 2.0 + 4.0 + 3.0)


def test_brick_vs_fine_grained_dp():
    """Discretize a brick trace finely; DP cost must match a0_cost."""
    costs = CostModel(P=1.0, beta_on=2.0, beta_off=2.0)
    tr = trace_from_intervals([(1.0, 3.0), (2.0, 9.0), (5.0, 6.0), (11.0, 14.0)], 16.0)
    # slot length 1.0 aligned with integer event times: a per slot [t, t+1)
    a = np.array([tr.a_at(t + 1e-9) for t in range(16)])
    got = a0_cost(tr, costs)
    want = dp_optimal_cost(a, costs)
    assert got == pytest.approx(want, rel=1e-9)
