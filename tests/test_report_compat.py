"""Schema back-compat: checked-in v1/v2/v3/v4 report artifacts must keep
loading under the v5 reader, with every newer column defaulted to None.

The fixture files in ``tests/fixtures/`` are frozen copies of what older
code actually wrote — regenerating them from current code would defeat the
point (the reader must accept *old* bytes, not new bytes with an old
schema string)."""
from __future__ import annotations

import dataclasses
import json
import pathlib

import pytest

from repro.eval import (
    SCHEMA,
    SCHEMA_V1,
    SCHEMA_V2,
    SCHEMA_V3,
    SCHEMA_V4,
    EvalReport,
    StreamingRow,
)
from repro.eval.report import CellResult

FIXTURES = pathlib.Path(__file__).parent / "fixtures"

OLD_FIXTURES = ("report_v1.json", "report_v2.json", "report_v3.json",
                "report_v4.json")

#: columns each schema version introduced, newest first (v5's addition is
#: the report-level ``streaming`` section, not a cell column)
V4_COLUMNS = ("wall_ms", "compiles")
V3_COLUMNS = ("slack", "rule", "max_delay", "p99_delay",
              "deadline_misses", "slo_ok")
V2_COLUMNS = ("p50_cr", "cr_quantiles", "group_names", "group_mean_cr",
              "group_bound", "group_bound_ok")


@pytest.mark.parametrize("name, schema", [
    ("report_v1.json", SCHEMA_V1),
    ("report_v2.json", SCHEMA_V2),
    ("report_v3.json", SCHEMA_V3),
    ("report_v4.json", SCHEMA_V4),
])
def test_old_fixture_loads_with_new_columns_none(name, schema):
    rep = EvalReport.load(FIXTURES / name)
    assert rep.schema == schema
    assert rep.cells
    assert rep.streaming is None, f"{name}: v5 streaming should default None"
    if schema != SCHEMA_V4:
        for c in rep.cells:
            for col in V4_COLUMNS:
                assert getattr(c, col) is None, f"{name}: {col} should be None"
    if schema == SCHEMA_V1:
        for c in rep.cells:
            for col in V2_COLUMNS + V3_COLUMNS:
                assert getattr(c, col) is None
    if schema == SCHEMA_V2:
        for c in rep.cells:
            for col in V3_COLUMNS:
                assert getattr(c, col) is None


def test_v3_fixture_keeps_typed_and_deferral_columns():
    rep = EvalReport.load(FIXTURES / "report_v3.json")
    typed = [c for c in rep.cells if c.group_mean_cr is not None]
    defer = [c for c in rep.cells if c.slack is not None]
    assert typed and defer
    assert typed[0].group_names == ["efficient", "legacy"]
    assert defer[0].rule == "EDF" and defer[0].slo_ok is True


def test_v4_fixture_keeps_runtime_columns():
    rep = EvalReport.load(FIXTURES / "report_v4.json")
    assert any(c.wall_ms is not None for c in rep.cells)
    assert any(c.compiles is not None for c in rep.cells)


def test_loaded_old_report_round_trips_preserving_schema(tmp_path):
    rep = EvalReport.load(FIXTURES / "report_v2.json")
    path = rep.save(tmp_path / "again.json")
    again = EvalReport.load(path)
    assert again.schema == SCHEMA_V2
    assert again.cells == rep.cells


def test_runtime_columns_are_excluded_from_cell_equality():
    """wall_ms/compiles are runtime facts (compare=False): two runs of the
    same grid on different machines must still produce *equal* cells."""
    rep = EvalReport.load(FIXTURES / "report_v1.json")
    base = rep.cells[0]
    timed = dataclasses.replace(base, wall_ms=123.4, compiles=1)
    assert timed == base
    assert timed.wall_ms == 123.4 and base.wall_ms is None


def test_streaming_rows_round_trip_and_latency_not_compared(tmp_path):
    """The v5 streaming section serializes, reloads, and its wall-clock
    latency columns stay out of equality (the compiles claim is a result
    and IS compared)."""
    rep = EvalReport.load(FIXTURES / "report_v4.json")
    rep.schema = SCHEMA
    rep.streaming = [
        StreamingRow(policy="A1", t_chunk=64, chunks=16, slots=1024,
                     compiles=0, p50_ms=1.25, p99_ms=3.5),
    ]
    again = EvalReport.load(rep.save(tmp_path / "v5.json"))
    assert again.schema == SCHEMA
    assert again.streaming == rep.streaming
    refit = dataclasses.replace(again.streaming[0], p50_ms=99.0, p99_ms=99.0)
    assert refit == rep.streaming[0]
    assert dataclasses.replace(refit, compiles=3) != rep.streaming[0]
    assert any(line.startswith("streaming:")
               for line in again.summary_lines())


def test_current_schema_is_v5_and_unknown_schema_rejected(tmp_path):
    assert SCHEMA.endswith("/v5")
    doc = json.loads((FIXTURES / "report_v1.json").read_text())
    doc["schema"] = "repro.eval/v999"
    with pytest.raises(ValueError, match="v999"):
        EvalReport.from_dict(doc)


def test_fixtures_are_frozen_old_bytes():
    """The fixtures must not quietly grow newer columns (someone
    regenerating them from current code) — the raw JSON is the contract."""
    for name in OLD_FIXTURES:
        doc = json.loads((FIXTURES / name).read_text())
        assert "streaming" not in doc, (
            f"{name} contains the v5 streaming section — fixtures must "
            "stay old bytes"
        )
        if name != "report_v4.json":
            for cell in doc["cells"]:
                assert "wall_ms" not in cell and "compiles" not in cell, (
                    f"{name} contains v4 columns — fixtures must stay old bytes"
                )
    v1 = json.loads((FIXTURES / "report_v1.json").read_text())
    for cell in v1["cells"]:
        assert "slack" not in cell and "p50_cr" not in cell


def test_fixture_field_sets_match_dataclass():
    """Every fixture key must still be a CellResult field (else loading
    would crash with an unexpected-kwarg TypeError — this pins the rename
    hazard explicitly)."""
    fields = {f.name for f in dataclasses.fields(CellResult)}
    for name in OLD_FIXTURES:
        doc = json.loads((FIXTURES / name).read_text())
        for cell in doc["cells"]:
            unknown = set(cell) - fields
            assert not unknown, f"{name}: unknown cell keys {unknown}"
