"""Per-architecture smoke tests on REDUCED configs (CPU).

For each of the 10 assigned archs: one forward/loss/grad step plus a
prefill+decode consistency check (decode logits at position S must match the
teacher-forced logits at that position).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (
    decode_fn,
    init_cache,
    init_params,
    logits_fn,
    loss_fn,
    prefill_fn,
)

ARCHS = list_archs()


def make_batch(cfg, rng, batch=2, seq=16):
    specs = {}
    if cfg.frontend == "vision_stub":
        nf = cfg.n_frontend_tokens
        specs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq - nf)), jnp.int32
        )
        specs["frontend"] = jnp.asarray(
            rng.standard_normal((batch, nf, cfg.d_model)), jnp.bfloat16
        )
    elif cfg.frontend == "audio_stub":
        specs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32
        )
        specs["frontend"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)), jnp.bfloat16
        )
    else:
        specs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32
        )
    return specs


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_and_grad(arch):
    cfg = get_config(arch, reduced=True).replace(remat="none")
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) > 0.0
    # crude sanity: random-init CE should be near log(vocab)
    assert float(metrics["ce"]) < np.log(cfg.vocab_size) + 2.0

    grads = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))(params, batch)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm) and float(gnorm) > 0.0, f"{arch}: bad grad norm"


@pytest.mark.parametrize("arch", ARCHS)
def test_output_shapes(arch):
    cfg = get_config(arch, reduced=True).replace(remat="none")
    rng = np.random.default_rng(1)
    params = init_params(cfg, jax.random.key(1))
    batch = make_batch(cfg, rng)
    logits = jax.jit(lambda p, b: logits_fn(p, cfg, b))(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(tokens[S]) after prefill(tokens[:S]) == teacher-forced logits.

    MoE archs run with dropless capacity here: capacity-based dropping
    depends on the token population, so teacher-forcing and decode only agree
    when nothing is dropped (the standard capacity artifact).
    """
    cfg = get_config(arch, reduced=True).replace(remat="none")
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    rng = np.random.default_rng(2)
    B, S = 2, 12
    params = init_params(cfg, jax.random.key(2))
    batch = make_batch(cfg, rng, batch=B, seq=S)

    full_logits = jax.jit(lambda p, b: logits_fn(p, cfg, b))(params, batch)

    prefix = dict(batch)
    prefix["tokens"] = batch["tokens"][:, :-1]
    src_len = prefix["frontend"].shape[1] if "frontend" in prefix else 0
    n_text = prefix["tokens"].shape[1]
    total_prefix = n_text + (
        cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0
    )
    cache = init_cache(cfg, B, total_prefix + 8, src_len=src_len)
    pre_logits, cache = jax.jit(lambda p, b, c: prefill_fn(p, cfg, b, c))(
        params, prefix, cache
    )
    # prefill logits at last prefix position == teacher-forced at that position
    np.testing.assert_allclose(
        np.asarray(pre_logits, np.float32),
        np.asarray(full_logits[:, -2, :], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    last_tok = batch["tokens"][:, -1]
    cur_len = jnp.int32(total_prefix)
    dec_logits, _ = jax.jit(lambda p, t, n, c: decode_fn(p, cfg, t, n, c))(
        params, last_tok, cur_len, cache
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits[:, -1, :], np.float32),
        rtol=2e-2, atol=2e-2,
    )
