"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import decode_attention_ref, flash_attention_ref


def rand(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kvh,hd,bq,bk",
    [
        (2, 256, 4, 4, 64, 128, 128),    # MHA
        (1, 256, 8, 2, 64, 128, 128),    # GQA 4x
        (2, 128, 4, 1, 128, 128, 128),   # MQA
        (1, 512, 2, 2, 64, 256, 128),    # rectangular blocks
        (1, 384, 2, 1, 64, 128, 128),    # non-power-of-two S
    ],
)
def test_flash_attention_causal(dtype, b, s, h, kvh, hd, bq, bk):
    ks = jax.random.split(jax.random.key(0), 3)
    q = rand(ks[0], (b, s, h, hd), dtype)
    k = rand(ks[1], (b, s, kvh, hd), dtype)
    v = rand(ks[2], (b, s, kvh, hd), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("window", [32, 128, 256])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.key(1), 3)
    b, s, h, kvh, hd = 1, 256, 4, 2, 64
    q = rand(ks[0], (b, s, h, hd), jnp.float32)
    k = rand(ks[1], (b, s, kvh, hd), jnp.float32)
    v = rand(ks[2], (b, s, kvh, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=128, block_k=128, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.key(2), 3)
    b, s, h, kvh, hd = 1, 256, 2, 2, 64
    q = rand(ks[0], (b, s, h, hd), jnp.float32)
    k = rand(ks[1], (b, s, kvh, hd), jnp.float32)
    v = rand(ks[2], (b, s, kvh, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kvh,hd,bk",
    [
        (2, 512, 8, 2, 64, 128),
        (1, 1024, 4, 4, 128, 256),
        (3, 256, 8, 1, 64, 128),
    ],
)
def test_decode_attention(dtype, b, s, h, kvh, hd, bk):
    ks = jax.random.split(jax.random.key(3), 4)
    q = rand(ks[0], (b, h, hd), dtype)
    kc = rand(ks[1], (b, s, kvh, hd), dtype)
    vc = rand(ks[2], (b, s, kvh, hd), dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
    got = decode_attention(q, kc, vc, lengths, block_k=bk, interpret=True)
    want = decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_decode_attention_full_and_single_lengths():
    b, s, h, kvh, hd = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.key(4), 3)
    q = rand(ks[0], (b, h, hd), jnp.float32)
    kc = rand(ks[1], (b, s, kvh, hd), jnp.float32)
    vc = rand(ks[2], (b, s, kvh, hd), jnp.float32)
    for lens in ([s, s], [1, 1], [1, s]):
        lengths = jnp.asarray(lens, jnp.int32)
        got = decode_attention(q, kc, vc, lengths, block_k=128, interpret=True)
        want = decode_attention_ref(q, kc, vc, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention():
    """Kernel semantics == the model's einsum attention path (same masks)."""
    from repro.models.attention import _causal_mask, _expand_kv, _sdpa

    b, s, h, kvh, hd = 1, 128, 4, 2, 64
    ks = jax.random.split(jax.random.key(5), 3)
    q = rand(ks[0], (b, s, h, hd), jnp.float32)
    k = rand(ks[1], (b, s, kvh, hd), jnp.float32)
    v = rand(ks[2], (b, s, kvh, hd), jnp.float32)
    mask = _causal_mask(s, s, 0, 0)[None, None]
    want = _sdpa(q * hd ** -0.5 / hd ** -0.5, _expand_kv(k, h), _expand_kv(v, h),
                 mask, jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
