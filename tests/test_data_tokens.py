"""Token-substrate regressions: the Zipf sampler's ids must stay in-vocab."""
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokens import TokenPipeline, _zipf_tokens


class _NearOneRng:
    """An rng whose uniforms all land above the float64 CDF endpoint."""

    def uniform(self, size=None):
        return np.full(size, 1.0 - 1e-15)


def test_zipf_ids_stay_in_vocab_when_u_is_near_one():
    # the Zipf CDF's float64 endpoint is < 1.0, so a draw above it used to
    # searchsorted to index `vocab` — one past the embedding table
    vocab = 257
    ids = _zipf_tokens(_NearOneRng(), vocab, (4, 8))
    assert ids.shape == (4, 8)
    assert ids.max() == vocab - 1
    assert ids.min() >= 0


def test_zipf_ids_in_range_and_deterministic_at_scale():
    rng = np.random.default_rng(0)
    ids = _zipf_tokens(rng, 1000, (64, 64))
    assert 0 <= ids.min() and ids.max() < 1000
    redraw = _zipf_tokens(np.random.default_rng(0), 1000, (64, 64))
    np.testing.assert_array_equal(ids, redraw)


def test_pipeline_batches_stay_in_vocab():
    cfg = ModelConfig(
        name="t", family="dense", n_layers=1, d_model=8, n_heads=2,
        n_kv_heads=2, d_ff=16, vocab_size=64,
    )
    pipe = TokenPipeline(cfg, batch=2, seq=16, seed=0)
    batch = pipe.batch_at(0)
    toks = np.asarray(batch["tokens"])
    assert toks.max() < cfg.vocab_size
    np.testing.assert_array_equal(toks, np.asarray(pipe.batch_at(0)["tokens"]))
