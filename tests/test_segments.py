"""Critical segment construction + Proposition 1 (paper Section III-A)."""
import numpy as np
import pytest

from repro.core import (
    SegmentType,
    critical_segments,
    critical_times,
    generate_brick_trace,
    trace_from_intervals,
)


def fig1_like_trace():
    """A trace exercising all four segment types.

    a(t): rises (arrivals), then a departure with no return (step-decreasing),
    then a canyon, then a U-shape.
    """
    # horizon 100
    jobs = [
        (0.5, 30.0),    # long-lived base job
        (1.0, 10.0),    # departs at 10 -> canyon structure below
        (2.0, 6.0),     # quick job: U-shape inside
        (7.0, 9.0),     # returns to level then leaves again
        (12.0, 28.0),   # arrival after canyon
        (40.0, 60.0),   # later activity
        (41.0, 45.0),
        (47.0, 59.0),
    ]
    return trace_from_intervals(jobs, 100.0)


def test_critical_times_cover_horizon():
    tr = fig1_like_trace()
    ct = critical_times(tr)
    assert ct[0] == 0.0
    assert ct[-1] <= tr.horizon
    assert all(b > a for a, b in zip(ct[:-1], ct[1:]))


def test_all_segments_classified():
    tr = fig1_like_trace()
    segs = critical_segments(tr)
    assert segs, "must produce at least one segment"
    for s in segs:
        assert s.seg_type in SegmentType
    # segments tile [0, last critical time]
    for s0, s1 in zip(segs[:-1], segs[1:]):
        assert s0.end == s1.start


def test_type_I_first_segment_when_starting_with_arrivals():
    tr = trace_from_intervals([(1.0, 5.0), (2.0, 6.0), (3.0, 7.0)], 10.0)
    segs = critical_segments(tr)
    assert segs[0].seg_type == SegmentType.TYPE_I
    # first departure at t=5 ends the first segment
    assert segs[0].end == 5.0


def test_type_III_u_shape():
    # one job departs and an identical level returns shortly after
    tr = trace_from_intervals([(0.5, 4.0), (1.0, 3.0), (3.5, 8.0)], 10.0)
    segs = critical_segments(tr)
    types = [s.seg_type for s in segs]
    assert SegmentType.TYPE_III in types


def test_type_II_step_decreasing():
    tr = trace_from_intervals([(1.0, 4.0), (2.0, 6.0)], 10.0)
    segs = critical_segments(tr)
    types = [s.seg_type for s in segs]
    assert SegmentType.TYPE_II in types


@pytest.mark.parametrize("seed", range(8))
def test_random_traces_segments_well_formed(seed):
    rng = np.random.default_rng(seed)
    tr = generate_brick_trace(rng, horizon=60.0, rate=0.8, mean_duration=3.0)
    segs = critical_segments(tr)
    for s in segs:
        assert s.end > s.start
        assert s.seg_type in SegmentType
    # Prop 1, type-specific invariants
    for s in segs:
        if s.seg_type in (SegmentType.TYPE_III, SegmentType.TYPE_IV):
            assert tr.a_at(s.end) == s.start_level
