"""Unit tests for dry-run accounting tools (parser, extrapolation, mesh)."""
import pytest

from repro.launch.dryrun import collective_bytes


def test_collective_parser_sync_ops():
    hlo = """
  %all-reduce = f32[256,1024]{1,0} all-reduce(%dot), channel_id=2, replica_groups={{0,1}}, to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(%p0), channel_id=3, dimensions={0}
  %rs = f32[32]{0} reduce-scatter(%x), channel_id=4, to_apply=%add
  %unrelated = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 256 * 1024 * 4
    assert got["all-gather"] == 64 * 512 * 2
    assert got["reduce-scatter"] == 32 * 4
    assert "dot" not in got


def test_collective_parser_async_pairs_not_double_counted():
    hlo = """
  %ars = (f32[128]{0}, f32[128]{0}) all-reduce-start(%x), channel_id=5, to_apply=%add
  %ard = f32[128]{0} all-reduce-done(%ars)
"""
    got = collective_bytes(hlo)
    # only the -start line counts (both tuple shapes belong to it)
    assert got["all-reduce"] == 2 * 128 * 4


def test_collective_parser_tuple_shapes():
    hlo = "  %a2a = (bf16[16,64]{1,0}, bf16[16,64]{1,0}) all-to-all(%x, %y), channel_id=7\n"
    got = collective_bytes(hlo)
    assert got["all-to-all"] == 2 * 16 * 64 * 2


def test_depth_extrapolation_linear():
    """total(L) = f(p) + (L/p - 1) * (f(2p) - f(p)) is exact for linear f."""
    base, per_layer = 7.0, 3.0
    def f(k):
        return base + per_layer * k

    p, L = 1, 95
    got = f(p) + (L // p - 1) * (f(2 * p) - f(p))
    assert got == pytest.approx(base + per_layer * L)


def test_production_mesh_shapes():
    import os
    import subprocess
    import sys

    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys; sys.path.insert(0, "src")
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.devices.size == 256 and m1.axis_names == ("data", "model")
m2 = make_production_mesh(multi_pod=True)
assert m2.devices.size == 512 and m2.axis_names == ("pod", "data", "model")
print("MESH_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=os.getcwd(), timeout=120)
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr
