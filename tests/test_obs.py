"""The observability layer: telemetry registry, compile accounting, and
per-slot decision provenance.

The load-bearing contracts:

- the default registry is a no-op (``NullTelemetry``) and the disabled
  path is bit-exact AND compile-count-identical to a build without the
  layer — observability must cost nothing when off;
- ``record_decisions=True`` emits per-slot per-level reason codes whose
  toggle bits reconstruct the schedule *exactly* (provenance is derived
  from the same scan that decided, never re-simulated);
- the mesh/Pallas fleet route reports the same aggregate decision counts
  as the lax.scan route on identical specs.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CostModel,
    PolicySpec,
    ProvisionSpec,
    ServerGroup,
    Workload,
    msr_like_trace,
    provision,
)
from repro.obs import (
    COUNT_ORDER,
    DEMAND_RISE,
    CompileWatcher,
    NullTelemetry,
    Telemetry,
    decision_counts,
    engine_cache_size,
    explain_slot,
    get_telemetry,
    profile_to,
    reconstruct_schedule,
    set_telemetry,
    telemetry_session,
    toggles_from_decisions,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


def _spec(a, n_levels, policy="A1", mesh=None, use_pallas=True, key=None,
          windows=None):
    return ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=jnp.asarray(a, jnp.int32)),
        policy=PolicySpec(policy, window=2, windows=windows, key=key),
        n_levels=n_levels,
        mesh=mesh,
        use_pallas=use_pallas,
    )


# ---------------------------------------------------------------- telemetry


def test_counters_gauges_histograms():
    tel = Telemetry()
    tel.count("requests")
    tel.count("requests", 2.0)
    tel.gauge("depth", 7.0)
    tel.gauge("depth", 3.0)
    for v in (1.0, 2.0, 3.0, 4.0):
        tel.observe("lat", v)
    assert tel.counter_value("requests") == 3.0
    assert tel.gauge_value("depth") == 3.0
    assert tel.samples("lat") == [1.0, 2.0, 3.0, 4.0]
    assert tel.quantile("lat", 0.0) == 1.0
    assert tel.quantile("lat", 1.0) == 4.0


def test_labels_key_separate_series():
    tel = Telemetry()
    tel.count("toggles", 1, policy="A1")
    tel.count("toggles", 5, policy="A3")
    assert tel.counter_value("toggles", policy="A1") == 1
    assert tel.counter_value("toggles", policy="A3") == 5


def test_span_emits_chrome_event_and_histogram():
    tel = Telemetry()
    with tel.span("work", policy="A1"):
        pass
    trace = tel.chrome_trace()
    events = trace["traceEvents"]
    assert any(e["name"] == "work" and e["ph"] == "X" for e in events)
    assert len(tel.samples("span/work")) == 1


def test_trace_and_metrics_files_round_trip(tmp_path):
    tel = Telemetry()
    with tel.span("phase"):
        tel.count("n")
    tel.instant("marker")
    tp = tel.write_chrome_trace(tmp_path / "t.json")
    mp = tel.write_metrics_jsonl(tmp_path / "m.jsonl")
    loaded = json.loads(tp.read_text())
    assert isinstance(loaded["traceEvents"], list) and loaded["traceEvents"]
    records = [json.loads(line) for line in mp.read_text().splitlines()]
    assert any(r["name"] == "n" for r in records)


def test_default_registry_is_disabled_noop():
    tel = get_telemetry()
    assert isinstance(tel, NullTelemetry) and not tel.enabled
    tel.count("x")
    tel.observe("x", 1.0)
    with tel.span("x"):
        pass
    assert tel.chrome_trace()["traceEvents"] == []


def test_telemetry_session_installs_and_restores():
    before = get_telemetry()
    with telemetry_session() as tel:
        assert get_telemetry() is tel and tel.enabled
        tel.count("inside")
    assert get_telemetry() is before
    assert tel.counter_value("inside") == 1


def test_set_telemetry_returns_previous():
    tel = Telemetry()
    old = set_telemetry(tel)
    try:
        assert get_telemetry() is tel
    finally:
        set_telemetry(old)


# ---------------------------------------------------------- CompileWatcher


def test_compile_watcher_counts_cold_then_warm():
    f = jax.jit(lambda x: x * 2)
    watch = CompileWatcher(fns=(f,))
    if not watch.available:
        pytest.skip("private jit _cache_size API unavailable")
    with watch:
        jax.block_until_ready(f(jnp.ones(4)))
    assert watch.added == 1
    with watch:
        jax.block_until_ready(f(jnp.ones(4)))
    assert watch.added == 0


def test_compile_watcher_degrades_to_minus_one():
    watch = CompileWatcher(fns=(lambda x: x,))    # not a jitted fn
    assert not watch.available
    assert watch.snapshot() == -1
    with watch:
        pass
    assert watch.added == -1


def test_compile_watcher_feeds_telemetry():
    f = jax.jit(lambda x: x + 1)
    tel = Telemetry()
    watch = CompileWatcher(fns=(f,), telemetry=tel)
    if not watch.available:
        pytest.skip("private jit _cache_size API unavailable")
    with watch:
        jax.block_until_ready(f(jnp.ones(3)))
    assert tel.counter_value("jax/compiles") == 1


def test_engine_cache_size_returns_int():
    assert isinstance(engine_cache_size(), int)


def test_profile_to_none_is_noop():
    with profile_to(None):
        pass


# ------------------------------------------------------ decision provenance


@pytest.mark.parametrize("policy, key", [
    ("A1", None),
    ("A2", jax.random.key(3)),
    ("delayedoff", None),
])
def test_reason_codes_reconstruct_schedule_exactly(policy, key):
    """The provenance property: cumulative toggle bits == the schedule.

    ``x(t) = x(0) + cumsum(rises - offs)`` must hold *exactly* — the codes
    come out of the same scan that decided, so any divergence is a bug in
    the recording, not noise."""
    n = 48
    a = msr_like_trace(np.random.default_rng(7), n_slots=200, mean_jobs=12.0)
    res = provision(_spec(a, n, policy, key=key), record_decisions=True)
    dec = np.asarray(res.decisions)
    assert dec.shape == (200, n) and dec.dtype == np.uint8
    x = np.asarray(res.x)
    x0 = min(int(a[0]), n)
    np.testing.assert_array_equal(reconstruct_schedule(dec, x0), x)
    # and the engine's on-device counts agree with the numpy reduction
    want = decision_counts(dec)
    assert set(res.decision_counts) == set(COUNT_ORDER)
    for name in COUNT_ORDER:
        np.testing.assert_array_equal(
            np.asarray(res.decision_counts[name]), want[name]
        )


def test_reconstruction_holds_on_batched_sweep():
    n = 32
    traces = np.stack([
        msr_like_trace(np.random.default_rng(s), n_slots=96, mean_jobs=8.0)
        for s in range(3)
    ])
    spec = _spec(traces, n, "A3", key=jax.random.key(0),
                 windows=jnp.arange(2, dtype=jnp.int32))
    res = provision(spec, record_decisions=True)
    dec = np.asarray(res.decisions)
    x = np.asarray(res.x)
    assert dec.shape == x.shape + (n,)
    for w in range(dec.shape[0]):
        for b in range(dec.shape[1]):
            x0 = min(int(traces[b, 0]), n)
            np.testing.assert_array_equal(
                reconstruct_schedule(dec[w, b], x0), x[w, b]
            )


def test_toggle_bits_match_schedule_diffs():
    n = 40
    a = msr_like_trace(np.random.default_rng(1), n_slots=150, mean_jobs=10.0)
    res = provision(_spec(a, n), record_decisions=True)
    rises, offs = toggles_from_decisions(np.asarray(res.decisions))
    dx = np.diff(np.asarray(res.x), prepend=min(int(a[0]), n))
    np.testing.assert_array_equal(rises - offs, dx)


def test_explain_slot_names_reasons():
    a = msr_like_trace(np.random.default_rng(2), n_slots=100, mean_jobs=8.0)
    res = provision(_spec(a, 32), record_decisions=True)
    dec = np.asarray(res.decisions)
    t = int(np.argmax((dec & DEMAND_RISE).any(axis=1)))
    reasons = explain_slot(dec, t)
    assert any("demand-rise" in line for line in reasons)


def test_record_default_off_and_offline_rejects_record():
    a = msr_like_trace(np.random.default_rng(3), n_slots=80, mean_jobs=6.0)
    res = provision(_spec(a, 16))
    assert res.decisions is None and res.decision_counts is None
    off = ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=jnp.asarray(a, jnp.int32)),
        policy=PolicySpec("offline"),
        n_levels=16,
    )
    with pytest.raises(ValueError, match="record"):
        provision(off, record_decisions=True)


def test_disabled_path_bit_exact_and_no_extra_compiles():
    """The zero-overhead contract: record off (the default) produces the
    same schedule AND hits the same compiled program as before the layer
    existed — even with a live telemetry registry installed."""
    from repro.core.jax_provision import _run

    a = msr_like_trace(np.random.default_rng(4), n_slots=120, mean_jobs=8.0)
    spec = _spec(a, 24)
    base = np.asarray(jax.block_until_ready(provision(spec).x))     # warm
    watch = CompileWatcher(fns=(_run,))
    with telemetry_session():
        with watch:
            lit = np.asarray(jax.block_until_ready(provision(spec).x))
    np.testing.assert_array_equal(lit, base)
    if watch.available:
        assert watch.added == 0
    # record=True must not change the decisions either, just annotate them
    rec = provision(spec, record_decisions=True)
    np.testing.assert_array_equal(np.asarray(rec.x), base)


def test_mesh_route_counts_match_scan_route():
    """The fleet path records aggregate counters only — but they must agree
    with the per-slot codes the scan route emits on the same spec."""
    n = 16
    traces = np.stack([
        msr_like_trace(np.random.default_rng(s), n_slots=96, mean_jobs=6.0)
        for s in range(2)
    ])
    plain = provision(_spec(traces, n), record_decisions=True)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    for use_pallas in (False, True):
        meshed = provision(
            _spec(traces, n, mesh=mesh, use_pallas=use_pallas),
            record_decisions=True,
        )
        np.testing.assert_array_equal(np.asarray(meshed.x),
                                      np.asarray(plain.x))
        for name in COUNT_ORDER:
            np.testing.assert_array_equal(
                np.asarray(meshed.decision_counts[name]),
                np.asarray(plain.decision_counts[name]),
                err_msg=f"{name} (use_pallas={use_pallas})",
            )


def test_typed_fleet_records_decisions():
    groups = (
        ServerGroup("fast", 8, P=1.0, beta_on=3.0, beta_off=3.0),
        ServerGroup("slow", 8, P=1.5, beta_on=4.5, beta_off=4.5),
    )
    a = msr_like_trace(np.random.default_rng(9), n_slots=96, mean_jobs=6.0)
    spec = ProvisionSpec(
        costs=CostModel.from_groups(*groups),
        workload=Workload(demand=jnp.asarray(a, jnp.int32)),
        policy=PolicySpec("AQ-det"),
        n_levels=16,
    )
    res = provision(spec, record_decisions=True)
    dec = np.asarray(res.decisions)
    x0 = min(int(a[0]), 16)
    np.testing.assert_array_equal(reconstruct_schedule(dec, x0),
                                  np.asarray(res.x))


def test_provision_spans_reach_telemetry():
    a = msr_like_trace(np.random.default_rng(5), n_slots=80, mean_jobs=6.0)
    with telemetry_session() as tel:
        provision(_spec(a, 16))
    assert len(tel.samples("span/provision")) == 1


# --------------------------------------------------------- serving metrics


def test_plan_metrics_prometheus_text():
    from repro.serving import FleetProvisioner

    rng = np.random.default_rng(0)
    planner = FleetProvisioner(COSTS, policy="A1", max_replicas=16)
    for _ in range(3):
        planner.advance(rng.integers(0, 12, size=8))
    m = planner.metrics
    assert m.plans == 3 and len(m.plan_latencies_ms) == 3
    assert m.latency_quantile(0.5) is not None
    txt = m.prometheus_text()
    assert "repro_serving_plans_total 3" in txt
    assert 'quantile="0.99"' in txt
    assert "repro_serving_backlog_depth" in txt


def test_plan_metrics_mirror_into_telemetry():
    from repro.serving.metrics import PlanMetrics

    with telemetry_session() as tel:
        m = PlanMetrics()
        m.observe_plan(12.5, toggles=4, backlog=2)
    assert tel.counter_value("serving/toggles") == 4
    assert tel.gauge_value("serving/backlog_depth") == 2
    assert tel.samples("serving/plan_latency_ms") == [12.5]
    assert m.peak_backlog == 2
