"""Jitted JAX provisioning engine == numpy reference engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CostModel, fluid_cost, fluid_scan, msr_like_trace
from repro.core.jax_provision import (
    _level_schedule,
    provision_cost,
    provision_schedule,
    provision_schedule_sharded,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
B = int(COSTS.delta)


@pytest.mark.parametrize("window", [0, 1, 3, 5, 8])
@pytest.mark.parametrize("seed", range(4))
def test_a1_jax_matches_numpy_scan(window, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 8, size=60)
    want = fluid_scan(a, "A1", COSTS, window=window)
    got_x = provision_schedule(
        jnp.asarray(a, jnp.int32), n_levels=int(a.max()) + 1, delta=B,
        window=window, policy="A1",
    )
    np.testing.assert_array_equal(np.asarray(got_x), want.x)


@pytest.mark.parametrize("seed", range(4))
def test_offline_jax_matches_optimal_cost(seed):
    rng = np.random.default_rng(seed + 100)
    a = rng.integers(0, 6, size=50)
    n = int(a.max()) + 1
    ons = _level_schedule(jnp.asarray(a, jnp.int32), n, B, 0, "offline")
    cost = provision_cost(jnp.asarray(a), ons, COSTS.P, COSTS.beta_on,
                          COSTS.beta_off)
    want = fluid_cost(a, "offline", COSTS).cost
    assert float(cost) == pytest.approx(want, rel=1e-9)


def test_a1_jax_cost_matches_numpy_cost():
    a = msr_like_trace(np.random.default_rng(1), n_slots=300, mean_jobs=15.0)
    for w in (0, 2, 5):
        ons = _level_schedule(jnp.asarray(a, jnp.int32), int(a.max()) + 1, B, w, "A1")
        cost = float(provision_cost(jnp.asarray(a), ons, COSTS.P,
                                    COSTS.beta_on, COSTS.beta_off))
        want = fluid_scan(a, "A1", COSTS, window=w).cost
        assert cost == pytest.approx(want, rel=1e-9)


def test_sharded_fleet_matches_single_device():
    """shard_map level-sharded provisioning == single-device result."""
    a = msr_like_trace(np.random.default_rng(2), n_slots=200, mean_jobs=20.0)
    n = int(a.max()) + 1
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    got = provision_schedule_sharded(
        mesh, jnp.asarray(a, jnp.int32), n_levels=n, delta=B, window=2
    )
    want = provision_schedule(
        jnp.asarray(a, jnp.int32), n_levels=n, delta=B, window=2, policy="A1"
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
