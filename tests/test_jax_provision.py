"""Jitted JAX provisioning engine == numpy reference engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    A2Randomized,
    A3Randomized,
    A1Deterministic,
    CostModel,
    brick_trace_from_fluid,
    fluid_cost,
    fluid_scan,
    msr_like_trace,
    simulate,
)
from repro.core.jax_provision import (
    _level_schedule,
    _uniforms,
    _waits_from_uniforms,
    provision_cost,
    provision_schedule,
    provision_schedule_sharded,
    provision_sweep,
    provision_sweep_costs,
)
from repro.kernels.provision_scan import provision_scan

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
B = int(COSTS.delta)


@pytest.mark.parametrize("window", [0, 1, 3, 5, 8])
@pytest.mark.parametrize("seed", range(4))
def test_a1_jax_matches_numpy_scan(window, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 8, size=60)
    want = fluid_scan(a, "A1", COSTS, window=window)
    got_x = provision_schedule(
        jnp.asarray(a, jnp.int32), n_levels=int(a.max()) + 1, delta=B,
        window=window, policy="A1",
    )
    np.testing.assert_array_equal(np.asarray(got_x), want.x)


@pytest.mark.parametrize("seed", range(4))
def test_offline_jax_matches_optimal_cost(seed):
    rng = np.random.default_rng(seed + 100)
    a = rng.integers(0, 6, size=50)
    n = int(a.max()) + 1
    ons = _level_schedule(jnp.asarray(a, jnp.int32), n, B, 0, "offline")
    cost = provision_cost(jnp.asarray(a), ons, COSTS.P, COSTS.beta_on,
                          COSTS.beta_off)
    want = fluid_cost(a, "offline", COSTS).cost
    assert float(cost) == pytest.approx(want, rel=1e-9)


def test_a1_jax_cost_matches_numpy_cost():
    a = msr_like_trace(np.random.default_rng(1), n_slots=300, mean_jobs=15.0)
    for w in (0, 2, 5):
        ons = _level_schedule(jnp.asarray(a, jnp.int32), int(a.max()) + 1, B, w, "A1")
        cost = float(provision_cost(jnp.asarray(a), ons, COSTS.P,
                                    COSTS.beta_on, COSTS.beta_off))
        want = fluid_scan(a, "A1", COSTS, window=w).cost
        assert cost == pytest.approx(want, rel=1e-9)


# ---------------------------------------------------------------------------
# Batched multi-policy engine: A2/A3, batching, sweep, Pallas scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["A2", "A3"])
@pytest.mark.parametrize("window", [0, 2, 4])
def test_randomized_jax_matches_fluid_scan_in_expectation(policy, window):
    """Jitted A2/A3 mean cost over keys == numpy slot-scan mean over seeds."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 6, size=60)
    n = int(a.max()) + 1
    runs = 300
    ab = jnp.asarray(np.tile(a, (runs, 1)), jnp.int32)
    costs = provision_sweep_costs(
        ab, n_levels=n, delta=B, windows=jnp.array([window]), policy=policy,
        key=jax.random.key(7),
        P=COSTS.P, beta_on=COSTS.beta_on, beta_off=COSTS.beta_off,
    )
    jit_mean = float(jnp.mean(costs[0]))
    ref_mean = np.mean([
        fluid_scan(a, policy, COSTS, window=window,
                   rng=np.random.default_rng(r)).cost
        for r in range(runs)
    ])
    assert jit_mean == pytest.approx(ref_mean, rel=0.02)


@pytest.mark.parametrize("policy,cls", [("A2", A2Randomized), ("A3", A3Randomized)])
@pytest.mark.parametrize("window", [0, 2, 4])
def test_randomized_jax_matches_event_simulator_in_expectation(policy, cls, window):
    """Jitted A2/A3 match core/online.py brick-simulator costs in expectation.

    The fluid (slot) and brick (continuous) models differ by a fixed
    discretization factor; deterministic A1 measures it exactly, and the
    randomized policies must sit at the same factor within sampling noise.
    """
    rng = np.random.default_rng(1)
    a = rng.integers(0, 6, size=80)
    n = int(a.max()) + 1
    alpha = min(1.0, (window + 1) / COSTS.delta)
    tr = brick_trace_from_fluid(a)

    calibration = (
        fluid_scan(a, "A1", COSTS, window=window).cost
        / simulate(tr, A1Deterministic(alpha=alpha), COSTS).cost
    )
    runs = 300
    ab = jnp.asarray(np.tile(a, (runs, 1)), jnp.int32)
    costs = provision_sweep_costs(
        ab, n_levels=n, delta=B, windows=jnp.array([window]), policy=policy,
        key=jax.random.key(3),
        P=COSTS.P, beta_on=COSTS.beta_on, beta_off=COSTS.beta_off,
    )
    jit_mean = float(jnp.mean(costs[0]))
    brick_mean = np.mean([
        simulate(tr, cls(alpha=alpha), COSTS, rng=np.random.default_rng(r)).cost
        for r in range(150)
    ])
    assert jit_mean / brick_mean == pytest.approx(calibration, rel=0.05)


@pytest.mark.parametrize("policy", ["A1", "A3", "delayedoff", "offline"])
def test_batched_matches_unbatched(policy):
    """(B, T) demand == stacking per-trace (T,) schedules (split keys)."""
    rng = np.random.default_rng(2)
    n_traces = 5
    ab = jnp.asarray(rng.integers(0, 7, size=(n_traces, 60)), jnp.int32)
    key = jax.random.key(11)
    kw = dict(n_levels=7, delta=B, window=2, policy=policy)
    if policy in ("A2", "A3"):
        kw["key"] = key
    xb = provision_schedule(ab, **kw)
    keys = jax.random.split(key, n_traces)
    for i in range(n_traces):
        if policy in ("A2", "A3"):
            kw["key"] = keys[i]
        xi = provision_schedule(ab[i], **kw)
        np.testing.assert_array_equal(np.asarray(xb[i]), np.asarray(xi))


def test_sweep_matches_individual_windows():
    """provision_sweep over W windows == W separate A1 schedules."""
    a = jnp.asarray(msr_like_trace(np.random.default_rng(5), n_slots=200,
                                   mean_jobs=10.0), jnp.int32)
    n = int(a.max()) + 1
    xs = provision_sweep(a, n_levels=n, delta=B, windows=jnp.arange(B),
                         policy="A1")
    for w in range(B):
        want = provision_schedule(a, n_levels=n, delta=B, window=w, policy="A1")
        np.testing.assert_array_equal(np.asarray(xs[w]), np.asarray(want))


def test_sweep_matches_single_schedule_randomized():
    """For a (T,) trace, sweep and single-window calls share the key stream."""
    rng = np.random.default_rng(14)
    a = jnp.asarray(rng.integers(0, 6, size=60), jnp.int32)
    key = jax.random.key(21)
    xs = provision_sweep(a, n_levels=6, delta=B, windows=jnp.arange(3),
                         policy="A3", key=key)
    for w in range(3):
        want = provision_schedule(a, n_levels=6, delta=B, window=w,
                                  policy="A3", key=key)
        np.testing.assert_array_equal(np.asarray(xs[w]), np.asarray(want))


def test_randomized_requires_key():
    a = jnp.zeros((10,), jnp.int32)
    with pytest.raises(ValueError, match="randomized"):
        provision_schedule(a, n_levels=4, delta=B, policy="A2")


def test_delayedoff_jax_matches_numpy_scan():
    rng = np.random.default_rng(6)
    a = rng.integers(0, 8, size=80)
    want = fluid_scan(a, "delayedoff", COSTS)
    got = provision_schedule(jnp.asarray(a, jnp.int32),
                             n_levels=int(a.max()) + 1, delta=B,
                             policy="delayedoff")
    np.testing.assert_array_equal(np.asarray(got), want.x)


@pytest.mark.parametrize("window", [0, 2, 5])
def test_pallas_scan_matches_scan_engine(window):
    """Fused Pallas kernel (interpret mode) == lax.scan engine, exactly."""
    rng = np.random.default_rng(8)
    a = rng.integers(0, 9, size=90)
    n = int(a.max()) + 1
    aj = jnp.asarray(a, jnp.int32)
    horizon = int(min(window + 1, B))
    # deterministic thresholds (A1)
    m = max(0.0, B - window - 1)
    want = _level_schedule(aj, n, B, window, "A1")
    got = provision_scan(aj, jnp.full((n,), m, jnp.float32), delta=B,
                         horizon=horizon)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # sampled wait table (A2) — same table through both paths
    key = jax.random.key(9)
    u0, u = _uniforms(key, len(a), n)
    waits = _waits_from_uniforms("A2", u0, u, window, B)
    want = _level_schedule(aj, n, B, window, "A2", key=key)
    got = provision_scan(aj, waits, delta=B, horizon=horizon)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sharded_randomized_matches_unsharded():
    """Sharded Pallas path (1 device => same key stream) == jitted engine."""
    rng = np.random.default_rng(10)
    a = jnp.asarray(rng.integers(0, 6, size=70), jnp.int32)
    n = 6
    key = jax.random.key(12)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    if len(jax.devices()) > 1:
        pytest.skip("key-stream equality only holds unsharded")
    got = provision_schedule_sharded(mesh, a, n_levels=n, delta=B, window=2,
                                     policy="A3", key=key)
    want = provision_schedule(a, n_levels=n, delta=B, window=2, policy="A3",
                              key=key)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_cost_matches_per_trace_cost():
    rng = np.random.default_rng(13)
    ab = rng.integers(0, 6, size=(4, 50))
    ons = np.stack([
        np.asarray(_level_schedule(jnp.asarray(ai, jnp.int32), 6, B, 1, "A1"))
        for ai in ab
    ])
    batched = provision_cost(jnp.asarray(ab), jnp.asarray(ons),
                             COSTS.P, COSTS.beta_on, COSTS.beta_off)
    for i in range(4):
        single = provision_cost(jnp.asarray(ab[i]), jnp.asarray(ons[i]),
                                COSTS.P, COSTS.beta_on, COSTS.beta_off)
        assert float(batched[i]) == pytest.approx(float(single))


def test_sharded_fleet_matches_single_device():
    """shard_map level-sharded provisioning == single-device result."""
    a = msr_like_trace(np.random.default_rng(2), n_slots=200, mean_jobs=20.0)
    n = int(a.max()) + 1
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    got = provision_schedule_sharded(
        mesh, jnp.asarray(a, jnp.int32), n_levels=n, delta=B, window=2
    )
    want = provision_schedule(
        jnp.asarray(a, jnp.int32), n_levels=n, delta=B, window=2, policy="A1"
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
