"""Declarative JAX provisioning engine == numpy reference engines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    A2Randomized,
    A3Randomized,
    A1Deterministic,
    CostModel,
    PolicySpec,
    ProvisionSpec,
    Workload,
    brick_trace_from_fluid,
    fluid_cost,
    fluid_scan,
    msr_like_trace,
    on_matrix_cost,
    provision,
    simulate,
)
from repro.core.jax_provision import (
    _level_schedule,
    _uniforms,
    _waits_from_uniforms,
)
from repro.core.traces import with_prediction_error
from repro.kernels.provision_scan import provision_scan

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
B = int(COSTS.delta)


def run(a, *, policy="A1", window=0, windows=None, predicted=None, key=None,
        costs=COSTS, n_levels=None, mesh=None, use_pallas=True):
    return provision(ProvisionSpec(
        costs=costs,
        workload=Workload(
            demand=jnp.asarray(a, jnp.int32),
            predicted=None if predicted is None else jnp.asarray(predicted, jnp.int32),
        ),
        policy=PolicySpec(policy, window=window, windows=windows, key=key),
        n_levels=n_levels if n_levels is not None else int(np.asarray(a).max()) + 1,
        mesh=mesh,
        use_pallas=use_pallas,
    ))


@pytest.mark.parametrize("window", [0, 1, 3, 5, 8])
@pytest.mark.parametrize("seed", range(4))
def test_a1_jax_matches_numpy_scan(window, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 8, size=60)
    want = fluid_scan(a, "A1", COSTS, window=window)
    got = run(a, window=window, policy="A1")
    np.testing.assert_array_equal(np.asarray(got.x), want.x)


@pytest.mark.parametrize("seed", range(4))
def test_offline_jax_matches_optimal_cost(seed):
    rng = np.random.default_rng(seed + 100)
    a = rng.integers(0, 6, size=50)
    res = run(a, policy="offline")
    want = fluid_cost(a, "offline", COSTS).cost
    assert float(res.cost) == pytest.approx(want, rel=1e-6)


def test_a1_jax_cost_matches_numpy_cost():
    a = msr_like_trace(np.random.default_rng(1), n_slots=300, mean_jobs=15.0)
    for w in (0, 2, 5):
        res = run(a, window=w, policy="A1")
        want = fluid_scan(a, "A1", COSTS, window=w).cost
        assert float(res.cost) == pytest.approx(want, rel=1e-6)
        # result invariants: cost decomposes over levels and into components
        assert float(res.level_cost.sum()) == pytest.approx(float(res.cost))
        assert float(res.energy + res.toggle_cost) == pytest.approx(float(res.cost))


# ---------------------------------------------------------------------------
# Batched multi-policy engine: A2/A3, batching, sweep, Pallas scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["A2", "A3"])
@pytest.mark.parametrize("window", [0, 2, 4])
def test_randomized_jax_matches_fluid_scan_in_expectation(policy, window):
    """Jitted A2/A3 mean cost over keys == numpy slot-scan mean over seeds."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 6, size=60)
    runs = 300
    ab = np.tile(a, (runs, 1))
    costs = run(ab, policy=policy, windows=jnp.array([window]),
                key=jax.random.key(7), n_levels=int(a.max()) + 1).cost
    jit_mean = float(jnp.mean(costs[0]))
    ref_mean = np.mean([
        fluid_scan(a, policy, COSTS, window=window,
                   rng=np.random.default_rng(r)).cost
        for r in range(runs)
    ])
    assert jit_mean == pytest.approx(ref_mean, rel=0.02)


@pytest.mark.parametrize("policy,cls", [("A2", A2Randomized), ("A3", A3Randomized)])
@pytest.mark.parametrize("window", [0, 2, 4])
def test_randomized_jax_matches_event_simulator_in_expectation(policy, cls, window):
    """Jitted A2/A3 match core/online.py brick-simulator costs in expectation.

    The fluid (slot) and brick (continuous) models differ by a fixed
    discretization factor; deterministic A1 measures it exactly, and the
    randomized policies must sit at the same factor within sampling noise.
    """
    rng = np.random.default_rng(1)
    a = rng.integers(0, 6, size=80)
    alpha = min(1.0, (window + 1) / COSTS.delta)
    tr = brick_trace_from_fluid(a)

    calibration = (
        fluid_scan(a, "A1", COSTS, window=window).cost
        / simulate(tr, A1Deterministic(alpha=alpha), COSTS).cost
    )
    runs = 300
    ab = np.tile(a, (runs, 1))
    costs = run(ab, policy=policy, windows=jnp.array([window]),
                key=jax.random.key(3), n_levels=int(a.max()) + 1).cost
    jit_mean = float(jnp.mean(costs[0]))
    brick_mean = np.mean([
        simulate(tr, cls(alpha=alpha), COSTS, rng=np.random.default_rng(r)).cost
        for r in range(150)
    ])
    assert jit_mean / brick_mean == pytest.approx(calibration, rel=0.05)


@pytest.mark.parametrize("policy", ["A1", "A3", "delayedoff", "offline"])
def test_batched_matches_unbatched(policy):
    """(B, T) demand == stacking per-trace (T,) schedules (split keys)."""
    rng = np.random.default_rng(2)
    n_traces = 5
    ab = rng.integers(0, 7, size=(n_traces, 60))
    key = jax.random.key(11)
    kw = dict(n_levels=7, window=2, policy=policy)
    xb = run(ab, **kw, key=key if policy in ("A2", "A3") else None).x
    keys = jax.random.split(key, n_traces)
    for i in range(n_traces):
        ki = keys[i] if policy in ("A2", "A3") else None
        xi = run(ab[i], **kw, key=ki).x
        np.testing.assert_array_equal(np.asarray(xb[i]), np.asarray(xi))


def test_sweep_matches_individual_windows():
    """One windows= sweep == W separate single-window A1 programs."""
    a = msr_like_trace(np.random.default_rng(5), n_slots=200, mean_jobs=10.0)
    xs = run(a, windows=jnp.arange(B), policy="A1").x
    for w in range(B):
        want = run(a, window=w, policy="A1").x
        np.testing.assert_array_equal(np.asarray(xs[w]), np.asarray(want))


def test_sweep_matches_single_schedule_randomized():
    """For a (T,) trace, sweep and single-window calls share the key stream."""
    rng = np.random.default_rng(14)
    a = rng.integers(0, 6, size=60)
    key = jax.random.key(21)
    xs = run(a, windows=jnp.arange(3), policy="A3", key=key, n_levels=6).x
    for w in range(3):
        want = run(a, window=w, policy="A3", key=key, n_levels=6).x
        np.testing.assert_array_equal(np.asarray(xs[w]), np.asarray(want))


def test_randomized_requires_key():
    a = np.zeros((10,), np.int64)
    with pytest.raises(ValueError, match="randomized"):
        run(a, policy="A2", n_levels=4)


def test_unknown_policy_names_valid_set():
    a = np.zeros((10,), np.int64)
    with pytest.raises(ValueError, match="A1.*A2.*A3.*offline.*delayedoff"):
        run(a, policy="A9", n_levels=4)
    with pytest.raises(ValueError, match="valid policies"):
        _level_schedule(jnp.zeros((10,), jnp.int32), 4, B, 0, "a1")


def test_delayedoff_jax_matches_numpy_scan():
    rng = np.random.default_rng(6)
    a = rng.integers(0, 8, size=80)
    want = fluid_scan(a, "delayedoff", COSTS)
    got = run(a, policy="delayedoff")
    np.testing.assert_array_equal(np.asarray(got.x), want.x)


@pytest.mark.parametrize("window", [0, 2, 5])
def test_pallas_scan_matches_scan_engine(window):
    """Fused Pallas kernel (interpret mode) == lax.scan engine, exactly."""
    rng = np.random.default_rng(8)
    a = rng.integers(0, 9, size=90)
    n = int(a.max()) + 1
    aj = jnp.asarray(a, jnp.int32)
    horizon = int(min(window + 1, B))
    # deterministic thresholds (A1)
    m = max(0.0, B - window - 1)
    want = _level_schedule(aj, n, B, window, "A1")
    got = provision_scan(aj, jnp.full((n,), m, jnp.float32), delta=B,
                         horizon=horizon)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # sampled wait table (A2) — same table through both paths
    key = jax.random.key(9)
    u0, u = _uniforms(key, len(a), n)
    waits = _waits_from_uniforms("A2", u0, u, window, B)
    want = _level_schedule(aj, n, B, window, "A2", key=key)
    got = provision_scan(aj, waits, delta=B, horizon=horizon)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_scan_distinct_prediction_trace():
    """The kernel's peek reads the scalar-prefetched predicted trace, not a."""
    rng = np.random.default_rng(15)
    a = rng.integers(0, 8, size=100)
    pred = with_prediction_error(a, rng, 0.4)
    assert not np.array_equal(pred, a)
    n = int(max(a.max(), pred.max())) + 1
    w = 2
    aj = jnp.asarray(a, jnp.int32)
    pj = jnp.asarray(pred, jnp.int32)
    want = _level_schedule(aj, n, B, w, "A1", predicted=pj)
    got = provision_scan(aj, jnp.full((n,), float(B - w - 1), jnp.float32),
                         delta=B, horizon=w + 1, predicted=pj)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and erroneous predictions must actually change the schedule somewhere
    exact = provision_scan(aj, jnp.full((n,), float(B - w - 1), jnp.float32),
                           delta=B, horizon=w + 1)
    assert not np.array_equal(np.asarray(got), np.asarray(exact))


@pytest.mark.parametrize("window", [0, 1, 2, 3])
def test_pallas_scan_fractional_delta_peek_boundary(window):
    """Per-level Δ_l ∈ {2.5, 3.0}: the kernel's fractional peek mask
    (``float(h) < Δ_l``) must agree with the engine at the boundary where
    the unrolled slot index straddles a non-integer horizon (h = 2 is
    peeked under Δ=2.5 iff the horizon row says 2.0 < 2.5, but h = 3 is
    not), and the A1 thresholds clip at zero (Δ − w − 1 < 0)."""
    rng = np.random.default_rng(21)
    a = rng.integers(0, 9, size=80)
    n = int(a.max()) + 1
    delta_lv = np.where(np.arange(n) % 2 == 0, 2.5, 3.0)
    max_h = 3                                    # ceil(max Δ)
    aj = jnp.asarray(a, jnp.int32)
    want = _level_schedule(aj, n, delta_lv, window, "A1")
    thr = jnp.asarray(np.maximum(0.0, delta_lv - window - 1), jnp.float32)
    lh = jnp.asarray(np.minimum(window + 1.0, delta_lv), jnp.float32)
    got = provision_scan(aj, thr, delta=max_h, horizon=min(window + 1, max_h),
                         level_horizon=lh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("policy", ["A2", "A3"])
def test_pallas_scan_wait_consumed_at_first_idle_slot(policy):
    """A trace that goes idle at the first scan step: the kernel's
    first-newly-idle wait consumption (``idle & (r == 0.0)``) must pick up
    the slot-1 table row exactly like the engine — including levels that
    were never busy (they never consume a draw) and a level re-idling
    after a later busy burst (fresh draw, not the stale one)."""
    a = np.zeros(40, np.int64)
    a[0] = 5                   # levels 0-4 on at t=0, all newly idle at t=1
    a[20:23] = 3               # levels 0-2 busy again, re-idle at t=23
    n = 6                      # level 5 never turns on at all
    window = 1
    key = jax.random.key(33)
    aj = jnp.asarray(a, jnp.int32)
    u0, u = _uniforms(key, len(a), n)
    waits = _waits_from_uniforms(policy, u0, u, window, B)
    want = _level_schedule(aj, n, B, window, policy, key=key)
    got = provision_scan(aj, waits, delta=B, horizon=window + 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the schedule must actually exercise the idle path (turn-offs happen)
    assert np.asarray(want)[:, :5].sum() < 5 * len(a)


def test_pallas_scan_heterogeneous_per_level_horizon():
    """Per-level Δ: thresholds AND peek reach vary per level, masked in-kernel."""
    rng = np.random.default_rng(16)
    a = rng.integers(0, 9, size=90)
    n = int(a.max()) + 1
    w = 2
    delta_lv = np.where(np.arange(n) % 2 == 0, 6.0, 3.0)
    aj = jnp.asarray(a, jnp.int32)
    want = _level_schedule(aj, n, delta_lv, w, "A1")
    thr = jnp.asarray(np.maximum(0.0, delta_lv - w - 1), jnp.float32)
    lh = jnp.asarray(np.minimum(w + 1.0, delta_lv), jnp.float32)
    got = provision_scan(aj, thr, delta=6, horizon=w + 1, level_horizon=lh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sharded_randomized_matches_unsharded():
    """Sharded Pallas path (1 device => same key stream) == jitted engine."""
    rng = np.random.default_rng(10)
    a = rng.integers(0, 6, size=70)
    key = jax.random.key(12)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    if len(jax.devices()) > 1:
        pytest.skip("key-stream equality only holds unsharded")
    got = run(a, window=2, policy="A3", key=key, n_levels=6, mesh=mesh)
    want = run(a, window=2, policy="A3", key=key, n_levels=6)
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    np.testing.assert_allclose(np.asarray(got.level_cost),
                               np.asarray(want.level_cost), rtol=1e-6)


def test_sharded_path_consumes_predicted_trace():
    """The shard_map/Pallas fleet path peeks an erroneous prediction trace
    and matches the lax.scan engine bit-exactly (the old sharded API
    silently dropped ``predicted``)."""
    rng = np.random.default_rng(11)
    a = msr_like_trace(rng, n_slots=150, mean_jobs=12.0)
    pred = with_prediction_error(a, rng, 0.3)
    n = int(max(a.max(), pred.max())) + 1
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    for use_pallas in (True, False):
        got = run(a, window=2, predicted=pred, n_levels=n, mesh=mesh,
                  use_pallas=use_pallas)
        want = run(a, window=2, predicted=pred, n_levels=n)
        np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    # the noisy prediction must differ from the exact-prediction schedule
    exact = run(a, window=2, n_levels=n)
    assert not np.array_equal(np.asarray(got.x), np.asarray(exact.x))


def test_batched_cost_matches_per_trace_cost():
    rng = np.random.default_rng(13)
    ab = rng.integers(0, 6, size=(4, 50))
    ons = np.stack([
        np.asarray(_level_schedule(jnp.asarray(ai, jnp.int32), 6, B, 1, "A1"))
        for ai in ab
    ])
    batched = on_matrix_cost(jnp.asarray(ab), jnp.asarray(ons), COSTS)
    for i in range(4):
        single = on_matrix_cost(jnp.asarray(ab[i]), jnp.asarray(ons[i]), COSTS)
        assert float(batched[i]) == pytest.approx(float(single))


def test_sharded_fleet_matches_single_device():
    """shard_map level-sharded provisioning == single-device result."""
    a = msr_like_trace(np.random.default_rng(2), n_slots=200, mean_jobs=20.0)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    got = run(a, window=2, mesh=mesh)
    want = run(a, window=2, policy="A1")
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    np.testing.assert_allclose(np.asarray(got.level_cost),
                               np.asarray(want.level_cost), rtol=1e-6)


def test_sharded_multi_device_padding_masked():
    """4 forced host devices, n_levels not divisible: the padded phantom
    levels must not inflate x(t) when demand exceeds the fleet cap."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
)
import numpy as np, jax, jax.numpy as jnp
from repro.core import PAPER_COSTS, PolicySpec, ProvisionSpec, Workload, provision
assert len(jax.devices()) == 4, jax.devices()
rng = np.random.default_rng(0)
a = rng.integers(0, 11, size=80)          # peak demand above the fleet cap
n = 6                                      # n_padded = 8 -> 2 phantom levels
mesh = jax.make_mesh((4,), ("data",))
def spec(mesh=None):
    return ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=jnp.asarray(a, jnp.int32)),
        policy=PolicySpec("A1", window=2), n_levels=n, mesh=mesh)
got = provision(spec(mesh))
want = provision(spec())
np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
np.testing.assert_allclose(np.asarray(got.level_cost),
                           np.asarray(want.level_cost), rtol=1e-6)
# randomized: uniforms drawn at n_levels, so the (trace, key) -> schedule
# contract must hold across mesh sizes too
def spec3(mesh=None):
    return ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=jnp.asarray(a, jnp.int32)),
        policy=PolicySpec("A3", window=2, key=jax.random.key(12)),
        n_levels=n, mesh=mesh)
np.testing.assert_array_equal(np.asarray(provision(spec3(mesh)).x),
                              np.asarray(provision(spec3()).x))
# the full (S, W, B) grid across 4 real shards: the psum / tiled
# all_gather / per-shard base offsets must reassemble the level axis in
# order (a 1-device mesh makes every collective a no-op, so only this
# forced-multi-device run exercises them)
from repro.core import PredictionNoise
ab = rng.integers(0, 11, size=(2, 60))
def spec_grid(mesh=None, use_pallas=True):
    return ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(
            demand=jnp.asarray(ab, jnp.int32),
            noise=PredictionNoise(jnp.asarray([0.0, 0.3]), jax.random.key(7))),
        policy=PolicySpec("A3", windows=jnp.arange(3), key=jax.random.key(8)),
        n_levels=n, mesh=mesh, use_pallas=use_pallas)
want = provision(spec_grid())
for use_pallas in (True, False):
    got = provision(spec_grid(mesh, use_pallas))
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    np.testing.assert_allclose(np.asarray(got.level_cost),
                               np.asarray(want.level_cost), rtol=1e-6)
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=dict(os.environ), timeout=300)
    assert r.returncode == 0 and "OK" in r.stdout, (r.stdout, r.stderr[-2000:])


def test_mesh_rejects_offline():
    a = np.ones((30,), np.int64)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    with pytest.raises(ValueError, match="online policies"):
        run(a, policy="offline", mesh=mesh, n_levels=4)


def test_n_levels_inference_under_jit_raises_clearly():
    """Default n_levels needs concrete demand; under an outer jit/vmap the
    old ``int(ab.max())`` exploded with an opaque ConcretizationTypeError —
    now a ValueError names the fix (regression)."""
    from repro.core import PAPER_COSTS

    a = jnp.asarray(np.ones(20), jnp.int32)

    def cost(ai, n_levels=None):
        return provision(ProvisionSpec(
            costs=PAPER_COSTS,
            workload=Workload(demand=ai),
            policy=PolicySpec("A1", window=1),
            n_levels=n_levels,
        )).cost

    with pytest.raises(ValueError, match="n_levels"):
        jax.jit(cost)(a)
    with pytest.raises(ValueError, match="jit/vmap"):
        jax.vmap(lambda ai: cost(ai))(a[None])
    # explicit n_levels works under jit; a level-pinned CostModel also works
    assert float(jax.jit(lambda ai: cost(ai, n_levels=2))(a)) == \
        pytest.approx(float(cost(a, n_levels=2)))


# ---------------------------------------------------------------------------
# Batched (S, W, B) axes through the mesh/Pallas fleet path
# ---------------------------------------------------------------------------

MESH_GRID_CASES = [
    # policy, batched, windows, noise-swept
    ("A1", True, True, False),
    ("A1", True, False, True),
    ("A2", True, True, True),
    ("A3", True, True, True),
    ("A3", False, True, False),
    ("A3", False, False, True),
    ("delayedoff", True, True, True),
]


@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("policy,batched,sweep_w,sweep_s", MESH_GRID_CASES)
def test_mesh_grid_matches_unsharded(policy, batched, sweep_w, sweep_s,
                                     use_pallas):
    """The sharded fleet path accepts the full (S, W, B) grid and is
    bit-exact against the lax.scan programs on every axis combination —
    kernel and sharded-lax.scan bodies alike (common random numbers)."""
    from repro.core import PredictionNoise

    rng = np.random.default_rng(42)
    a = rng.integers(0, 7, size=(3, 50) if batched else (50,))
    n = int(a.max()) + 1
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    kw = dict(
        policy=policy,
        n_levels=n,
        key=jax.random.key(5) if policy in ("A2", "A3") else None,
        windows=jnp.arange(3) if sweep_w else None,
        window=2,
    )
    noise = (
        PredictionNoise(jnp.asarray([0.0, 0.3]), jax.random.key(6))
        if sweep_s else None
    )

    def go(**extra):
        return provision(ProvisionSpec(
            costs=COSTS,
            workload=Workload(demand=jnp.asarray(a, jnp.int32), noise=noise),
            policy=PolicySpec(kw["policy"], window=kw["window"],
                              windows=kw["windows"], key=kw["key"]),
            n_levels=n,
            **extra,
        ))

    got = go(mesh=mesh, use_pallas=use_pallas)
    want = go()
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    np.testing.assert_allclose(np.asarray(got.level_cost),
                               np.asarray(want.level_cost), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got.cost), np.asarray(want.cost),
                               rtol=1e-6)


def test_mesh_path_works_under_outer_jit():
    """provision(mesh=...) traced by an outer jit must still run (the
    static peek unroll falls back to the Δ bound when the windows values
    are tracers) and agree with the eager meshed and unmeshed results.
    ``windows`` enters as a jit *argument* so its values really are
    tracers inside the trace — pinning the fallback branch, not just the
    constant-folded path."""
    rng = np.random.default_rng(44)
    a = rng.integers(0, 6, size=50)
    n = int(a.max()) + 1
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))

    def cost(ai, ws, m=None):
        return provision(ProvisionSpec(
            costs=COSTS,
            workload=Workload(demand=ai),
            policy=PolicySpec("A1", windows=ws),
            n_levels=n,
            mesh=m,
        )).cost

    aj = jnp.asarray(a, jnp.int32)
    ws = jnp.arange(3)
    got = jax.jit(lambda ai, w: cost(ai, w, mesh))(aj, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(cost(aj, ws, mesh)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(cost(aj, ws)),
                               rtol=1e-6)


def test_mesh_grid_heterogeneous_fractional_delta():
    """(S, W, B) mesh grid with per-level Δ ∈ {2.5, 6.0} — fractional peek
    reach and per-level thresholds through the batched kernel."""
    from repro.core import PredictionNoise

    rng = np.random.default_rng(43)
    ab = rng.integers(0, 6, size=(2, 40))
    n = int(ab.max()) + 1
    half = np.where(np.arange(n) % 2 == 0, 3.0, 1.25)      # Δ 6.0 / 2.5
    costs = CostModel(P=1.0, beta_on=half, beta_off=half)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    spec = ProvisionSpec(
        costs=costs,
        workload=Workload(
            demand=jnp.asarray(ab, jnp.int32),
            noise=PredictionNoise(jnp.asarray([0.0, 0.25]), jax.random.key(1)),
        ),
        policy=PolicySpec("A1", windows=jnp.arange(3)),
        n_levels=n,
    )
    want = provision(spec)
    got = provision(dataclasses.replace(spec, mesh=mesh))
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    np.testing.assert_allclose(np.asarray(got.level_cost),
                               np.asarray(want.level_cost), rtol=1e-6)


def test_prediction_noise_workload():
    """Workload.noise synthesizes the predicted trace (Sec. V-C) on device."""
    from repro.core import PredictionNoise

    rng = np.random.default_rng(17)
    a = msr_like_trace(rng, n_slots=120, mean_jobs=10.0)
    noise = PredictionNoise(std_frac=0.5, key=jax.random.key(2))
    spec = ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=jnp.asarray(a, jnp.int32), noise=noise),
        policy=PolicySpec("A1", window=3),
        n_levels=int(a.max()) + 1,
    )
    res = provision(spec)
    # identical to passing the synthesized trace explicitly
    pred = noise.apply(jnp.asarray(a, jnp.int32))
    want = run(a, window=3, predicted=pred)
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(want.x))
    # and different from the exact-prediction schedule
    exact = run(a, window=3)
    assert not np.array_equal(np.asarray(res.x), np.asarray(exact.x))
    with pytest.raises(ValueError, match="not both"):
        provision(dataclasses.replace(
            spec, workload=Workload(jnp.asarray(a, jnp.int32), predicted=pred,
                                    noise=noise)))
    # batched noise reduces to its unbatched rows (key split per trace,
    # same convention as PolicySpec.key)
    ab = np.stack([a, a[::-1].copy()])
    bres = provision(dataclasses.replace(
        spec, workload=Workload(jnp.asarray(ab, jnp.int32), noise=noise)))
    keys = jax.random.split(noise.key, 2)
    for i in range(2):
        ri = provision(dataclasses.replace(
            spec, workload=Workload(jnp.asarray(ab[i], jnp.int32),
                                    noise=PredictionNoise(0.5, keys[i]))))
        np.testing.assert_array_equal(np.asarray(bres.x[i]), np.asarray(ri.x))


def test_predicted_shape_must_match_demand():
    ab = np.random.default_rng(18).integers(0, 5, size=(4, 25))
    with pytest.raises(ValueError, match="must match demand shape"):
        run(ab, predicted=ab.T, n_levels=5)       # same size, wrong shape
    with pytest.raises(ValueError, match="must match demand shape"):
        run(ab[0], predicted=ab[0][:-1], n_levels=5)


# ---------------------------------------------------------------------------
# Typed server groups: AQ policies + the group-aligned kernel layout
# ---------------------------------------------------------------------------

from repro.core import ServerGroup  # noqa: E402

TYPED = CostModel.from_groups(
    ServerGroup("efficient", 5, P=1.0, beta_on=2.0, beta_off=2.0),
    ServerGroup("legacy", 4, P=1.5, beta_on=4.5, beta_off=4.5),
)


def _typed_trace(seed=11, n_slots=96):
    rng = np.random.default_rng(seed)
    return np.minimum(msr_like_trace(rng, mean_jobs=4.0, n_slots=n_slots),
                      TYPED.n_levels)


def test_aq_det_is_delayedoff_on_a_single_type():
    """d = 1 AQ-det IS the paper's delayed-off: same break-even timer Δ, no
    peek — the schedules must be bit-identical."""
    a = np.random.default_rng(7).integers(0, 9, size=120)
    got, want = run(a, policy="AQ-det"), run(a, policy="delayedoff")
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    np.testing.assert_array_equal(np.asarray(got.level_cost),
                                  np.asarray(want.level_cost))


@pytest.mark.parametrize("policy", ["A1", "A3", "offline", "delayedoff",
                                    "AQ-det", "AQ-rand"])
def test_single_group_typed_model_bit_exact_vs_untyped(policy):
    """The d=1 regression gate: one ServerGroup carrying the untyped scalar
    parameters must reproduce the untyped engine bit-exactly (same PRNG
    stream included) on the lax.scan path."""
    from repro.core.jax_provision import KEYED

    a = np.random.default_rng(8).integers(0, 9, size=120)
    n = int(a.max()) + 1
    typed = CostModel.from_groups(
        ServerGroup("std", n, P=1.0, beta_on=3.0, beta_off=3.0))
    key = jax.random.key(5) if policy in KEYED else None
    got = run(a, policy=policy, key=key, costs=typed, n_levels=n)
    want = run(a, policy=policy, key=key, n_levels=n)
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    np.testing.assert_array_equal(np.asarray(got.level_cost),
                                  np.asarray(want.level_cost))
    # the typed result additionally carries the (single) group reduction
    np.testing.assert_allclose(np.asarray(got.group_cost)[..., 0],
                               np.asarray(got.cost), rtol=1e-6)
    assert want.group_cost is None


@pytest.mark.parametrize("policy", ["A1", "AQ-det", "AQ-rand"])
def test_single_group_typed_model_bit_exact_on_fleet_path(policy):
    """Same d=1 gate through the sharded Pallas fleet path (the group-
    aligned routed kernel layout vs the contiguous one)."""
    from repro.core.jax_provision import KEYED

    a = np.random.default_rng(9).integers(0, 9, size=96)
    n = int(a.max()) + 1
    typed = CostModel.from_groups(
        ServerGroup("std", n, P=1.0, beta_on=3.0, beta_off=3.0))
    key = jax.random.key(6) if policy in KEYED else None
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    got = run(a, policy=policy, window=2, key=key, costs=typed, n_levels=n,
              mesh=mesh)
    want = run(a, policy=policy, window=2, key=key, n_levels=n, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    np.testing.assert_array_equal(np.asarray(got.level_cost),
                                  np.asarray(want.level_cost))


@pytest.mark.parametrize("policy", ["A1", "AQ-det", "AQ-rand"])
@pytest.mark.parametrize("use_pallas", [True, False])
def test_multi_type_fleet_path_matches_unsharded(policy, use_pallas):
    """Multi-type parity: the sharded fleet path (Pallas routed kernel and
    the sharded lax.scan body) must reproduce the unsharded engine on a
    genuinely heterogeneous d=2 fleet, group_cost included."""
    from repro.core.jax_provision import KEYED

    ab = np.stack([_typed_trace(s) for s in (11, 12)])
    key = jax.random.key(3) if policy in KEYED else None
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    plain = run(ab, policy=policy, window=2, key=key, costs=TYPED,
                n_levels=TYPED.n_levels)
    fleet = run(ab, policy=policy, window=2, key=key, costs=TYPED,
                n_levels=TYPED.n_levels, mesh=mesh, use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(plain.x), np.asarray(fleet.x))
    np.testing.assert_array_equal(np.asarray(plain.level_cost),
                                  np.asarray(fleet.level_cost))
    np.testing.assert_allclose(np.asarray(plain.group_cost),
                               np.asarray(fleet.group_cost), rtol=1e-6)
    # group_cost is the exact per-group reduction of level_cost
    np.testing.assert_allclose(
        np.asarray(plain.group_cost).sum(axis=-1), np.asarray(plain.cost),
        rtol=1e-6)


def test_aq_rand_respects_per_type_bound_in_expectation():
    """AQ-rand's per-type guarantee: over PRNG replicas, each type's mean
    cost stays within e/(e−1) of that type's offline share (plus sampling
    slack) — the randomized full-span waits are doing their job."""
    import math

    a = _typed_trace(21, n_slots=288)
    opt = run(a, policy="offline", costs=TYPED, n_levels=TYPED.n_levels)
    opt_group = np.asarray(opt.group_cost, np.float64)
    reps = [
        np.asarray(run(a, policy="AQ-rand", key=jax.random.key(s),
                       costs=TYPED, n_levels=TYPED.n_levels).group_cost,
                   np.float64)
        for s in range(12)
    ]
    mean_group = np.mean(reps, axis=0)
    bound = math.e / (math.e - 1.0)
    assert (mean_group <= opt_group * (bound + 0.15)).all(), (
        f"per-type mean cost {mean_group} vs offline {opt_group}")
