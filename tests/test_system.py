"""End-to-end behaviour tests: the paper's headline experimental claims."""
import numpy as np
import pytest

from repro.core import (
    CostModel,
    fluid_cost,
    fluid_scan,
    msr_like_trace,
    pmr,
    scale_to_pmr,
    with_prediction_error,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)  # paper: Delta = 6 slots


@pytest.fixture(scope="module")
def trace():
    return msr_like_trace(np.random.default_rng(0))


def test_trace_matches_paper_statistics(trace):
    """One week of 10-minute slots, PMR ~ 4.63 (paper Section V-A)."""
    assert len(trace) == 1008
    assert 4.2 <= pmr(trace) <= 5.1


def test_cost_reduction_beyond_66_percent_with_zero_future_info(trace):
    """Paper Sec. V-B: >66% reduction vs static provisioning even at window 0."""
    static = fluid_cost(trace, "static", COSTS).cost
    for policy in ("A1", "A2", "A3"):
        c = fluid_cost(trace, policy, COSTS, window=0,
                       rng=np.random.default_rng(1)).cost
        assert 1.0 - c / static > 0.60, f"{policy}: {(1.0 - c / static):.3f}"


def test_reduction_grows_with_window_and_reaches_optimal(trace):
    """Fig. 4b: linear growth to the optimum at window Delta - 1."""
    static = fluid_cost(trace, "static", COSTS).cost
    opt = fluid_cost(trace, "offline", COSTS).cost
    prev = -1.0
    for w in range(0, 6):
        c = fluid_cost(trace, "A1", COSTS, window=w).cost
        red = 1.0 - c / static
        assert red >= prev - 1e-12
        prev = red
    assert fluid_cost(trace, "A1", COSTS, window=5).cost == pytest.approx(opt)


def test_ordering_offline_best_then_a3_a2_a1(trace):
    """Expected ranking at intermediate window sizes (in expectation)."""
    opt = fluid_cost(trace, "offline", COSTS).cost
    runs = 30
    means = {}
    for name in ("A1", "A2", "A3"):
        tot = sum(
            fluid_cost(trace, name, COSTS, window=2,
                       rng=np.random.default_rng(r)).cost
            for r in range(runs)
        )
        means[name] = tot / runs
    assert opt <= min(means.values()) + 1e-9


def test_robust_to_prediction_error(trace):
    """Fig. 4c: performance degrades gracefully with 50% Gaussian error."""
    static = fluid_cost(trace, "static", COSTS).cost
    exact = fluid_scan(trace, "A1", COSTS, window=4).cost
    rng = np.random.default_rng(5)
    noisy_costs = []
    for _ in range(10):
        pred = with_prediction_error(trace, rng, 0.5)
        noisy_costs.append(fluid_scan(trace, "A1", COSTS, window=4,
                                      predicted=pred).cost)
    noisy = float(np.mean(noisy_costs))
    assert 1.0 - noisy / static > 0.55
    assert noisy >= exact - 1e-9 or abs(noisy - exact) / exact < 0.1


def test_pmr_sweep_monotone_savings():
    """Fig. 4d: higher PMR -> larger savings from dynamic provisioning."""
    base = msr_like_trace(np.random.default_rng(2), mean_jobs=40.0)
    reductions = []
    for target in (2.0, 4.0, 7.0, 10.0):
        a = scale_to_pmr(base.astype(float), target)
        a = np.maximum(np.rint(a / a.mean() * 40.0), 0).astype(np.int64)
        static = fluid_cost(a, "static", COSTS).cost
        c = fluid_cost(a, "offline", COSTS).cost
        reductions.append(1.0 - c / static)
    assert all(b >= a - 0.02 for a, b in zip(reductions, reductions[1:])), reductions
    assert reductions[0] > 0.25 and reductions[-1] > 0.7
