"""Serving cluster + autoscaler integration tests."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import A1Deterministic, CostModel, a0_cost, simulate
from repro.data.requests import generate_sessions
from repro.models import init_params
from repro.serving import (
    FleetProvisioner,
    InferenceEngine,
    make_window_max_predictor,
    replica_cost_model,
    run_cluster,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


@pytest.fixture(scope="module")
def session_trace():
    return generate_sessions(np.random.default_rng(0), n_slots=120,
                             mean_concurrency=6.0)


def test_autoscaler_a1_zero_info_matches_brick_simulator(session_trace):
    """The live autoscaler (alpha=0) must equal the validated brick simulator."""
    brick = session_trace.to_brick()
    want = simulate(brick, A1Deterministic(alpha=0.0), COSTS).cost
    rep = run_cluster(session_trace, COSTS, policy="A1", alpha=0.0)
    assert rep.total_cost == pytest.approx(want, rel=1e-6)


def test_autoscaler_with_window_matches_brick_simulator(session_trace):
    """With a perfect predictor, the LIFO-depth peek == the matched-pop peek."""
    brick = session_trace.to_brick()
    for alpha in (0.5, 1.0):
        want = simulate(brick, A1Deterministic(alpha=alpha), COSTS).cost
        pred = make_window_max_predictor(session_trace)
        rep = run_cluster(session_trace, COSTS, policy="A1", alpha=alpha,
                          predictor=pred)
        assert rep.total_cost == pytest.approx(want, rel=1e-6), alpha


def test_autoscaler_respects_competitive_bound(session_trace):
    brick = session_trace.to_brick()
    opt = a0_cost(brick, COSTS)
    for alpha in (0.0, 0.5, 1.0):
        pred = make_window_max_predictor(session_trace)
        rep = run_cluster(session_trace, COSTS, policy="A1", alpha=alpha,
                          predictor=pred)
        slack = COSTS.delta * 3  # horizon-truncation slack
        assert rep.total_cost <= (2 - alpha) * opt + slack


def test_cluster_saves_energy_vs_static(session_trace):
    rep = run_cluster(session_trace, COSTS, policy="A1", alpha=0.0)
    assert rep.reduction > 0.3, rep


def test_end_to_end_generation_with_autoscaler():
    """Real prefill/decode on pinned replicas while the autoscaler runs."""
    trace = generate_sessions(np.random.default_rng(3), n_slots=30,
                              mean_concurrency=2.0)
    cfg = get_config("llama3.2-1b", reduced=True).replace(remat="none")
    import jax

    params = init_params(cfg, jax.random.key(0))

    def factory():
        return InferenceEngine(cfg, params, max_batch=1, max_seq=64)

    rep = run_cluster(trace, COSTS, policy="A1", alpha=0.0,
                      engine_factory=factory)
    assert rep.tokens_generated > 0
    assert rep.sessions_served == len(trace.sessions)


def test_fleet_provisioner_matches_fluid_scan():
    """The slot planner (batched jitted engine) == the numpy slot engine."""
    from repro.core import fluid_scan, msr_like_trace

    a = msr_like_trace(np.random.default_rng(5), n_slots=150, mean_jobs=8.0)
    planner = FleetProvisioner(COSTS, policy="A1", window=2,
                              max_replicas=int(a.max()) + 1)
    res = planner.plan(a)
    want = fluid_scan(a, "A1", COSTS, window=2)
    np.testing.assert_array_equal(np.asarray(res.x), want.x)
    assert float(res.cost) == pytest.approx(want.cost, rel=1e-6)


def test_fleet_provisioner_batched_sweep_shapes():
    import jax

    from repro.core import msr_like_trace

    traces = np.stack([
        msr_like_trace(np.random.default_rng(s), n_slots=100, mean_jobs=6.0)
        for s in range(3)
    ])
    planner = FleetProvisioner(COSTS, policy="A3",
                              max_replicas=int(traces.max()) + 1,
                              key=jax.random.key(0))
    windows = np.arange(4)
    xs = planner.plan_sweep(traces, windows)
    assert xs.shape == (4, 3, 100)
    costs = planner.sweep_costs(traces, windows)
    assert costs.shape == (4, 3)
    # every schedule covers demand
    assert (xs >= traces[None]).all()
    # more future info never costs more in expectation-free A1 terms; for A3
    # just check costs are positive and finite
    assert np.isfinite(costs).all() and (costs > 0).all()


def test_fleet_provisioner_requires_key_for_randomized():
    with pytest.raises(ValueError, match="randomized"):
        FleetProvisioner(COSTS, policy="A2")


def test_replica_cost_model_sane():
    cm = replica_cost_model(weights_bytes_per_device=8e9, n_chips=16)
    assert cm.beta_on > 0 and cm.beta_off > 0
    assert 0.1 < cm.delta < 100
