"""Eval harness: grid validation, report integrity + JSON round-trip, paper
bounds on the smoke grid, warmed-program reuse, and the PredictionNoise
(S,) sweep axis it consumes (scalar-row reduction, common random numbers)."""
import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    PAPER_COSTS,
    CostModel,
    PolicySpec,
    PredictionNoise,
    ProvisionSpec,
    Workload,
    provision,
)
from repro.eval import SCHEMA, EvalGrid, EvalReport, evaluate
from repro.scenarios import Scenario

SMALL = EvalGrid(
    policies=("A1", "A3"),
    scenarios=(
        Scenario("sinusoidal", target_pmr=4.0, mean_jobs=16.0),
        Scenario("step_outage", target_pmr=4.0, mean_jobs=16.0),
    ),
    noise_stds=(0.0, 0.2),
    windows=(0, 3),
    n_traces=3,
    n_slots=144,
)


@pytest.fixture(scope="module")
def report():
    return evaluate(SMALL)


def test_grid_validation():
    with pytest.raises(ValueError, match="homogeneous"):
        evaluate(dataclasses.replace(
            SMALL, costs=CostModel(P=1.0, beta_on=np.ones(4), beta_off=np.ones(4))
        ))
    with pytest.raises(ValueError, match="windows"):
        evaluate(dataclasses.replace(SMALL, windows=(-1,)))
    with pytest.raises(ValueError, match="noise_stds"):
        evaluate(dataclasses.replace(SMALL, noise_stds=()))


def test_report_covers_the_full_grid(report):
    assert len(report.cells) == 2 * 2 * 2 * 2      # policy x scenario x S x W
    keys = {(c.policy, c.scenario, c.noise_std, c.window) for c in report.cells}
    assert len(keys) == len(report.cells)
    for c in report.cells:
        assert c.mean_cr >= 1.0 - 1e-9             # never beats hindsight
        assert c.max_cr >= c.p95_cr >= c.mean_cr - 1e-9 or c.p95_cr >= 1.0
        assert c.bound is not None


def test_smoke_grid_respects_paper_bounds(report):
    assert report.bounds_ok, report.violations()
    for c in report.cells:
        slack = SMALL.tol + SMALL.noise_slack * c.noise_std
        assert c.mean_cr <= c.bound + slack


def test_noise_hurts_in_aggregate(report):
    """More prediction error never helps on average across the grid."""
    clean = np.mean([c.mean_cr for c in report.cells if c.noise_std == 0.0])
    noisy = np.mean([c.mean_cr for c in report.cells if c.noise_std > 0.0])
    assert noisy >= clean - 1e-6


def test_report_json_round_trip(tmp_path, report):
    p = report.save(tmp_path / "BENCH_provision.json")
    loaded = EvalReport.load(p)
    assert loaded.grid == report.grid
    assert loaded.cells == report.cells
    assert loaded.bounds_ok == report.bounds_ok
    d = json.loads(p.read_text())
    assert d["schema"] == SCHEMA
    bad = dict(d, schema="repro.eval/v0")
    with pytest.raises(ValueError, match="schema"):
        EvalReport.from_dict(bad)


def test_second_run_is_warm_and_identical(report):
    again = evaluate(SMALL)
    assert again.jit_entries_added <= 0 or again.jit_entries_added == -1
    assert again.cells == report.cells             # fully deterministic


def test_worst_orders_by_effective_slack(report):
    """worst() ranks by distance to the same threshold bound_ok used
    (bound + tol + noise_slack*std), not the raw bound."""
    worst = report.worst(len(report.cells))
    slacks = [report.threshold(c) - c.mean_cr for c in worst]
    assert slacks == sorted(slacks)
    for c in report.cells:
        assert report.threshold(c) == pytest.approx(
            c.bound + SMALL.tol + SMALL.noise_slack * c.noise_std
        )


# ---------------------------------------------------------------------------
# The PredictionNoise (S,) sweep axis (the spec axis the harness consumes)
# ---------------------------------------------------------------------------

def _demand(b=2, t=120):
    rng = np.random.default_rng(0)
    base = 20 + 15 * np.sin(np.arange(t) / 8)[None, :] + 3 * rng.standard_normal((b, t))
    return jnp.asarray(np.maximum(np.rint(base), 0), jnp.int32)


def test_noise_sweep_reduces_to_scalar_rows():
    a = _demand()
    key = jax.random.key(11)
    stds = (0.0, 0.15, 0.4)
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=a, noise=PredictionNoise(jnp.asarray(stds), key)),
        policy=PolicySpec("A1", window=2),
        n_levels=int(a.max()) + 1,
    )
    res = provision(spec)
    assert res.x.shape == (3,) + a.shape
    for i, std in enumerate(stds):
        one = provision(dataclasses.replace(
            spec,
            workload=Workload(demand=a, noise=PredictionNoise(float(std), key)),
        ))
        np.testing.assert_array_equal(np.asarray(res.x[i]), np.asarray(one.x))
        np.testing.assert_allclose(
            np.asarray(res.cost[i]), np.asarray(one.cost), rtol=1e-6
        )


def test_noise_sweep_composes_with_windows_and_randomized_policies():
    a = _demand()
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(
            demand=a, noise=PredictionNoise(jnp.asarray([0.0, 0.3]), jax.random.key(0))
        ),
        policy=PolicySpec("A3", windows=jnp.arange(4), key=jax.random.key(1)),
        n_levels=int(a.max()) + 1,
    )
    res = provision(spec)
    assert res.x.shape == (2, 4) + a.shape        # (S, W, B, T)
    assert res.cost.shape == (2, 4, a.shape[0])
    assert res.level_cost.shape == (2, 4, a.shape[0], int(a.max()) + 1)
    # common random numbers: the std-0 row with a perfect predictor equals
    # the no-noise run (same wait draws regardless of the noise sweep)
    plain = provision(dataclasses.replace(
        spec, workload=Workload(demand=a)
    ))
    np.testing.assert_array_equal(np.asarray(res.x[0]), np.asarray(plain.x))


def test_noise_sweep_through_mesh_matches_unsharded():
    """The mesh path now takes the (S,) noise sweep too (it used to raise)
    and reproduces the lax.scan rows bit-exactly."""
    a = _demand()
    noise = PredictionNoise(jnp.asarray([0.0, 0.2]), jax.random.key(0))
    mesh = jax.make_mesh((1,), ("data",))
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=a[0], noise=noise),
        policy=PolicySpec("A1", window=1),
        n_levels=int(a.max()) + 1,
        mesh=mesh,
    )
    got = provision(spec)
    want = provision(dataclasses.replace(spec, mesh=None))
    assert got.x.shape == (2, a.shape[1])
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    with pytest.raises(ValueError, match="scalar or a"):
        PredictionNoise(jnp.zeros((2, 2)), jax.random.key(0)).apply(a)


# ---------------------------------------------------------------------------
# The mesh= fleet path through the harness, and explicit bound dispatch
# ---------------------------------------------------------------------------

def test_mesh_grid_reproduces_cells(report):
    """evaluate(EvalGrid(..., mesh=...)) runs every policy cell through the
    sharded Pallas fleet path and must reproduce the lax.scan report's
    cells verbatim (bit-exact kernel parity end to end)."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    meshed = evaluate(dataclasses.replace(SMALL, mesh=mesh))
    assert meshed.cells == report.cells
    assert meshed.grid["mesh"] == {"data": len(jax.devices())}
    # the sharded lax.scan body agrees too
    unfused = evaluate(dataclasses.replace(SMALL, mesh=mesh, use_pallas=False))
    assert unfused.cells == report.cells


def test_mesh_grid_rejects_offline_policy():
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="offline"):
        evaluate(dataclasses.replace(
            SMALL, policies=("A1", "offline"), mesh=mesh))


def test_offline_and_delayedoff_cells_carry_bounds():
    """_bound dispatches on the policy name explicitly: offline cells pin
    bound 1.0 and delayedoff 2.0 — they must not silently lose their
    bounds because ``theoretical_ratio`` only knows A1/A2/A3 (regression:
    the old except-KeyError fallback was one raise-type change away from
    stripping them)."""
    from repro.eval.harness import _bound

    assert _bound("offline", 0.3) == 1.0
    assert _bound("delayedoff", 0.3) == 2.0
    assert _bound("A1", 0.5) == pytest.approx(1.5)
    assert _bound("not_a_policy", 0.5) is None

    grid = dataclasses.replace(SMALL, policies=("offline", "delayedoff"))
    rep = evaluate(grid)
    by_policy = {}
    for c in rep.cells:
        by_policy.setdefault(c.policy, set()).add(c.bound)
    assert by_policy["offline"] == {1.0}
    assert by_policy["delayedoff"] == {2.0}
    # offline IS the baseline: its CR is exactly 1 and always within bound
    for c in rep.cells:
        if c.policy == "offline":
            assert c.mean_cr == pytest.approx(1.0)
        assert c.bound_ok
