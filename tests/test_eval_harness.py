"""Eval harness: grid validation, report integrity + JSON round-trip, paper
bounds on the smoke grid, warmed-program reuse, and the PredictionNoise
(S,) sweep axis it consumes (scalar-row reduction, common random numbers)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PAPER_COSTS,
    CostModel,
    PolicySpec,
    PredictionNoise,
    ProvisionSpec,
    Workload,
    provision,
)
from repro.eval import SCHEMA, EvalGrid, EvalReport, evaluate
from repro.scenarios import Scenario

SMALL = EvalGrid(
    policies=("A1", "A3"),
    scenarios=(
        Scenario("sinusoidal", target_pmr=4.0, mean_jobs=16.0),
        Scenario("step_outage", target_pmr=4.0, mean_jobs=16.0),
    ),
    noise_stds=(0.0, 0.2),
    windows=(0, 3),
    n_traces=3,
    n_slots=144,
)


@pytest.fixture(scope="module")
def report():
    return evaluate(SMALL)


def test_grid_validation():
    with pytest.raises(ValueError, match="homogeneous"):
        evaluate(dataclasses.replace(
            SMALL, costs=CostModel(P=1.0, beta_on=np.ones(4), beta_off=np.ones(4))
        ))
    with pytest.raises(ValueError, match="windows"):
        evaluate(dataclasses.replace(SMALL, windows=(-1,)))
    with pytest.raises(ValueError, match="noise_stds"):
        evaluate(dataclasses.replace(SMALL, noise_stds=()))


def test_report_covers_the_full_grid(report):
    assert len(report.cells) == 2 * 2 * 2 * 2      # policy x scenario x S x W
    keys = {(c.policy, c.scenario, c.noise_std, c.window) for c in report.cells}
    assert len(keys) == len(report.cells)
    for c in report.cells:
        assert c.mean_cr >= 1.0 - 1e-9             # never beats hindsight
        assert c.max_cr >= c.p95_cr >= c.mean_cr - 1e-9 or c.p95_cr >= 1.0
        assert c.bound is not None


def test_smoke_grid_respects_paper_bounds(report):
    assert report.bounds_ok, report.violations()
    for c in report.cells:
        slack = SMALL.tol + SMALL.noise_slack * c.noise_std
        assert c.mean_cr <= c.bound + slack


def test_noise_hurts_in_aggregate(report):
    """More prediction error never helps on average across the grid."""
    clean = np.mean([c.mean_cr for c in report.cells if c.noise_std == 0.0])
    noisy = np.mean([c.mean_cr for c in report.cells if c.noise_std > 0.0])
    assert noisy >= clean - 1e-6


def test_report_json_round_trip(tmp_path, report):
    p = report.save(tmp_path / "BENCH_provision.json")
    loaded = EvalReport.load(p)
    assert loaded.grid == report.grid
    assert loaded.cells == report.cells
    assert loaded.bounds_ok == report.bounds_ok
    d = json.loads(p.read_text())
    assert d["schema"] == SCHEMA
    bad = dict(d, schema="repro.eval/v0")
    with pytest.raises(ValueError, match="schema"):
        EvalReport.from_dict(bad)


def test_second_run_is_warm_and_identical(report):
    again = evaluate(SMALL)
    assert again.jit_entries_added <= 0 or again.jit_entries_added == -1
    assert again.cells == report.cells             # fully deterministic


def test_worst_orders_by_effective_slack(report):
    """worst() ranks by distance to the same threshold bound_ok used
    (bound + tol + noise_slack*std), not the raw bound."""
    worst = report.worst(len(report.cells))
    slacks = [report.threshold(c) - c.mean_cr for c in worst]
    assert slacks == sorted(slacks)
    for c in report.cells:
        assert report.threshold(c) == pytest.approx(
            c.bound + SMALL.tol + SMALL.noise_slack * c.noise_std
        )


# ---------------------------------------------------------------------------
# The PredictionNoise (S,) sweep axis (the spec axis the harness consumes)
# ---------------------------------------------------------------------------

def _demand(b=2, t=120):
    rng = np.random.default_rng(0)
    base = 20 + 15 * np.sin(np.arange(t) / 8)[None, :] + 3 * rng.standard_normal((b, t))
    return jnp.asarray(np.maximum(np.rint(base), 0), jnp.int32)


def test_noise_sweep_reduces_to_scalar_rows():
    a = _demand()
    key = jax.random.key(11)
    stds = (0.0, 0.15, 0.4)
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=a, noise=PredictionNoise(jnp.asarray(stds), key)),
        policy=PolicySpec("A1", window=2),
        n_levels=int(a.max()) + 1,
    )
    res = provision(spec)
    assert res.x.shape == (3,) + a.shape
    for i, std in enumerate(stds):
        one = provision(dataclasses.replace(
            spec,
            workload=Workload(demand=a, noise=PredictionNoise(float(std), key)),
        ))
        np.testing.assert_array_equal(np.asarray(res.x[i]), np.asarray(one.x))
        np.testing.assert_allclose(
            np.asarray(res.cost[i]), np.asarray(one.cost), rtol=1e-6
        )


def test_noise_sweep_composes_with_windows_and_randomized_policies():
    a = _demand()
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(
            demand=a, noise=PredictionNoise(jnp.asarray([0.0, 0.3]), jax.random.key(0))
        ),
        policy=PolicySpec("A3", windows=jnp.arange(4), key=jax.random.key(1)),
        n_levels=int(a.max()) + 1,
    )
    res = provision(spec)
    assert res.x.shape == (2, 4) + a.shape        # (S, W, B, T)
    assert res.cost.shape == (2, 4, a.shape[0])
    assert res.level_cost.shape == (2, 4, a.shape[0], int(a.max()) + 1)
    # common random numbers: the std-0 row with a perfect predictor equals
    # the no-noise run (same wait draws regardless of the noise sweep)
    plain = provision(dataclasses.replace(
        spec, workload=Workload(demand=a)
    ))
    np.testing.assert_array_equal(np.asarray(res.x[0]), np.asarray(plain.x))


def test_noise_sweep_through_mesh_matches_unsharded():
    """The mesh path now takes the (S,) noise sweep too (it used to raise)
    and reproduces the lax.scan rows bit-exactly."""
    a = _demand()
    noise = PredictionNoise(jnp.asarray([0.0, 0.2]), jax.random.key(0))
    mesh = jax.make_mesh((1,), ("data",))
    spec = ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=a[0], noise=noise),
        policy=PolicySpec("A1", window=1),
        n_levels=int(a.max()) + 1,
        mesh=mesh,
    )
    got = provision(spec)
    want = provision(dataclasses.replace(spec, mesh=None))
    assert got.x.shape == (2, a.shape[1])
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(want.x))
    with pytest.raises(ValueError, match="scalar or a"):
        PredictionNoise(jnp.zeros((2, 2)), jax.random.key(0)).apply(a)


# ---------------------------------------------------------------------------
# The mesh= fleet path through the harness, and explicit bound dispatch
# ---------------------------------------------------------------------------

def test_mesh_grid_reproduces_cells(report):
    """evaluate(EvalGrid(..., mesh=...)) runs every policy cell through the
    sharded Pallas fleet path and must reproduce the lax.scan report's
    cells verbatim (bit-exact kernel parity end to end)."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    meshed = evaluate(dataclasses.replace(SMALL, mesh=mesh))
    assert meshed.cells == report.cells
    assert meshed.grid["mesh"] == {"data": len(jax.devices())}
    # the sharded lax.scan body agrees too
    unfused = evaluate(dataclasses.replace(SMALL, mesh=mesh, use_pallas=False))
    assert unfused.cells == report.cells


def test_mesh_grid_rejects_offline_policy():
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="offline"):
        evaluate(dataclasses.replace(
            SMALL, policies=("A1", "offline"), mesh=mesh))


def test_offline_and_delayedoff_cells_carry_bounds():
    """_bound dispatches on the policy name explicitly: offline cells pin
    bound 1.0 and delayedoff 2.0 — they must not silently lose their
    bounds because ``theoretical_ratio`` only knows A1/A2/A3 (regression:
    the old except-KeyError fallback was one raise-type change away from
    stripping them)."""
    from repro.eval.harness import _bound

    assert _bound("offline", 0.3) == 1.0
    assert _bound("delayedoff", 0.3) == 2.0
    assert _bound("A1", 0.5) == pytest.approx(1.5)
    assert _bound("not_a_policy", 0.5) is None

    grid = dataclasses.replace(SMALL, policies=("offline", "delayedoff"))
    rep = evaluate(grid)
    by_policy = {}
    for c in rep.cells:
        by_policy.setdefault(c.policy, set()).add(c.bound)
    assert by_policy["offline"] == {1.0}
    assert by_policy["delayedoff"] == {2.0}
    # offline IS the baseline: its CR is exactly 1 and always within bound
    for c in rep.cells:
        if c.policy == "offline":
            assert c.mean_cr == pytest.approx(1.0)
        assert c.bound_ok


# ---------------------------------------------------------------------------
# Typed-fleet cells (EvalGrid.typed_groups) + v1 artifact back-compat
# ---------------------------------------------------------------------------

from repro.core import ServerGroup  # noqa: E402
from repro.eval import SCHEMA_V1, TYPED_POLICIES  # noqa: E402

TYPED_SMALL = dataclasses.replace(SMALL, typed_groups=(
    ServerGroup("efficient", 24, P=1.0, beta_on=3.0, beta_off=3.0),
    ServerGroup("legacy", 24, P=1.5, beta_on=4.5, beta_off=4.5),
))


@pytest.fixture(scope="module")
def typed_report():
    return evaluate(TYPED_SMALL)


def test_typed_cells_cover_policies_by_scenario(typed_report):
    typed = [c for c in typed_report.cells if c.group_mean_cr is not None]
    keys = {(c.policy, c.scenario) for c in typed}
    assert keys == {
        (p, s) for p in TYPED_POLICIES
        for s in typed_report.grid["scenario_labels"]
    }
    untyped = [c for c in typed_report.cells if c.group_mean_cr is None]
    assert len(untyped) == 2 * 2 * 2 * 2           # the plain grid rides along
    d = len(TYPED_SMALL.typed_groups)
    for c in typed:
        assert c.group_names == ["efficient", "legacy"]
        assert len(c.group_mean_cr) == d
        assert c.bound == pytest.approx(
            d * {"AQ-det": 2.0, "AQ-rand": np.e / (np.e - 1)}[c.policy])
        assert all(b == pytest.approx(c.bound / d) for b in c.group_bound)
        assert c.noise_std == 0.0 and c.window == 0 and c.alpha == 0.0


def test_typed_cells_respect_aq_bounds(typed_report):
    assert typed_report.bounds_ok
    for c in typed_report.cells:
        if c.group_bound_ok is not None:
            assert all(c.group_bound_ok)


def test_typed_grid_metadata_and_round_trip(tmp_path, typed_report):
    g = typed_report.grid
    assert [t["name"] for t in g["typed_groups"]] == ["efficient", "legacy"]
    assert g["typed_policies"] == list(TYPED_POLICIES)
    p = typed_report.save(tmp_path / "typed.json")
    loaded = EvalReport.load(p)
    assert loaded.cells == typed_report.cells
    assert loaded.bounds_ok


def test_typed_group_violation_fails_the_report(typed_report):
    """bounds_ok / violations() must consider the per-type verdicts, not
    just the aggregate one."""
    broken = dataclasses.replace(
        typed_report.cells[-1], group_bound_ok=[True, False])
    assert broken.group_mean_cr is not None        # it IS a typed cell
    report = dataclasses.replace(
        typed_report, cells=typed_report.cells[:-1] + [broken])
    assert not report.bounds_ok
    assert report.violations() == [broken]


def test_v1_artifact_still_loads(tmp_path, report):
    """A checked-in v1 report (no distribution/typed columns) must load:
    the v2 fields come back defaulted, verdict logic unchanged."""
    d = report.to_dict()
    d["schema"] = SCHEMA_V1
    v2_only = ("p50_cr", "cr_quantiles", "group_names", "group_mean_cr",
               "group_bound", "group_bound_ok")
    for c in d["cells"]:
        for k in v2_only:
            del c[k]
    p = tmp_path / "v1.json"
    p.write_text(json.dumps(d))
    loaded = EvalReport.load(p)
    assert loaded.schema == SCHEMA_V1
    assert len(loaded.cells) == len(report.cells)
    for got, want in zip(loaded.cells, report.cells):
        assert got.p50_cr is None and got.cr_quantiles is None
        assert got.group_mean_cr is None
        assert got.mean_cr == want.mean_cr
        assert got.bound_ok == want.bound_ok
    assert loaded.bounds_ok == report.bounds_ok


def test_typed_grid_validation():
    with pytest.raises(ValueError, match="typed_policies"):
        evaluate(dataclasses.replace(
            TYPED_SMALL, typed_policies=("A1",)))
    with pytest.raises(ValueError, match="ServerGroup"):
        evaluate(dataclasses.replace(TYPED_SMALL, typed_groups=()))


# ---------------------------------------------------------------------------
# Deferral cells (EvalGrid.deferral_slacks) + v2 artifact back-compat
# ---------------------------------------------------------------------------

from repro.eval import SCHEMA_V2  # noqa: E402

DEFER_SMALL = dataclasses.replace(SMALL, deferral_slacks=(0, 2, 5))


@pytest.fixture(scope="module")
def defer_report():
    return evaluate(DEFER_SMALL)


def test_deferral_cells_cover_the_slack_sweep(defer_report):
    dcells = [c for c in defer_report.cells if c.slack is not None]
    keys = {(c.policy, c.scenario, c.slack) for c in dcells}
    assert keys == {
        (p, s, k)
        for p in DEFER_SMALL.deferral_policies
        for s in defer_report.grid["scenario_labels"]
        for k in DEFER_SMALL.deferral_slacks
    }
    for c in dcells:
        assert c.rule == "EDF"
        assert c.noise_std == 0.0 and c.window == 0
        assert c.p99_delay is not None and c.max_delay is not None
        assert c.p99_delay <= c.max_delay <= c.slack
        assert c.deadline_misses == 0
        assert c.slo_ok
        assert c.bound_ok            # the CR bound still applies


def test_deferral_rigid_cells_ride_along_unchanged(defer_report, report):
    """Adding the deferral axis must not perturb the plain grid's cells."""
    rigid = [c for c in defer_report.cells if c.slack is None]
    assert rigid == report.cells


def test_deferral_slack_buys_cost_off(defer_report):
    by_ps = {}
    for c in defer_report.cells:
        if c.slack is not None:
            by_ps.setdefault((c.policy, c.scenario), []).append(c)
    for cs in by_ps.values():
        cs = sorted(cs, key=lambda c: c.slack)
        assert cs[-1].mean_cost <= cs[0].mean_cost
        # slack 0 IS the rigid engine on this scenario's traces
        assert cs[0].p99_delay == 0


def test_deferral_report_round_trips(tmp_path, defer_report):
    assert SCHEMA.endswith("/v5")
    p = defer_report.save(tmp_path / "defer.json")
    loaded = EvalReport.load(p)
    assert loaded.cells == defer_report.cells
    assert loaded.grid["deferral_slacks"] == [0, 2, 5]
    assert loaded.grid["deferral_rule"] == "EDF"
    assert loaded.bounds_ok


def test_deferral_slo_violation_fails_the_report(defer_report):
    broken_idx = next(i for i, c in enumerate(defer_report.cells)
                      if c.slack is not None)
    broken = dataclasses.replace(defer_report.cells[broken_idx], slo_ok=False)
    cells = list(defer_report.cells)
    cells[broken_idx] = broken
    rep = dataclasses.replace(defer_report, cells=cells)
    assert not rep.bounds_ok
    assert broken in rep.violations()


def test_v2_artifact_still_loads(tmp_path, defer_report):
    """A checked-in v2 report (no deferral columns) must load: the v3
    fields come back None, verdicts unchanged."""
    d = defer_report.to_dict()
    d["schema"] = SCHEMA_V2
    v3_only = ("slack", "rule", "max_delay", "p99_delay",
               "deadline_misses", "slo_ok")
    for c in d["cells"]:
        for k in v3_only:
            del c[k]
    for k in ("deferral_slacks", "deferral_rule", "deferral_policies"):
        d["grid"].pop(k, None)
    p = tmp_path / "v2.json"
    p.write_text(json.dumps(d))
    loaded = EvalReport.load(p)
    assert loaded.schema == SCHEMA_V2
    assert len(loaded.cells) == len(defer_report.cells)
    for got in loaded.cells:
        assert got.slack is None and got.slo_ok is None
    assert loaded.bounds_ok          # missing slo_ok never fails a verdict


def test_deferral_grid_validation():
    with pytest.raises(ValueError, match="deferral_slacks"):
        evaluate(dataclasses.replace(SMALL, deferral_slacks=(-1,)))
    with pytest.raises(ValueError, match="deferral_slacks"):
        evaluate(dataclasses.replace(SMALL, deferral_slacks=()))
    with pytest.raises(ValueError, match="deferral_rule"):
        evaluate(dataclasses.replace(
            SMALL, deferral_slacks=(0,), deferral_rule="LIFO"))
    with pytest.raises(ValueError, match="deferral_policies"):
        evaluate(dataclasses.replace(
            SMALL, deferral_slacks=(0,), deferral_policies=("offline",)))
