"""Theorem 7 / Corollary 8: online competitive ratios; Lemma 6 invariance."""
import math

import numpy as np
import pytest

from repro.core import (
    A1Deterministic,
    A2Randomized,
    A3Randomized,
    CostModel,
    OfflinePolicy,
    a0_cost,
    fluid_cost,
    generate_brick_trace,
    msr_like_trace,
    simulate,
    theoretical_ratio,
    trace_from_intervals,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)  # Delta = 6
E = math.e


# ---------------------------------------------------------------------------
# A1 (deterministic): ratio must hold on EVERY instance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha", [0.0, 0.25, 0.5, 0.75, 1.0])
@pytest.mark.parametrize("seed", range(6))
def test_a1_competitive_ratio_random_traces(alpha, seed):
    rng = np.random.default_rng(seed)
    tr = generate_brick_trace(rng, horizon=80.0, rate=0.9, mean_duration=4.0)
    opt = a0_cost(tr, COSTS)
    on = simulate(tr, A1Deterministic(alpha=alpha), COSTS).cost
    # horizon truncation can add up to one idle wait per trailing server; the
    # interior analysis bound is 2 - alpha (Lemma 10).
    slack = 1e-9 + COSTS.P * (1 - alpha) * COSTS.delta * 3 / max(opt, 1e-9)
    assert on / opt <= theoretical_ratio("A1", alpha) + slack


def test_a1_bound_is_tight_adversarial():
    """Repeated (tiny job, gap just over Delta) cycles -> ratio -> 2 - alpha."""
    eps = 1e-4
    cycle = COSTS.delta + 0.01
    jobs = [(1.0 + i * cycle, 1.0 + i * cycle + eps) for i in range(200)]
    tr = trace_from_intervals(jobs, 1.0 + 200 * cycle + 5.0)
    opt = a0_cost(tr, COSTS)
    for alpha in (0.0, 0.5, 1.0):
        on = simulate(tr, A1Deterministic(alpha=alpha), COSTS).cost
        ratio = on / opt
        bound = theoretical_ratio("A1", alpha)
        assert ratio <= bound + 1e-2
        # tight up to boundary-term dilution for alpha < 1
        if alpha < 1.0:
            assert ratio >= bound - 0.05


def test_a1_alpha1_is_optimal():
    """alpha = 1: full critical window knowledge => exactly optimal (Thm 7 rmk)."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        tr = generate_brick_trace(rng, horizon=60.0, rate=0.8, mean_duration=3.0)
        opt = a0_cost(tr, COSTS)
        on = simulate(tr, A1Deterministic(alpha=1.0), COSTS).cost
        assert on == pytest.approx(opt, rel=1e-9)


# ---------------------------------------------------------------------------
# A2 / A3 (randomized): expected ratio
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,cls", [("A2", A2Randomized), ("A3", A3Randomized)])
@pytest.mark.parametrize("alpha", [0.0, 0.5, 1.0])
def test_randomized_expected_ratio(name, cls, alpha):
    rng = np.random.default_rng(42)
    tr = generate_brick_trace(rng, horizon=120.0, rate=1.2, mean_duration=4.0)
    opt = a0_cost(tr, COSTS)
    runs = 60
    tot = 0.0
    for r in range(runs):
        tot += simulate(tr, cls(alpha=alpha), COSTS, rng=np.random.default_rng(r)).cost
    emp = tot / runs / opt
    bound = theoretical_ratio(name, alpha)
    # expectation estimate + trailing-period slack
    assert emp <= bound + 0.08, f"{name} alpha={alpha}: {emp} > {bound}"


def test_a3_alpha1_is_optimal():
    for seed in range(5):
        rng = np.random.default_rng(seed)
        tr = generate_brick_trace(rng, horizon=60.0, rate=0.8, mean_duration=3.0)
        opt = a0_cost(tr, COSTS)
        on = simulate(tr, A3Randomized(alpha=1.0), COSTS,
                      rng=np.random.default_rng(seed + 99)).cost
        assert on == pytest.approx(opt, rel=1e-9)


def test_a3_beats_a2_bound():
    """e/(e-1+a) <= (e-a)/(e-1) for all alpha in [0,1]."""
    for alpha in np.linspace(0, 1, 21):
        assert theoretical_ratio("A3", alpha) <= theoretical_ratio("A2", alpha) + 1e-12


# ---------------------------------------------------------------------------
# Lemma 6: dispatch is identical across policies (same jobs -> same servers)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_lemma6_assignments_invariant(seed):
    rng = np.random.default_rng(seed)
    tr = generate_brick_trace(rng, horizon=60.0, rate=1.0, mean_duration=3.0)
    base = simulate(tr, OfflinePolicy(), COSTS).assignments
    for pol in (
        A1Deterministic(alpha=0.0),
        A1Deterministic(alpha=0.7),
        A2Randomized(alpha=0.3),
        A3Randomized(alpha=0.9),
    ):
        got = simulate(tr, pol, COSTS, rng=np.random.default_rng(seed + 1)).assignments
        assert got == base, "LIFO dispatch must not depend on the off/idle policy"


# ---------------------------------------------------------------------------
# Fluid-model ratios (Corollary 8)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 1, 2, 3, 5, 6, 8])
def test_fluid_a1_ratio(window):
    a = msr_like_trace(np.random.default_rng(7), n_slots=400, mean_jobs=25.0)
    opt = fluid_cost(a, "offline", COSTS).cost
    on = fluid_cost(a, "A1", COSTS, window=window).cost
    alpha = min(1.0, (window + 1) / COSTS.delta)
    assert on / opt <= 2.0 - alpha + 1e-9


def test_fluid_a1_window_delta_minus_1_is_optimal():
    """Paper Sec. V-B: window Delta-1 slots + current slot => optimal."""
    a = msr_like_trace(np.random.default_rng(3), n_slots=500, mean_jobs=30.0)
    opt = fluid_cost(a, "offline", COSTS).cost
    on = fluid_cost(a, "A1", COSTS, window=int(COSTS.delta) - 1).cost
    assert on == pytest.approx(opt, rel=1e-12)


@pytest.mark.parametrize("name", ["A2", "A3"])
def test_fluid_randomized_ratio(name):
    a = msr_like_trace(np.random.default_rng(11), n_slots=400, mean_jobs=20.0)
    opt = fluid_cost(a, "offline", COSTS).cost
    for window in (0, 2, 4):
        tot = 0.0
        runs = 40
        for r in range(runs):
            tot += fluid_cost(a, name, COSTS, window=window,
                              rng=np.random.default_rng(r)).cost
        alpha = min(1.0, (window + 1) / COSTS.delta)
        assert tot / runs / opt <= theoretical_ratio(name, alpha) + 0.05
