"""Property-based tests (hypothesis) for the provisioning core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    A1Deterministic,
    CostModel,
    a0_cost,
    a0_schedule,
    critical_times,
    dp_optimal_cost,
    fluid_cost,
    fluid_scan,
    optimal_schedule_constructed,
    schedule_cost,
    simulate,
    trace_from_intervals,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


@st.composite
def brick_traces(draw):
    n = draw(st.integers(min_value=1, max_value=14))
    horizon = 60.0
    jobs = []
    used: set[float] = set()

    def fresh(lo, hi):
        t = draw(st.floats(min_value=lo, max_value=hi, allow_nan=False))
        while round(t, 6) in used:
            t += 0.000013
        used.add(round(t, 6))
        return t

    for _ in range(n):
        a = fresh(0.01, horizon - 1.0)
        d = fresh(a + 0.001, min(a + 25.0, horizon - 0.001))
        jobs.append((a, d))
    return trace_from_intervals(jobs, horizon)


@st.composite
def fluid_traces(draw):
    n = draw(st.integers(min_value=3, max_value=60))
    return np.array(draw(st.lists(st.integers(0, 7), min_size=n, max_size=n)))


@given(brick_traces())
@settings(max_examples=60, deadline=None)
def test_prop_a0_matches_construction(tr):
    """Theorem 5 as a property: A0 cost == constructed optimum cost."""
    xa = a0_schedule(tr, COSTS)
    xc = optimal_schedule_constructed(tr, COSTS)
    fl = float(tr.final_count())
    assert schedule_cost(xa, COSTS, final_level=fl) == pytest.approx(
        schedule_cost(xc, COSTS, final_level=fl), rel=1e-9
    )


@given(brick_traces())
@settings(max_examples=60, deadline=None)
def test_prop_lemma2_construction_meets_a_at_critical_times(tr):
    """Lemma 2: x*(t) meets a(t) at every critical time."""
    x = optimal_schedule_constructed(tr, COSTS)
    for tc in critical_times(tr):
        if tc >= tr.horizon:
            continue
        assert x.at(tc) == tr.a_at(tc) or x.before(tc) == tr.a_before(tc)


@given(brick_traces())
@settings(max_examples=40, deadline=None)
def test_prop_feasibility_and_online_upper_bound(tr):
    """x(t) >= a(t) always; A1 never beats the offline optimum."""
    x = a0_schedule(tr, COSTS)
    times, vals = tr.a_breakpoints()
    for t, v in zip(times, vals):
        assert x.at(t) >= v
    opt = a0_cost(tr, COSTS)
    for alpha in (0.0, 0.5, 1.0):
        on = simulate(tr, A1Deterministic(alpha=alpha), COSTS).cost
        assert on >= opt - 1e-9
        assert on <= (2 - alpha) * opt + COSTS.delta * 3  # + boundary slack


@given(fluid_traces(), st.integers(0, 8))
@settings(max_examples=60, deadline=None)
def test_prop_fluid_dp_and_engines_agree(a, window):
    """Level decomposition == DP oracle; scan engine == closed form (det.)."""
    opt_closed = fluid_cost(a, "offline", COSTS).cost
    assert opt_closed == pytest.approx(dp_optimal_cost(a, COSTS), rel=1e-9)
    scan = fluid_scan(a, "offline", COSTS).cost
    assert scan == pytest.approx(opt_closed, rel=1e-9)
    a1_closed = fluid_cost(a, "A1", COSTS, window=window).cost
    a1_scan = fluid_scan(a, "A1", COSTS, window=window).cost
    assert a1_scan == pytest.approx(a1_closed, rel=1e-9)


@given(fluid_traces())
@settings(max_examples=40, deadline=None)
def test_prop_fluid_monotone_in_window(a):
    """More future info never hurts A1 (deterministic)."""
    prev = None
    for w in range(0, 9):
        c = fluid_cost(a, "A1", COSTS, window=w).cost
        if prev is not None:
            assert c <= prev + 1e-9
        prev = c


@given(fluid_traces(), st.floats(0.1, 8.0), st.floats(0.1, 8.0))
@settings(max_examples=40, deadline=None)
def test_prop_fluid_dp_cost_model_sweep(a, bon, boff):
    costs = CostModel(P=1.0, beta_on=bon, beta_off=boff)
    assert fluid_cost(a, "offline", costs).cost == pytest.approx(
        dp_optimal_cost(a, costs), rel=1e-9
    )
