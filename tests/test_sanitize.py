"""The runtime half of repro.lint: tracer_sanitizer's compile and leak
gates, plus the pytest fixture's skip-when-unobservable contract."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.lint.sanitize import (
    RecompileError,
    UnobservableCacheError,
    tracer_sanitizer,
)
from repro.obs import CompileWatcher


@jax.jit
def _double(x):
    return x * 2.0


def _observable() -> bool:
    return CompileWatcher(fns=(_double,)).available


pytestmark = pytest.mark.skipif(
    not _observable(), reason="private jit _cache_size API unavailable"
)


def test_warmed_region_passes_zero_compile_gate():
    _double(jnp.ones(3))  # warm
    with tracer_sanitizer(fns=(_double,)) as watch:
        _double(jnp.ones(3))
    assert watch.added == 0


def test_recompile_raises():
    _double(jnp.ones(3))  # warm the (3,) entry
    with pytest.raises(RecompileError, match="at most 0"):
        with tracer_sanitizer(fns=(_double,)):
            _double(jnp.ones((51,)))  # fresh shape -> new compile


def test_exact_compiles_pins_the_cold_count():
    @jax.jit
    def fresh(x):
        return x + 1.0

    with tracer_sanitizer(fns=(fresh,), exact_compiles=1):
        fresh(jnp.ones(3))
    with pytest.raises(RecompileError, match="exactly 1"):
        with tracer_sanitizer(fns=(fresh,), exact_compiles=1):
            fresh(jnp.ones(3))  # warmed: adds 0, not 1


def test_max_compiles_budget():
    @jax.jit
    def fresh(x):
        return x - 1.0

    with tracer_sanitizer(fns=(fresh,), max_compiles=2):
        fresh(jnp.ones(3))
        fresh(jnp.ones(4))


def test_compile_gate_disabled_with_none():
    @jax.jit
    def fresh(x):
        return x * 3.0

    with tracer_sanitizer(fns=(fresh,), max_compiles=None) as watch:
        fresh(jnp.ones(3))
    assert watch.added == 1  # observed but not gated


def test_leak_check_catches_escaping_tracer():
    box = []

    @jax.jit
    def leaky(x):
        box.append(x)  # tracer escapes into a host closure
        return x

    with pytest.raises(Exception, match="[Ll]eak"):
        with tracer_sanitizer(fns=(leaky,)):
            leaky(jnp.ones(3))


def test_require_observable_raises_when_cache_api_gone(monkeypatch):
    watcher = CompileWatcher(fns=(_double,))
    monkeypatch.setattr(
        type(watcher), "available", property(lambda self: False),
        raising=False,
    )
    # simulate the degraded path: added stays -1 when unobservable
    monkeypatch.setattr(
        "repro.lint.sanitize.CompileWatcher",
        lambda fns=None: _FakeUnobservable(),
    )
    with pytest.raises(UnobservableCacheError):
        with tracer_sanitizer(fns=(_double,), require_observable=True):
            pass
    # and the default degrades silently
    with tracer_sanitizer(fns=(_double,)):
        pass


class _FakeUnobservable:
    added = -1
    available = False

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def test_fixture_factory_yields_gate(tracer_sanitizer):
    _double(jnp.ones(3))  # warm
    with tracer_sanitizer(fns=(_double,)) as watch:
        _double(jnp.ones(3))
    assert watch.added == 0
