"""Scenario library: generator structure, determinism, PMR targeting, replay
round-trips, the Workload bridge, and an empirical competitive-ratio property
(A2's mean CR stays under its paper bound on every registered scenario)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PAPER_COSTS,
    PolicySpec,
    ProvisionSpec,
    Workload,
    provision,
    theoretical_ratio,
)
from repro.core.traces import pmr
from repro.scenarios import (
    DEFAULT_SCENARIOS,
    SAMPLE_TRACE_PATH,
    Scenario,
    concat,
    generate,
    make_workload,
    mix,
    register_scenario,
    scenario_names,
)

N_SLOTS = 288
BUILTIN = ("flash_crowd", "heavy_tail_bursts", "msr_diurnal", "replay",
           "sinusoidal", "step_outage")
COMBINATORS = ("concat", "mix")


def test_registry_has_the_builtin_bank():
    assert scenario_names() == tuple(sorted(BUILTIN + COMBINATORS))
    assert {sc.name for sc in DEFAULT_SCENARIOS} == set(BUILTIN)


def test_unknown_scenario_names_the_registry():
    with pytest.raises(ValueError, match="msr_diurnal"):
        generate(Scenario("msr_durnal"), 1, N_SLOTS)


def test_reregistering_a_name_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_scenario("sinusoidal")(lambda rng, n: np.ones(n))


@pytest.mark.parametrize("name", BUILTIN)
def test_deterministic_under_fixed_seed(name):
    sc = Scenario(name, seed=3, target_pmr=4.0)
    a = generate(sc, 3, N_SLOTS)
    b = generate(sc, 3, N_SLOTS)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, N_SLOTS)
    assert a.dtype == np.int64
    assert (a >= 0).all()


def test_seed_changes_the_traces_but_batch_prefix_is_stable():
    sc = Scenario("flash_crowd", seed=0)
    other = Scenario("flash_crowd", seed=1)
    assert not np.array_equal(generate(sc, 2, N_SLOTS), generate(other, 2, N_SLOTS))
    # trace i is drawn from (seed, i): growing the batch keeps the prefix
    np.testing.assert_array_equal(
        generate(sc, 4, N_SLOTS)[:2], generate(sc, 2, N_SLOTS)
    )


@pytest.mark.parametrize("name", [n for n in BUILTIN if n != "replay"])
@pytest.mark.parametrize("target", [2.5, 4.63])
def test_scale_to_pmr_hits_the_target(name, target):
    sc = Scenario(name, seed=1, target_pmr=target, mean_jobs=40.0)
    a = generate(sc, 2, N_SLOTS)
    for row in a:
        # integer rounding perturbs the continuous-trace PMR slightly
        assert pmr(row) == pytest.approx(target, rel=0.06)
        assert row.mean() == pytest.approx(40.0, rel=0.06)


def test_realized_pmr_is_refit_after_rounding():
    """Rounding used to drift the realized PMR of bursty scenarios well past
    the continuous-trace target (heavy_tail_bursts at a low mean was ~4%
    off before any re-fit could fire at stricter settings); generate() now
    measures the post-rounding ratio and secant-corrects it to PMR_TOL."""
    from repro.scenarios.registry import PMR_TOL

    for name, target, mean in (
        ("heavy_tail_bursts", 8.0, 4.0),
        ("heavy_tail_bursts", 4.63, 2.0),
        ("flash_crowd", 8.0, 4.0),
        ("msr_diurnal", 4.63, 32.0),
    ):
        sc = Scenario(name, seed=1, target_pmr=target, mean_jobs=mean)
        for row in generate(sc, 3, N_SLOTS):
            assert abs(pmr(row) - target) / target <= PMR_TOL + 1e-9, (
                name, target, mean, pmr(row)
            )


def test_unreachable_pmr_warns_and_keeps_best_fit():
    """A near-binary step_outage shape caps the reachable peak-to-mean
    ratio; an impossible target must warn (not silently drift) and still
    return the closest deterministic fit."""
    sc = Scenario("step_outage", seed=1, target_pmr=16.0, mean_jobs=32.0)
    with pytest.warns(RuntimeWarning, match="realized PMR"):
        a = generate(sc, 2, N_SLOTS)
    with pytest.warns(RuntimeWarning, match="realized PMR"):
        b = generate(sc, 2, N_SLOTS)   # determinism survives the re-fit
    np.testing.assert_array_equal(a, b)


def test_flash_crowd_has_spikes_on_a_quiet_baseline():
    sc = Scenario("flash_crowd", seed=2, params={"n_events": 2, "spike_mag": 10.0})
    (a,) = generate(sc, 1, N_SLOTS).astype(float)
    med, peak = np.median(a), a.max()
    assert peak > 4 * med          # spikes tower over the baseline
    # and decay: the slot after the global peak stays elevated (no one-slot blip)
    t = int(a.argmax())
    if t + 1 < len(a):
        assert a[t + 1] > med


def test_step_outage_has_levels_and_a_dropout():
    sc = Scenario("step_outage", seed=5, params={"outage_slots": 12, "noise": 0.0})
    (a,) = generate(sc, 1, N_SLOTS)
    # the dropout survives rescaling: a run of >= 12 consecutive zero slots
    is_zero = np.concatenate([[0], (a == 0).astype(int), [0]])
    edges = np.flatnonzero(np.diff(is_zero))
    runs = edges[1::2] - edges[0::2]
    assert runs.max() >= 12
    # piecewise-constant: few distinct levels relative to the horizon
    assert len(np.unique(a)) < 16


def test_heavy_tail_bursts_is_heavy_tailed():
    sc = Scenario("heavy_tail_bursts", seed=0, target_pmr=None)
    (a,) = generate(sc, 1, 2000).astype(float)
    # Zipf burst sizes: the top slot dwarfs the typical slot
    assert a.max() > 8 * np.median(a)


def test_replay_round_trips_the_checked_in_sample(tmp_path):
    raw = np.loadtxt(SAMPLE_TRACE_PATH, comments="#", delimiter=",")
    sc = Scenario("replay")     # natural PMR, mean rescale only
    (a,) = generate(sc, 1, len(raw)).astype(float)
    # the sample round-trips up to the mean rescale + integer rounding
    want = raw / raw.mean() * sc.mean_jobs
    assert np.abs(a - want).max() <= 0.5 + 1e-9
    # npz replay: exact round-trip when the mean is kept
    p = tmp_path / "t.npz"
    np.savez(p, demand=raw)
    sc2 = Scenario("replay", params={"path": str(p)}, mean_jobs=float(raw.mean()))
    (b,) = generate(sc2, 1, len(raw))
    np.testing.assert_array_equal(b, raw.astype(np.int64))
    # tiling: a longer horizon repeats the recording
    (c,) = generate(sc2, 1, 2 * len(raw))
    np.testing.assert_array_equal(c[: len(raw)], c[len(raw):])


def test_make_workload_attaches_a_noise_sweep():
    wl = make_workload(
        Scenario("sinusoidal", seed=4), 3, N_SLOTS,
        noise_std=jnp.asarray([0.0, 0.3]),
    )
    assert wl.demand.shape == (3, N_SLOTS)
    assert wl.demand.dtype == jnp.int32
    pred = wl.resolve_predicted(wl.demand)
    assert pred.shape == (2, 3, N_SLOTS)
    # std 0 row predicts perfectly; std 0.3 row does not
    np.testing.assert_array_equal(np.asarray(pred[0]), np.asarray(wl.demand))
    assert not np.array_equal(np.asarray(pred[1]), np.asarray(wl.demand))


def test_make_workload_clips_to_fleet_capacity():
    """clip_to caps demand at a (typed) fleet's pinned capacity; below the
    cap the trace is untouched."""
    sc = Scenario("flash_crowd", seed=4, target_pmr=6.0, mean_jobs=16.0)
    full = make_workload(sc, 3, N_SLOTS)
    cap = int(np.asarray(full.demand).max()) - 5
    clipped = make_workload(sc, 3, N_SLOTS, clip_to=cap)
    np.testing.assert_array_equal(
        np.asarray(clipped.demand), np.minimum(np.asarray(full.demand), cap))
    assert int(np.asarray(clipped.demand).max()) == cap
    with pytest.raises(ValueError, match="clip_to"):
        make_workload(sc, 1, N_SLOTS, clip_to=0)


@pytest.mark.parametrize("name", BUILTIN)
def test_a2_empirical_cr_respects_the_paper_bound(name):
    """A2's expectation guarantee (Thm 3) holds empirically on every
    registered scenario: mean CR over PRNG replicas <= (e-alpha)/(e-1) + tol."""
    sc = next(s for s in DEFAULT_SCENARIOS if s.name == name)
    demand = jnp.asarray(generate(sc, 8, N_SLOTS), jnp.int32)
    n_levels = int(demand.max()) + 1
    opt = provision(ProvisionSpec(
        costs=PAPER_COSTS,
        workload=Workload(demand=demand),
        policy=PolicySpec("offline"),
        n_levels=n_levels,
    )).cost
    for window in (0, 3):
        cost = provision(ProvisionSpec(
            costs=PAPER_COSTS,
            workload=Workload(demand=demand),
            policy=PolicySpec("A2", window=window, key=jax.random.key(7)),
            n_levels=n_levels,
        )).cost
        alpha = min(1.0, (window + 1) / float(PAPER_COSTS.delta))
        mean_cr = float(jnp.mean(cost / opt))
        assert mean_cr <= theoretical_ratio("A2", alpha) + 0.05, (name, window)


# ---------------------------------------------------------------------------
# Combinators: mix (weighted overlay) and concat (timeline splice)
# ---------------------------------------------------------------------------

MIX = mix(
    Scenario("msr_diurnal", target_pmr=3.0),
    Scenario("heavy_tail_bursts", target_pmr=8.0, mean_jobs=8.0),
    weights=(0.7, 0.3), seed=5, target_pmr=4.0,
)
CONCAT = concat(
    Scenario("sinusoidal", target_pmr=3.0),
    Scenario("flash_crowd", target_pmr=6.0),
    fractions=(0.75, 0.25), seed=5, target_pmr=4.0,
)


@pytest.mark.parametrize("sc", [MIX, CONCAT], ids=["mix", "concat"])
def test_combinators_are_deterministic_and_prefix_stable(sc):
    a = generate(sc, 4, N_SLOTS)
    np.testing.assert_array_equal(a, generate(sc, 4, N_SLOTS))
    assert a.shape == (4, N_SLOTS) and a.dtype == np.int64 and (a >= 0).all()
    # growing the batch keeps its prefix (the CRN contract composites share)
    np.testing.assert_array_equal(generate(sc, 8, N_SLOTS)[:4], a)


@pytest.mark.parametrize("sc", [MIX, CONCAT], ids=["mix", "concat"])
def test_combinators_hit_the_outer_pmr_target(sc):
    from repro.scenarios.registry import PMR_TOL

    for row in generate(sc, 3, N_SLOTS):
        assert abs(pmr(row) - 4.0) / 4.0 <= PMR_TOL + 1e-9, pmr(row)
        assert row.mean() == pytest.approx(32.0, rel=0.06)


def test_mix_weights_actually_weight():
    """An all-weight-on-one mix equals generating that component alone
    through the composite pipeline (same child stream, weight 1)."""
    lone = mix(Scenario("sinusoidal", target_pmr=3.0), seed=7, target_pmr=3.0)
    pair = mix(Scenario("sinusoidal", target_pmr=3.0),
               Scenario("flash_crowd", target_pmr=6.0),
               weights=(1.0, 0.0), seed=7, target_pmr=3.0)
    # not array-equal (the second child stream is still drawn), but the
    # zero-weighted component must not contribute load: both are pure
    # sinusoids, so the distinguishing flash-crowd spikes are absent
    a, b = generate(lone, 2, N_SLOTS), generate(pair, 2, N_SLOTS)
    assert pmr(a[0]) == pytest.approx(pmr(b[0]), rel=0.1)


def test_concat_splices_the_timeline():
    """The concat trace's segments carry their components' character: the
    flash-crowd tail contains the composite's peak slots."""
    (row,) = generate(CONCAT, 1, N_SLOTS)
    split = int(round(0.75 * N_SLOTS))
    assert row[split:].max() > row[:split].max()


def test_combinator_validation():
    with pytest.raises(ValueError, match="at least one component"):
        mix()
    with pytest.raises(ValueError, match="Scenario instances"):
        mix("sinusoidal")  # type: ignore[arg-type]
    with pytest.raises(ValueError, match="weights"):
        generate(mix(Scenario("sinusoidal"), weights=(0.5, 0.5)), 1, N_SLOTS)
    with pytest.raises(ValueError, match="fractions"):
        generate(concat(Scenario("sinusoidal"), fractions=(0.4, 0.6)),
                 1, N_SLOTS)


# ---------------------------------------------------------------------------
# The deferral bridge: clip_to + DeferralSpec queues instead of truncating
# ---------------------------------------------------------------------------

def test_make_workload_defers_instead_of_clipping():
    """With a DeferralSpec, clip_to becomes the service cap: demand is NOT
    truncated, over-capacity arrivals queue, and (at a feasible cap) the
    deferred profile conserves every job the raw trace carried."""
    from repro.core import DeferralSpec

    sc = Scenario("msr_diurnal", seed=4, target_pmr=3.0, mean_jobs=32.0)
    full = make_workload(sc, 2, N_SLOTS)
    cap = 80                                  # feasible: well above the mean
    assert int(np.asarray(full.demand).max()) > cap
    wl = make_workload(sc, 2, N_SLOTS, clip_to=cap,
                       deferral=DeferralSpec(slack=8))
    # demand is the raw trace, the cap moved into the spec
    np.testing.assert_array_equal(np.asarray(wl.demand),
                                  np.asarray(full.demand))
    assert wl.deferral.cap == cap
    deferred = np.asarray(wl.deferral.validate().apply(wl.demand))
    assert int(deferred.max()) <= cap
    # conservation: clipping would have dropped this work
    np.testing.assert_array_equal(deferred.sum(axis=-1),
                                  np.asarray(full.demand).sum(axis=-1))
    # an explicit tighter spec cap is respected (min wins)
    tighter = make_workload(sc, 2, N_SLOTS, clip_to=cap,
                            deferral=DeferralSpec(slack=8, cap=cap - 10))
    assert tighter.deferral.cap == cap - 10
