"""BENCH-diff: compare two ``BENCH_provision.json`` artifacts cell by cell.

CI's regression gate for the competitive-ratio trajectory: given the
checked-in baseline and a freshly generated report, key every cell by
``(policy, scenario, noise_std, window, slack, rule)`` (the deferral
coordinates are None on rigid cells, so pre-v3 keys are unchanged) and
flag

- **removed cells** — a grid that silently shrank is a coverage regression;
- **mean-CR increases** beyond ``--tol`` — the empirical ratio drifting up
  means the engine got *worse* at following the offline optimum (common
  random numbers make mean CR deterministic per seed, so any drift is a
  code change, not sampling noise);
- **bound-verdict flips** (``bound_ok``/per-type ``group_bound_ok``/the
  deferral latency ``slo_ok`` true → false) — a paper guarantee or
  latency SLO newly violated.

New cells, CR improvements, verdicts flipping false → true, ``p99_delay``
drift, and per-cell ``wall_ms`` drift beyond ``--wall-tol`` (v4's runtime
column — machine-dependent, so never gated) are informational only.  So is
the whole v5 ``streaming`` section: rows are keyed by ``(policy,
t_chunk)`` and their plan-latency p50/p99 and compile counts are reported
when they move (latency beyond ``--wall-tol``), but wall time on a
benchmark host proves nothing about the engine, so streaming changes never
set the exit status — the zero-steady-state-recompile claim is gated at
generation time by ``cr_eval.py`` instead.  Exit status 1 on any
regression, 0 otherwise::

    PYTHONPATH=src python benchmarks/bench_diff.py baseline.json new.json

Loads via :class:`repro.eval.report.EvalReport`, so a v1/v2/v3 baseline
diffs cleanly against a v4 report (older cells just lack the newer
columns, which the diff treats as absent rather than changed).
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys

from repro.eval import EvalReport
from repro.eval.report import CellResult

#: default tolerance on mean-CR drift before it counts as a regression
DEFAULT_TOL = 1e-6

#: default relative wall_ms drift before a cell is even mentioned (25% —
#: wall clocks are noisy and machine-dependent; this is informational only)
DEFAULT_WALL_TOL = 0.25


def cell_key(c: CellResult) -> tuple:
    return (
        c.policy,
        c.scenario,
        round(float(c.noise_std), 9),
        int(c.window),
        None if c.slack is None else int(c.slack),
        c.rule,
    )


def _sort_key(k: tuple) -> tuple:
    """Total order over cell keys: rigid cells (slack None) sort before
    deferral cells — plain sorted() would choke comparing None with int."""
    policy, scenario, std, window, slack, rule = k
    return (policy, scenario, std, window,
            slack is not None, slack or 0, rule or "")


def _fmt_key(k: tuple) -> str:
    policy, scenario, std, window, slack, rule = k
    base = f"{policy} on {scenario} (std={std:g}, w={window})"
    if slack is not None:
        base += f" defer[{rule} slack={slack}]"
    return base


def _verdict_flipped(old: CellResult, new: CellResult) -> bool:
    """True iff any bound/SLO verdict the baseline passed now fails."""
    if old.bound_ok and not new.bound_ok:
        return True
    if old.group_bound_ok is not None and new.group_bound_ok is not None:
        if any(o and not n for o, n in
               zip(old.group_bound_ok, new.group_bound_ok)):
            return True
    if old.slo_ok is not None and new.slo_ok is not None:
        if old.slo_ok and not new.slo_ok:
            return True
    return False


@dataclasses.dataclass
class BenchDiff:
    """The cell-by-cell comparison of two reports."""

    removed: list[tuple]                               # keys gone from new
    added: list[tuple]                                 # keys new grew
    worse: list[tuple[tuple, float, float]]            # (key, old_cr, new_cr)
    improved: list[tuple[tuple, float, float]]
    flipped: list[tuple]                               # verdict true -> false
    unflipped: list[tuple]                             # verdict false -> true
    latency_drift: list[tuple[tuple, int, int]] = dataclasses.field(
        default_factory=list
    )                                                  # (key, old_p99, new_p99)
    wall_drift: list[tuple[tuple, float, float]] = dataclasses.field(
        default_factory=list
    )                                                  # (key, old_ms, new_ms)
    stream_changed: list[str] = dataclasses.field(
        default_factory=list
    )                                                  # informational lines
    n_common: int = 0

    @property
    def regressed(self) -> bool:
        return bool(self.removed or self.worse or self.flipped)

    def lines(self) -> list[str]:
        out = [f"{self.n_common} common cells, {len(self.added)} added, "
               f"{len(self.removed)} removed"]
        for k in self.removed:
            out.append(f"REGRESSION removed cell: {_fmt_key(k)}")
        for k, old, new in self.worse:
            out.append(
                f"REGRESSION mean CR up: {_fmt_key(k)}: "
                f"{old:.6f} -> {new:.6f} (+{new - old:.2e})"
            )
        for k in self.flipped:
            out.append(f"REGRESSION bound verdict flipped ok->VIOLATED: "
                       f"{_fmt_key(k)}")
        for k in self.added:
            out.append(f"new cell: {_fmt_key(k)}")
        for k, old, new in self.improved:
            out.append(f"improved: {_fmt_key(k)}: {old:.6f} -> {new:.6f}")
        for k in self.unflipped:
            out.append(f"bound verdict recovered: {_fmt_key(k)}")
        for k, old, new in self.latency_drift:
            out.append(f"p99 delay drift: {_fmt_key(k)}: {old} -> {new}")
        for k, old, new in self.wall_drift:
            out.append(
                f"wall_ms drift (informational): {_fmt_key(k)}: "
                f"{old:.1f} -> {new:.1f} ({(new - old) / old:+.0%})"
            )
        out.extend(self.stream_changed)
        return out


def diff_reports(
    baseline: EvalReport,
    new: EvalReport,
    tol: float = DEFAULT_TOL,
    wall_tol: float = DEFAULT_WALL_TOL,
) -> BenchDiff:
    """Compare two reports; ``tol`` is the allowed mean-CR increase and
    ``wall_tol`` the relative wall_ms change worth mentioning."""
    old_cells = {cell_key(c): c for c in baseline.cells}
    new_cells = {cell_key(c): c for c in new.cells}
    if len(old_cells) != len(baseline.cells):
        raise ValueError("baseline report has duplicate cell keys")
    if len(new_cells) != len(new.cells):
        raise ValueError("new report has duplicate cell keys")

    diff = BenchDiff(
        removed=sorted((k for k in old_cells if k not in new_cells),
                       key=_sort_key),
        added=sorted((k for k in new_cells if k not in old_cells),
                     key=_sort_key),
        worse=[], improved=[], flipped=[], unflipped=[],
    )
    for k in sorted(set(old_cells) & set(new_cells), key=_sort_key):
        o, n = old_cells[k], new_cells[k]
        diff.n_common += 1
        if n.mean_cr > o.mean_cr + tol:
            diff.worse.append((k, o.mean_cr, n.mean_cr))
        elif n.mean_cr < o.mean_cr - tol:
            diff.improved.append((k, o.mean_cr, n.mean_cr))
        if _verdict_flipped(o, n):
            diff.flipped.append(k)
        elif _verdict_flipped(n, o):
            diff.unflipped.append(k)
        if (
            o.p99_delay is not None
            and n.p99_delay is not None
            and o.p99_delay != n.p99_delay
        ):
            diff.latency_drift.append((k, o.p99_delay, n.p99_delay))
        if (
            o.wall_ms is not None
            and n.wall_ms is not None
            and o.wall_ms > 0
            and abs(n.wall_ms - o.wall_ms) / o.wall_ms > wall_tol
        ):
            diff.wall_drift.append((k, o.wall_ms, n.wall_ms))
    diff.stream_changed = _diff_streaming(baseline, new, wall_tol)
    return diff


def _diff_streaming(
    baseline: EvalReport, new: EvalReport, wall_tol: float
) -> list[str]:
    """Informational lines for the v5 streaming rows — never a regression.

    Rows are keyed by ``(policy, t_chunk)``; latency drift is mentioned
    past ``wall_tol`` (relative, on p50), compile-count changes always.
    """
    old_rows = {(r.policy, r.t_chunk): r for r in (baseline.streaming or [])}
    new_rows = {(r.policy, r.t_chunk): r for r in (new.streaming or [])}
    lines = []
    for key in sorted(set(old_rows) - set(new_rows)):
        lines.append(
            f"streaming row gone (informational): {key[0]} t_chunk={key[1]}"
        )
    for key in sorted(set(new_rows) - set(old_rows)):
        lines.append(f"new streaming row: {key[0]} t_chunk={key[1]}")
    for key in sorted(set(old_rows) & set(new_rows)):
        o, n = old_rows[key], new_rows[key]
        tag = f"{key[0]} t_chunk={key[1]}"
        if o.compiles != n.compiles:
            lines.append(
                f"streaming compiles changed (informational): {tag}: "
                f"{o.compiles} -> {n.compiles}"
            )
        if (
            o.p50_ms is not None
            and n.p50_ms is not None
            and o.p50_ms > 0
            and abs(n.p50_ms - o.p50_ms) / o.p50_ms > wall_tol
        ):
            lines.append(
                f"streaming latency drift (informational): {tag}: "
                f"p50 {o.p50_ms:.2f} -> {n.p50_ms:.2f} ms, "
                f"p99 {o.p99_ms:.2f} -> {n.p99_ms:.2f} ms"
            )
    return lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", type=pathlib.Path,
                    help="the reference BENCH_provision.json")
    ap.add_argument("new", type=pathlib.Path,
                    help="the freshly generated report to gate")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="allowed mean-CR increase per cell "
                         f"(default {DEFAULT_TOL:g})")
    ap.add_argument("--wall-tol", type=float, default=DEFAULT_WALL_TOL,
                    help="relative wall_ms drift worth reporting, never gated "
                         f"(default {DEFAULT_WALL_TOL:g})")
    args = ap.parse_args(argv)

    diff = diff_reports(
        EvalReport.load(args.baseline), EvalReport.load(args.new),
        tol=args.tol, wall_tol=args.wall_tol,
    )
    for line in diff.lines():
        print(line)
    if diff.regressed:
        print("bench_diff: REGRESSION", file=sys.stderr)
        return 1
    print("bench_diff: ok", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
