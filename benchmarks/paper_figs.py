"""Paper-figure benchmarks: one function per table/figure (Section V).

Fig. 3 and Fig. 4b run on the declarative jitted engine: each policy's
whole (runs x alpha) grid is ONE device program (`provision` with a
`PolicySpec(windows=...)` sweep) instead of a Python loop per (trace,
policy, alpha) triple; Fig. 4c's error study rides the `PredictionNoise`
(S,) sweep axis the same way.  LCP keeps the closed-form numpy path (it is
not one of the paper's ski-rental policies).  Traces come from the scenario
registry (`repro.scenarios`); `benchmarks/cr_eval.py` runs the full
scenario x policy x noise grid and serializes the CR report.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    RANDOMIZED_POLICIES,
    CostModel,
    PolicySpec,
    PredictionNoise,
    ProvisionSpec,
    Workload,
    fluid_cost,
    provision,
    theoretical_ratio,
)
from repro.core.traces import WEEK_SLOTS
from repro.scenarios import Scenario, generate

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)   # Delta = 6, paper Sec. V-A


def _trace(target_pmr: float = 4.63) -> np.ndarray:
    """The paper's MSR-like week, drawn from the scenario registry."""
    sc = Scenario("msr_diurnal", target_pmr=target_pmr, mean_jobs=40.0)
    return generate(sc, 1, WEEK_SLOTS)[0]


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def _sweep_mean_costs(a: np.ndarray, policy: str, windows, runs: int, seed: int = 0):
    """((W,) mean engine cost over `runs` PRNG replicas, us per call).

    The whole (runs x windows) grid is one device program; the first call
    warms the jit cache so the reported time is execution, not compile.
    """
    n_levels = int(a.max()) + 1
    ab = jnp.asarray(np.tile(a, (runs, 1)), jnp.int32)
    spec = ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=ab),
        policy=PolicySpec(
            policy,
            windows=jnp.asarray(windows, jnp.int32),
            key=jax.random.key(seed) if policy in RANDOMIZED_POLICIES else None,
        ),
        n_levels=n_levels,
    )

    def once():
        return jax.block_until_ready(provision(spec).cost)

    costs = once()
    t0 = time.perf_counter()
    for _ in range(3):
        once()
    us = (time.perf_counter() - t0) / 3 * 1e6
    return np.asarray(costs).mean(axis=1), us


def fig3_competitive_ratios(rows: list[str]) -> None:
    """Fig. 3: worst-case vs empirical ratios as alpha grows (batched engine)."""
    a = _trace()
    opt = fluid_cost(a, "offline", COSTS).cost
    windows = np.arange(0, 6)
    for name, runs in (("A1", 1), ("A2", 30), ("A3", 30)):
        means, us = _sweep_mean_costs(a, name, windows, runs)
        for w, mean in zip(windows, means):
            alpha = min(1.0, (w + 1) / COSTS.delta)
            emp = float(mean) / opt
            bound = theoretical_ratio(name, alpha)
            assert emp <= bound + 0.05, (name, alpha, emp, bound)
            rows.append(
                f"fig3_{name}_w{w},{us / (runs * len(windows)):.1f},"
                f"alpha={alpha:.2f};empirical={emp:.4f};bound={bound:.4f}"
            )


def fig4b_cost_reduction_vs_window(rows: list[str]) -> None:
    """Fig. 4b: cost reduction vs prediction window, all six policies."""
    a = _trace()
    static = fluid_cost(a, "static", COSTS).cost
    opt = fluid_cost(a, "offline", COSTS).cost
    rows.append(f"fig4b_offline,0.0,reduction={1 - opt / static:.4f}")
    windows = np.arange(0, 11)
    for name, runs in (("A1", 1), ("A2", 20), ("A3", 20)):
        means, us = _sweep_mean_costs(a, name, windows, runs)
        for w, mean in zip(windows, means):
            red = 1 - float(mean) / static
            rows.append(
                f"fig4b_{name}_w{w},{us / (runs * len(windows)):.1f},"
                f"reduction={red:.4f}"
            )
    for w in range(1, 11):
        c, us = _timed(lambda: fluid_cost(a, "lcp", COSTS, window=w).cost)
        rows.append(f"fig4b_LCP_w{w},{us:.1f},reduction={1 - c / static:.4f}")
    means, us = _sweep_mean_costs(a, "delayedoff", [0], 1)
    rows.append(f"fig4b_DELAYEDOFF,{us:.1f},reduction={1 - float(means[0]) / static:.4f}")


def fig4c_prediction_error(rows: list[str]) -> None:
    """Fig. 4c: robustness to zero-mean Gaussian prediction error.

    The whole (error-std x window x replica) study is ONE device program:
    ``PredictionNoise.std_frac`` is the (S,) sweep axis — common random
    numbers across stds — and the windows ride ``PolicySpec.windows``.
    """
    a = _trace()
    static = fluid_cost(a, "static", COSTS).cost
    runs = 10
    stds = (0.0, 0.1, 0.25, 0.5)
    windows = (2, 4)
    spec = ProvisionSpec(
        costs=COSTS,
        workload=Workload(
            demand=jnp.asarray(np.tile(a, (runs, 1)), jnp.int32),
            noise=PredictionNoise(
                std_frac=jnp.asarray(stds, jnp.float32), key=jax.random.key(7)
            ),
        ),
        policy=PolicySpec("A1", windows=jnp.asarray(windows, jnp.int32)),
        n_levels=int(a.max()) + 1,
    )
    jax.block_until_ready(provision(spec).cost)       # warm the jit cache
    t0 = time.perf_counter()
    costs = jax.block_until_ready(provision(spec).cost)     # (S, W, B)
    us = (time.perf_counter() - t0) * 1e6 / (runs * len(stds) * len(windows))
    for s, std in enumerate(stds):
        for w, window in enumerate(windows):
            red = 1 - float(jnp.mean(costs[s, w])) / static
            rows.append(
                f"fig4c_A1_w{window}_std{int(std * 100)},{us:.1f},reduction={red:.4f}"
            )


def fig4d_pmr_sweep(rows: list[str]) -> None:
    """Fig. 4d: savings grow with the peak-to-mean ratio.

    The PMR knob is the scenario's ``target_pmr`` field (same seed => same
    base shape, only the Section V-D rescale differs).
    """
    for pmr in (2, 3, 4, 6, 8, 10):
        a = _trace(target_pmr=float(pmr))
        static = fluid_cost(a, "static", COSTS).cost
        (c, us) = _timed(lambda: fluid_cost(a, "A1", COSTS, window=1).cost)
        rows.append(f"fig4d_pmr{pmr},{us:.1f},reduction={1 - c / static:.4f}")


def run(rows: list[str]) -> None:
    fig3_competitive_ratios(rows)
    fig4b_cost_reduction_vs_window(rows)
    fig4c_prediction_error(rows)
    fig4d_pmr_sweep(rows)
