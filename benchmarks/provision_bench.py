"""Provisioning-engine benchmarks: throughput of the jitted fleet provisioner
and the event-driven brick simulator (cluster-controller capacity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CostModel, generate_brick_trace, msr_like_trace, simulate
from repro.core.jax_provision import provision_schedule
from repro.core.ski_rental import A1Deterministic

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


def jax_provisioner_throughput(rows: list[str]) -> None:
    for n_levels in (64, 512, 4096):
        a = jnp.asarray(
            msr_like_trace(np.random.default_rng(0), mean_jobs=n_levels / 4.0,
                           n_slots=1008),
            jnp.int32,
        )
        fn = lambda: provision_schedule(
            a, n_levels=n_levels, delta=6, window=2, policy="A1"
        )
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(
            f"jax_provision_levels{n_levels},{us:.1f},"
            f"slots=1008;decisions_per_s={n_levels * 1008 / (us / 1e6):.3e}"
        )


def brick_simulator_throughput(rows: list[str]) -> None:
    rng = np.random.default_rng(1)
    tr = generate_brick_trace(rng, horizon=2000.0, rate=3.0, mean_duration=4.0)
    t0 = time.perf_counter()
    simulate(tr, A1Deterministic(alpha=0.5), COSTS)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"brick_sim_{len(tr.jobs)}jobs,{us:.1f},"
        f"events_per_s={2 * len(tr.jobs) / (us / 1e6):.3e}"
    )


def run(rows: list[str]) -> None:
    jax_provisioner_throughput(rows)
    brick_simulator_throughput(rows)
