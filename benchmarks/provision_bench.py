"""Provisioning-engine benchmarks: throughput of the batched jitted fleet
provisioner (traces x alpha-sweep x levels as one device program), the fused
Pallas scan path, and the event-driven brick simulator (cluster-controller
capacity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    RANDOMIZED_POLICIES,
    CostModel,
    generate_brick_trace,
    msr_like_trace,
    simulate,
)
from repro.core.jax_provision import (
    provision_schedule,
    provision_sweep_costs,
)
from repro.core.ski_rental import A1Deterministic
from repro.kernels.provision_scan import provision_scan

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
DELTA = int(COSTS.delta)
N_SLOTS = 1008


def _trace(n_levels: int, seed: int = 0) -> np.ndarray:
    return msr_like_trace(
        np.random.default_rng(seed), mean_jobs=n_levels / 4.0, n_slots=N_SLOTS
    )


def jax_provisioner_throughput(rows: list[str]) -> None:
    """Single-trace A1 path (the serving autoscaler's hot loop)."""
    for n_levels in (64, 512, 4096):
        a = jnp.asarray(_trace(n_levels), jnp.int32)
        fn = lambda: provision_schedule(
            a, n_levels=n_levels, delta=DELTA, window=2, policy="A1"
        )
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(
            f"jax_provision_levels{n_levels},{us:.1f},"
            f"slots={N_SLOTS};decisions_per_s={n_levels * N_SLOTS / (us / 1e6):.3e}"
        )


def batched_sweep_throughput(rows: list[str]) -> None:
    """The batched engine: (traces x alpha values x levels) per second."""
    n_levels = 256
    n_windows = DELTA
    windows = jnp.arange(n_windows, dtype=jnp.int32)
    for policy, n_traces in (("A1", 32), ("A3", 32)):
        a = jnp.asarray(
            np.stack([_trace(n_levels, seed=s) for s in range(n_traces)]), jnp.int32
        )
        key = jax.random.key(0)
        fn = lambda: provision_sweep_costs(
            a, n_levels=n_levels, delta=DELTA, windows=windows, policy=policy,
            key=key if policy in RANDOMIZED_POLICIES else None,
            P=COSTS.P, beta_on=COSTS.beta_on, beta_off=COSTS.beta_off,
        )
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 3 * 1e6
        cells = n_traces * n_windows * n_levels * N_SLOTS
        rows.append(
            f"batched_sweep_{policy}_b{n_traces}_w{n_windows}_n{n_levels},{us:.1f},"
            f"decisions_per_s={cells / (us / 1e6):.3e}"
        )


def pallas_scan_throughput(rows: list[str]) -> None:
    """Fused Pallas per-level scan (interpret mode off-TPU)."""
    for n_levels in (512, 4096):
        a = jnp.asarray(_trace(n_levels), jnp.int32)
        thresholds = jnp.full((n_levels,), float(DELTA - 3), jnp.float32)
        fn = jax.jit(
            lambda a_, m_: provision_scan(a_, m_, delta=DELTA, horizon=3)
        )
        jax.block_until_ready(fn(a, thresholds))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(a, thresholds))
        us = (time.perf_counter() - t0) / 3 * 1e6
        mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
        rows.append(
            f"pallas_scan_{mode}_levels{n_levels},{us:.1f},"
            f"decisions_per_s={n_levels * N_SLOTS / (us / 1e6):.3e}"
        )


def brick_simulator_throughput(rows: list[str]) -> None:
    rng = np.random.default_rng(1)
    tr = generate_brick_trace(rng, horizon=2000.0, rate=3.0, mean_duration=4.0)
    t0 = time.perf_counter()
    simulate(tr, A1Deterministic(alpha=0.5), COSTS)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"brick_sim_{len(tr.jobs)}jobs,{us:.1f},"
        f"events_per_s={2 * len(tr.jobs) / (us / 1e6):.3e}"
    )


def run(rows: list[str]) -> None:
    jax_provisioner_throughput(rows)
    batched_sweep_throughput(rows)
    pallas_scan_throughput(rows)
    brick_simulator_throughput(rows)
