"""Provisioning-engine benchmarks: throughput of the declarative jitted fleet
provisioner (traces x alpha-sweep x levels as one `provision(spec)` program),
the fused Pallas scan path, heterogeneous per-level cost models, and the
event-driven brick simulator (cluster-controller capacity).

Run standalone for the CI smoke leg:

    PYTHONPATH=src python benchmarks/provision_bench.py --smoke

The smoke run uses small shapes and additionally asserts that re-pricing a
fleet (new CostModel values, same shapes/policy) does NOT grow the engine's
jit cache — the spec's cost fields are pytree data, not compile keys — that
one mesh-path (S, W, B) grid cell compiles exactly one `_sharded_grid`
program (none on a warmed re-run), and that the observability layer keeps
its zero-overhead contract (`telemetry_overhead` row: a live telemetry
registry adds 0 compiles to the warmed default path, and
``record_decisions=True`` leaves the schedule bit-exact).

``--profile DIR`` wraps the run in ``jax.profiler.trace``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    RANDOMIZED_POLICIES,
    CostModel,
    PolicySpec,
    PredictionNoise,
    ProvisionSpec,
    ServerGroup,
    Workload,
    generate_brick_trace,
    msr_like_trace,
    provision,
    simulate,
)
from repro.core.ski_rental import A1Deterministic
from repro.kernels.provision_scan import provision_scan
from repro.lint.sanitize import tracer_sanitizer
from repro.obs import CompileWatcher, profile_to, telemetry_session

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
DELTA = int(COSTS.delta)
N_SLOTS = 1008


def _trace(n_levels: int, seed: int = 0, n_slots: int = N_SLOTS) -> np.ndarray:
    return msr_like_trace(
        np.random.default_rng(seed), mean_jobs=n_levels / 4.0, n_slots=n_slots
    )


def _spec(a, n_levels, policy="A1", windows=None, costs=COSTS, key=None):
    return ProvisionSpec(
        costs=costs,
        workload=Workload(demand=jnp.asarray(a, jnp.int32)),
        policy=PolicySpec(
            policy, window=2, windows=windows,
            key=key if policy in RANDOMIZED_POLICIES else None,
        ),
        n_levels=n_levels,
    )


def jax_provisioner_throughput(rows: list[str], sizes=(64, 512, 4096)) -> None:
    """Single-trace A1 path (the serving autoscaler's hot loop)."""
    for n_levels in sizes:
        a = _trace(n_levels)
        spec = _spec(a, n_levels)
        def fn():
            return provision(spec).x

        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(
            f"jax_provision_levels{n_levels},{us:.1f},"
            f"slots={len(a)};decisions_per_s={n_levels * len(a) / (us / 1e6):.3e}"
        )


def batched_sweep_throughput(rows: list[str], n_levels=256, n_traces=32) -> None:
    """The batched engine: (traces x alpha values x levels) per second."""
    n_windows = DELTA
    windows = jnp.arange(n_windows, dtype=jnp.int32)
    for policy in ("A1", "A3"):
        a = np.stack([_trace(n_levels, seed=s) for s in range(n_traces)])
        spec = _spec(a, n_levels, policy, windows=windows, key=jax.random.key(0))
        def fn():
            return provision(spec).cost

        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 3 * 1e6
        cells = n_traces * n_windows * n_levels * a.shape[1]
        rows.append(
            f"batched_sweep_{policy}_b{n_traces}_w{n_windows}_n{n_levels},{us:.1f},"
            f"decisions_per_s={cells / (us / 1e6):.3e}"
        )


def heterogeneous_throughput(rows: list[str], n_levels=256) -> None:
    """Per-level cost arrays (two server classes) vs the scalar model."""
    a = _trace(n_levels)
    beta = np.where(np.arange(n_levels) < n_levels // 2, 4.5, 1.5)
    het = CostModel(P=1.0, beta_on=beta, beta_off=beta)
    for tag, costs in (("homog", COSTS), ("hetero", het)):
        spec = _spec(a, n_levels, costs=costs)
        def fn():
            return provision(spec).cost

        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(
            f"provision_{tag}_n{n_levels},{us:.1f},"
            f"decisions_per_s={n_levels * len(a) / (us / 1e6):.3e}"
        )


def typed_fleet_throughput(rows: list[str], n_total=256) -> None:
    """Typed d=2 fleet (CostModel.from_groups) under AQ-det vs the untyped
    scalar model under delayedoff on the same demand — same per-level timer
    mechanics, so the delta is the cost of the group axis (group_cost
    reduction + routing-priority concatenation)."""
    half = n_total // 2
    typed = CostModel.from_groups(
        ServerGroup("efficient", half, P=1.0, beta_on=3.0, beta_off=3.0),
        ServerGroup("legacy", n_total - half, P=1.5, beta_on=4.5, beta_off=4.5),
    )
    a = _trace(n_total)
    for tag, costs, policy in (
        ("untyped_delayedoff", COSTS, "delayedoff"),
        ("typed2_AQ-det", typed, "AQ-det"),
    ):
        spec = ProvisionSpec(
            costs=costs,
            workload=Workload(demand=jnp.asarray(a, jnp.int32)),
            policy=PolicySpec(policy),
            n_levels=n_total,
        )
        def fn():
            return provision(spec).cost

        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(
            f"provision_{tag}_n{n_total},{us:.1f},"
            f"decisions_per_s={n_total * len(a) / (us / 1e6):.3e}"
        )


def pallas_scan_throughput(rows: list[str], sizes=(512, 4096)) -> None:
    """Fused Pallas per-level scan (interpret mode off-TPU)."""
    for n_levels in sizes:
        a = jnp.asarray(_trace(n_levels), jnp.int32)
        thresholds = jnp.full((n_levels,), float(DELTA - 3), jnp.float32)
        fn = jax.jit(
            lambda a_, m_: provision_scan(a_, m_, delta=DELTA, horizon=3)
        )
        jax.block_until_ready(fn(a, thresholds))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(a, thresholds))
        us = (time.perf_counter() - t0) / 3 * 1e6
        mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
        rows.append(
            f"pallas_scan_{mode}_levels{n_levels},{us:.1f},"
            f"decisions_per_s={n_levels * a.shape[0] / (us / 1e6):.3e}"
        )


def _mesh_grid_spec(n_levels, n_traces, n_windows, n_stds, n_slots, mesh,
                    use_pallas=True):
    ab = np.stack([_trace(n_levels, seed=s, n_slots=n_slots)
                   for s in range(n_traces)])
    noise = PredictionNoise(
        std_frac=jnp.linspace(0.0, 0.4, n_stds), key=jax.random.key(5)
    )
    return ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=jnp.asarray(ab, jnp.int32), noise=noise),
        policy=PolicySpec("A3", windows=jnp.arange(n_windows, dtype=jnp.int32),
                          key=jax.random.key(0)),
        n_levels=n_levels,
        mesh=mesh,
        use_pallas=use_pallas,
    )


def mesh_grid_throughput(rows: list[str], n_levels=256, n_traces=8,
                         n_windows=4, n_stds=2, n_slots=N_SLOTS) -> None:
    """The sharded fleet path on the full (S, W, B) grid: fused Pallas grid
    kernel vs the sharded lax.scan body on identical cells (A3, so the wait
    tables ride along too).  Off-TPU the kernel row is interpret-mode (CPU
    emulation) — the derived decisions/s is the comparable number."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
    for tag, use_pallas in ((f"pallas_{mode}", True), ("lax_scan", False)):
        spec = _mesh_grid_spec(n_levels, n_traces, n_windows, n_stds, n_slots,
                               mesh, use_pallas=use_pallas)
        def fn():
            return provision(spec).cost

        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 3 * 1e6
        cells = n_stds * n_windows * n_traces * n_levels * n_slots
        rows.append(
            f"mesh_grid_{tag}_s{n_stds}_w{n_windows}_b{n_traces}_n{n_levels},"
            f"{us:.1f},decisions_per_s={cells / (us / 1e6):.3e}"
        )


def mesh_grid_compile_gate(rows: list[str], n_levels=48, n_slots=168) -> None:
    """One mesh-path grid cell as a smoke gate: the sharded engine body
    (`_sharded_grid`) must compile exactly once for the (S, W, B) program
    and a warmed re-run must add nothing — mirroring the `_run` guard."""
    from repro.core.jax_provision import _sharded_grid

    if not CompileWatcher(fns=(_sharded_grid,)).available:
        rows.append("mesh_grid_compiles,0.0,skipped=no_cache_size_api")
        return
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    spec = _mesh_grid_spec(n_levels, 2, 2, 2, n_slots, mesh)
    # one gated implementation (repro.lint.sanitize) instead of hand-rolled
    # cache deltas: cold run compiles exactly one program, warm run zero
    with tracer_sanitizer(fns=(_sharded_grid,), exact_compiles=1) as cold:
        jax.block_until_ready(provision(spec).cost)
    with tracer_sanitizer(fns=(_sharded_grid,), exact_compiles=0) as warm:
        jax.block_until_ready(provision(spec).cost)  # warmed re-run
    rows.append(
        f"mesh_grid_compiles,0.0,cold={cold.added};warm_added={warm.added}"
    )


def deferral_cost_vs_slack(rows: list[str], n_levels=256,
                           slacks=(0, 2, 6, 12)) -> None:
    """The defer-then-provision path: provisioning cost as a function of the
    granted queueing slack, one row per slack.  Slack is pytree data (the
    specs share ``max_slack``), so the whole curve reuses one compiled
    program; the widest-slack schedule must not cost more than rigid."""
    from repro.deferral import DeferralSpec

    a = _trace(n_levels)
    max_slack = max(slacks)
    curve = []
    for slack in slacks:
        spec = ProvisionSpec(
            costs=COSTS,
            workload=Workload(
                demand=jnp.asarray(a, jnp.int32),
                deferral=DeferralSpec(slack=slack, max_slack=max_slack),
            ),
            policy=PolicySpec("A1", window=2),
            n_levels=n_levels,
        )
        res = provision(spec)
        jax.block_until_ready(res.cost)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(provision(spec).cost)
        us = (time.perf_counter() - t0) / 3 * 1e6
        curve.append(float(res.cost))
        rows.append(
            f"deferral_slack{slack}_n{n_levels},{us:.1f},"
            f"cost={curve[-1]:.1f};p99={int(res.p99_delay)};"
            f"miss={int(res.deadline_misses)}"
        )
    assert curve[-1] <= curve[0], (
        f"deferral bought nothing: rigid costs {curve[0]:.1f}, "
        f"slack={slacks[-1]} costs {curve[-1]:.1f}"
    )


def brick_simulator_throughput(rows: list[str]) -> None:
    rng = np.random.default_rng(1)
    tr = generate_brick_trace(rng, horizon=2000.0, rate=3.0, mean_duration=4.0)
    t0 = time.perf_counter()
    simulate(tr, A1Deterministic(alpha=0.5), COSTS)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"brick_sim_{len(tr.jobs)}jobs,{us:.1f},"
        f"events_per_s={2 * len(tr.jobs) / (us / 1e6):.3e}"
    )


def jit_cache_reuse(rows: list[str]) -> None:
    """Re-pricing the fleet must hit the compiled program, not rebuild it.

    The spec's cost fields are pytree leaves; only (policy, shapes, Δ's
    static scan bound) key the jit cache.  A regression here (e.g. a field
    accidentally made a meta/static) blows the cache up per price point.
    """
    from repro.core.jax_provision import _run

    if not CompileWatcher(fns=(_run,)).available:
        rows.append("jit_cache_repricing,0.0,skipped=no_cache_size_api")
        return
    a = _trace(32, n_slots=160)
    # vary the price point but keep ceil(max Delta) fixed (it IS a shape key)
    with tracer_sanitizer(fns=(_run,), max_compiles=1) as watch:
        for beta in (2.6, 2.75, 2.9, 3.0):
            spec = _spec(a, 32, costs=CostModel(P=1.0, beta_on=beta, beta_off=beta))
            jax.block_until_ready(provision(spec).cost)
    rows.append(f"jit_cache_repricing,0.0,entries_added={watch.added}")


def telemetry_overhead(rows: list[str]) -> None:
    """The observability layer's zero-overhead contract, as a smoke gate.

    With a live telemetry registry installed, re-running the warmed default
    path must add 0 compiled programs (spans are host-side; ``record`` is a
    static jit arg that defaults off, so the default jaxpr is unchanged) —
    and turning ``record_decisions=True`` on must leave the schedule
    bit-exact (provenance is extra scan outputs, never a decision input).
    """
    from repro.core.jax_provision import _run

    a = _trace(32, n_slots=160)
    spec = _spec(a, 32)
    base = np.asarray(jax.block_until_ready(provision(spec).x))   # warm
    with telemetry_session():
        # zero-compile gate on the warmed default path, leak checking on
        with tracer_sanitizer(fns=(_run,)) as watch:
            lit = np.asarray(jax.block_until_ready(provision(spec).x))
    assert (lit == base).all(), "telemetry changed the schedule"
    rec = provision(spec, record_decisions=True)
    assert np.array_equal(np.asarray(rec.x), base), (
        "record_decisions=True changed the schedule"
    )
    assert rec.decisions is not None
    rows.append(
        f"telemetry_overhead,0.0,extra_compiles={max(watch.added, 0)};"
        "record_bitexact=1"
    )


def run(rows: list[str]) -> None:
    jax_provisioner_throughput(rows)
    batched_sweep_throughput(rows)
    heterogeneous_throughput(rows)
    typed_fleet_throughput(rows)
    pallas_scan_throughput(rows)
    mesh_grid_throughput(rows)
    deferral_cost_vs_slack(rows)
    brick_simulator_throughput(rows)
    jit_cache_reuse(rows)
    mesh_grid_compile_gate(rows)
    telemetry_overhead(rows)


def run_smoke(rows: list[str]) -> None:
    """CI leg: small shapes, every code path, plus the jit-cache assertions
    (re-pricing must not recompile; the mesh grid compiles exactly once;
    telemetry adds zero compiles to the disabled path)."""
    jax_provisioner_throughput(rows, sizes=(64,))
    batched_sweep_throughput(rows, n_levels=32, n_traces=4)
    heterogeneous_throughput(rows, n_levels=32)
    typed_fleet_throughput(rows, n_total=32)
    pallas_scan_throughput(rows, sizes=(128,))
    mesh_grid_throughput(rows, n_levels=32, n_traces=2, n_windows=2, n_stds=2,
                         n_slots=160)
    deferral_cost_vs_slack(rows, n_levels=32, slacks=(0, 4))
    jit_cache_reuse(rows)
    mesh_grid_compile_gate(rows)
    telemetry_overhead(rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + jit-cache assertion (CI)")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="write a jax.profiler trace of the run to DIR")
    args = ap.parse_args()
    rows: list[str] = []
    with profile_to(args.profile):
        (run_smoke if args.smoke else run)(rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
