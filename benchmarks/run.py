"""Benchmark harness: one module per paper table/figure + substrate benches.

Prints ``name,us_per_call,derived`` CSV lines.  Roofline terms for the
(arch x shape) cells come from the dry-run artifacts (see
``python -m repro.launch.dryrun`` and ``python -m repro.launch.roofline``).

Runs the same either way::

    PYTHONPATH=src python -m benchmarks.run      # package form
    PYTHONPATH=src python benchmarks/run.py      # script form

The script form has no parent package, so the relative ``from . import``
raises ImportError there; the fallback puts this directory on ``sys.path``
and imports the sibling modules absolutely (they only import ``repro.*``
themselves, so both routes load identical code).

``--profile DIR`` wraps the whole run in ``jax.profiler.trace`` (view with
TensorBoard's profile plugin or Perfetto).
"""
from __future__ import annotations

import argparse
import pathlib
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="write a jax.profiler trace of the run to DIR")
    args = ap.parse_args()

    try:
        from . import kernel_bench, paper_figs, provision_bench
    except ImportError:  # script form: no parent package for `from .`
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
        import kernel_bench
        import paper_figs
        import provision_bench

    from repro.obs.jaxwatch import profile_to

    rows: list[str] = []
    with profile_to(args.profile):
        paper_figs.run(rows)
        provision_bench.run(rows)
        kernel_bench.run(rows)

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    print(f"# {len(rows)} benchmark rows", file=sys.stderr)


if __name__ == "__main__":
    main()
