"""Benchmark harness: one module per paper table/figure + substrate benches.

Prints ``name,us_per_call,derived`` CSV lines.  Roofline terms for the
(arch x shape) cells come from the dry-run artifacts (see
``python -m repro.launch.dryrun`` and ``python -m repro.launch.roofline``).
"""
from __future__ import annotations

import sys


def main() -> None:
    rows: list[str] = []
    from . import kernel_bench, paper_figs, provision_bench

    paper_figs.run(rows)
    provision_bench.run(rows)
    kernel_bench.run(rows)

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    print(f"# {len(rows)} benchmark rows", file=sys.stderr)


if __name__ == "__main__":
    main()
