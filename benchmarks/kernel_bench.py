"""Kernel microbenchmarks (interpret-mode correctness timing on CPU; the
useful derived number is the achieved-vs-roofline arithmetic on TPU specs).

Runs as part of ``benchmarks/run.py`` or standalone::

    PYTHONPATH=src python benchmarks/kernel_bench.py           # all sections
    PYTHONPATH=src python benchmarks/kernel_bench.py --smoke   # long-trace
                                                               # section only,
                                                               # CI sizes

The long-trace section (:func:`provision_stream_long`) is the
production-length axis of the perf trajectory: the chunked double-buffered
streaming kernel against the monolithic prefetch-all grid kernel on an
overlapping size (bit-exact, asserted), then streaming-only rows at
T = 10^6 slots and a 10^4-lane fleet — sizes where the monolithic layout's
O(B·T) scalar prefetch is unrepresentable.  Each row carries the
per-slot decision latency and both layouts' working-set estimates, so the
memory win is explicit in BENCH.  ``--smoke`` shrinks T/N for CI; the keys
are stable either way and ``bench_diff.py`` treats all wall-clock columns
as informational, never gated.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import flash_attention_ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _bench(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def flash_roofline(rows: list[str]) -> None:
    """Analytic roofline occupancy for the flash kernel tiling."""
    for s, hd, bq, bk in ((4096, 128, 512, 512), (32768, 128, 512, 1024)):
        flops = 4 * s * s * hd / 2          # causal
        hbm = 3 * s * hd * 2 + s * hd * 2   # q,k,v read + o write (bf16)
        t_c = flops / PEAK_FLOPS
        t_m = hbm / HBM_BW
        ai = flops / hbm
        vmem = (bq * hd + 2 * bk * hd + bq * bk) * 4 + bq * (hd + 2) * 4
        rows.append(
            f"flash_roofline_s{s},0.0,"
            f"ai={ai:.0f};compute_us={t_c * 1e6:.1f};mem_us={t_m * 1e6:.1f};"
            f"vmem_bytes={vmem};bound={'compute' if t_c > t_m else 'memory'}"
        )


def decode_roofline(rows: list[str]) -> None:
    for s, kvh, hd, b in ((32768, 8, 128, 128), (524288, 5, 64, 1)):
        cache_bytes = 2 * b * s * kvh * hd * 2
        flops = 4 * b * s * kvh * hd  # q.k + p.v per kv head group
        t_m = cache_bytes / HBM_BW
        t_c = flops / PEAK_FLOPS
        rows.append(
            f"decode_roofline_s{s},0.0,"
            f"cache_gb={cache_bytes / 1e9:.2f};mem_us={t_m * 1e6:.1f};"
            f"compute_us={t_c * 1e6:.1f};bound=memory"
        )


def provision_grid_vs_lax_scan(rows: list[str]) -> None:
    """Batched (S, W, B) provisioning grid: the fused Pallas grid kernel
    (one program per (cell, level block), interpret mode off-TPU) against
    the vmapped lax.scan engine on identical cells — same A1 thresholds,
    same per-window peek horizons, bit-identical output (asserted)."""
    from repro.core.jax_provision import _on_matrix_scan
    from repro.kernels.provision_scan import provision_scan_grid

    S, W, B, T, N = 2, 3, 2, 256, 128
    delta, max_w = 6, 2
    rng = np.random.default_rng(0)
    ab = jnp.asarray(rng.integers(0, N, size=(B, T)), jnp.int32)
    pred = jnp.asarray(
        np.stack([rng.integers(0, N, size=(B, T)) for _ in range(S)]), jnp.int32
    ).reshape(S * B, T)
    windows = jnp.arange(W, dtype=jnp.float32)
    thr = jnp.broadcast_to(                                      # (W, 1, N)
        jnp.maximum(0.0, float(delta) - windows - 1.0)[:, None, None], (W, 1, N)
    )
    hor = jnp.broadcast_to(                                      # (W, N)
        jnp.minimum(windows + 1.0, float(delta))[:, None], (W, N)
    )
    s_ix, w_ix, b_ix = jnp.meshgrid(
        jnp.arange(S), jnp.arange(W), jnp.arange(B), indexing="ij"
    )
    cells = (
        b_ix.reshape(-1), (s_ix * B + b_ix).reshape(-1),
        w_ix.reshape(-1), w_ix.reshape(-1),
    )

    kernel_fn = jax.jit(lambda: provision_scan_grid(
        ab, pred, thr, *cells, delta=delta, horizon=max_w + 1,
        level_horizon=hor,
    ))

    levels = jnp.arange(N)

    def per_cell(bi, pi, wi):
        return _on_matrix_scan(
            ab[bi], pred[pi], levels, delta=float(delta), max_h=delta,
            window=windows[wi], policy="A1",
        )

    scan_fn = jax.jit(lambda: jax.vmap(per_cell)(cells[0], cells[1], cells[2]))

    got, want = kernel_fn(), scan_fn()
    assert (np.asarray(got) == np.asarray(want)).all(), "grid kernel != lax.scan"
    cells_n = S * W * B * T * N
    mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
    for tag, fn in ((f"pallas_{mode}", kernel_fn), ("lax_scan", scan_fn)):
        us = _bench(fn)
        rows.append(
            f"provision_grid_{tag}_s{S}w{W}b{B}n{N},{us:.1f},"
            f"decisions_per_s={cells_n / (us / 1e6):.3e}"
        )


def provision_grid_routed(rows: list[str]) -> None:
    """Typed-fleet block packing: the same (W, B) grid through the kernel's
    group-aligned routed layout (scalar-prefetch route lanes, pad lanes
    carrying the sentinel id) vs the contiguous single-type layout — the
    routing must be pure lane relabeling, bit-identical after compaction."""
    from repro.core.jax_provision import _group_layout
    from repro.kernels.provision_scan import provision_scan_grid

    W, B, T = 2, 2, 256
    group_sizes = (24, 40)                        # d=2 typed fleet, n=64
    n = sum(group_sizes)
    delta, max_w = 6, 2
    rng = np.random.default_rng(1)
    ab = jnp.asarray(rng.integers(0, n, size=(B, T)), jnp.int32)
    windows = jnp.arange(W, dtype=jnp.float32)
    thr1 = jnp.maximum(0.0, float(delta) - windows - 1.0)        # (W,)
    hor1 = jnp.minimum(windows + 1.0, float(delta))              # (W,)
    w_ix, b_ix = jnp.meshgrid(jnp.arange(W), jnp.arange(B), indexing="ij")
    cells = (b_ix.reshape(-1), b_ix.reshape(-1),
             w_ix.reshape(-1), w_ix.reshape(-1))

    route_np, sel_np, n_layout = _group_layout(n, group_sizes, 1)
    sel = jnp.asarray(sel_np)
    thr_l = jnp.zeros((W, 1, n_layout)).at[:, :, sel].set(
        jnp.broadcast_to(thr1[:, None, None], (W, 1, n))
    )
    hor_l = jnp.zeros((W, n_layout)).at[:, sel].set(
        jnp.broadcast_to(hor1[:, None], (W, n))
    )

    contig = jax.jit(lambda: provision_scan_grid(
        ab, ab, jnp.broadcast_to(thr1[:, None, None], (W, 1, n)), *cells,
        delta=delta, horizon=max_w + 1,
        level_horizon=jnp.broadcast_to(hor1[:, None], (W, n)),
    ))
    routed = jax.jit(lambda: provision_scan_grid(
        ab, ab, thr_l, *cells, delta=delta, horizon=max_w + 1,
        level_horizon=hor_l, routes=jnp.asarray(route_np),
    ))

    got, want = np.asarray(routed())[..., sel_np], np.asarray(contig())
    assert (got == want).all(), "routed grid kernel != contiguous layout"
    cells_n = W * B * T
    mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
    for tag, fn, lanes in ((f"contig_{mode}", contig, n),
                           (f"routed_{mode}", routed, n_layout)):
        us = _bench(fn)
        rows.append(
            f"provision_grid_{tag}_w{W}b{B}n{lanes},{us:.1f},"
            f"decisions_per_s={cells_n * lanes / (us / 1e6):.3e}"
        )


def interpret_correctness(rows: list[str]) -> None:
    """Tiny interpret-mode run vs oracle (wall time = CPU emulation only)."""
    from repro.kernels.flash_attention import flash_attention

    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    us = _bench(
        lambda a, b, c: flash_attention(a, b, c, causal=True, block_q=128,
                                        block_k=128, interpret=True),
        q, k, v, iters=1,
    )
    err = float(
        jnp.max(jnp.abs(
            flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                            interpret=True)
            - flash_attention_ref(q, k, v, causal=True)
        ))
    )
    rows.append(f"flash_interpret_256,{us:.1f},max_err={err:.2e}")


def provision_stream_long(rows: list[str], *, full: bool = False) -> None:
    """Production-length traces through the chunked streaming kernel.

    One row per (T, N, layout): ``us_per_call`` plus ``decisions_per_s``,
    per-slot latency ``slot_ns`` and the working-set estimates
    ``mem_stream_bytes`` (2 trace tiles x double buffer + per-level carry)
    vs ``mem_monolithic_bytes`` (the prefetch-all layout's whole-trace
    residency) — O(T_chunk) against O(T).  The overlapping size runs both
    kernels and asserts bit-identical replica counts before timing.
    """
    from repro.kernels.provision_scan import (
        provision_scan_grid,
        provision_scan_stream,
    )

    t_chunk = 4096
    T_cmp = 65_536 if full else 8_192
    T_long = 1_000_000 if full else 65_536
    N_wide = 10_000 if full else 2_048
    N = 128
    delta, horizon = 6, 2
    mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
    rng = np.random.default_rng(7)
    z = jnp.zeros((1,), jnp.int32)

    def mem(T, n, tc):
        # demand + predicted rows (int32): tiles x double buffer streaming,
        # whole-trace residency monolithic; carry is per-level either way
        return 2 * 2 * tc * 4 + 3 * n * 4, 2 * T * 4

    def stream_fn(a, thr, tc):
        return jax.jit(lambda a: provision_scan_stream(
            a, a, thr, z, z, z, z, horizon=horizon, t_chunk=tc)[0])

    # --- overlapping size: monolithic vs streaming, bit-exact then timed
    a = jnp.asarray(rng.integers(0, N, size=(1, T_cmp)), jnp.int32)
    thr = jnp.full((1, 1, N), float(delta) - 1.0, jnp.float32)
    mono = jax.jit(lambda a: provision_scan_grid(
        a, a, thr, z, z, z, z, delta=delta, horizon=horizon))
    strm = stream_fn(a, thr, t_chunk)
    x_mono = np.asarray(mono(a)).sum(-1)
    x_strm = np.asarray(strm(a))
    assert (x_strm == x_mono).all(), "streaming kernel != monolithic grid"
    m_s, m_m = mem(T_cmp, N, t_chunk)
    for tag, fn, m in ((f"mono_{mode}", mono, m_m),
                       (f"stream_{mode}", strm, m_s)):
        us = _bench(lambda: fn(a))
        rows.append(
            f"provision_long_{tag}_t{T_cmp}n{N},{us:.1f},"
            f"decisions_per_s={T_cmp * N / (us / 1e6):.3e};"
            f"slot_ns={us * 1e3 / T_cmp:.1f};trace_bytes={m}"
        )

    # --- streaming-only sizes the monolithic layout cannot hold
    for tag, T, n in ((f"stream_{mode}_long", T_long, N),
                      (f"stream_{mode}_wide", 8_192, N_wide)):
        a = jnp.asarray(rng.integers(0, n, size=(1, T)), jnp.int32)
        thr = jnp.full((1, 1, n), float(delta) - 1.0, jnp.float32)
        fn = stream_fn(a, thr, t_chunk)
        us = _bench(lambda: fn(a), iters=1)
        m_s, m_m = mem(T, n, t_chunk)
        rows.append(
            f"provision_long_{tag}_t{T}n{n},{us:.1f},"
            f"decisions_per_s={T * n / (us / 1e6):.3e};"
            f"slot_ns={us * 1e3 / T:.1f};"
            f"mem_stream_bytes={m_s};mem_monolithic_bytes={m_m}"
        )


def run(rows: list[str], *, long_full: bool = False) -> None:
    flash_roofline(rows)
    decode_roofline(rows)
    interpret_correctness(rows)
    provision_grid_vs_lax_scan(rows)
    provision_grid_routed(rows)
    provision_stream_long(rows, full=long_full)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="long-trace section only, CI-sized T/N")
    args = ap.parse_args(argv)
    rows: list[str] = []
    if args.smoke:
        provision_stream_long(rows, full=False)
    else:
        run(rows, long_full=True)
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    print(f"# {len(rows)} benchmark rows", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
