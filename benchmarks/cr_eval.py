"""Competitive-ratio evaluation CLI: the paper's claims as a JSON artifact.

Runs ``repro.eval.evaluate`` over the scenario library and writes the
:class:`~repro.eval.report.EvalReport` to ``BENCH_provision.json`` — the
repo's provisioning-quality trajectory (CI uploads it per commit).

    PYTHONPATH=src python benchmarks/cr_eval.py --smoke   # CI leg, ~30 s
    PYTHONPATH=src python benchmarks/cr_eval.py           # full grid
    PYTHONPATH=src python benchmarks/cr_eval.py --profile /tmp/prof

The smoke leg also runs under a live :mod:`repro.obs.telemetry` registry
and drops two sidecar artifacts next to the report (CI uploads all three):
``BENCH_provision.trace.json`` — a Chrome trace of the harness spans +
compile events, viewable at https://ui.perfetto.dev — and
``BENCH_provision.metrics.jsonl`` — the counters/gauges/histogram
summaries, one JSON record per line.  ``--profile DIR`` additionally wraps
the run in ``jax.profiler.trace``.

Both legs hard-fail if any (policy, scenario, noise, α) cell's empirical CR
violates its paper bound beyond the grid tolerance, or if re-running the
grid recompiles anything (the whole grid must execute as warmed batched
device programs — one program per (policy, scenario), shapes shared across
scenarios).  Both grids carry ``TYPED_GROUPS`` — a two-generation
heterogeneous fleet — so every run also records multi-type AQ-det/AQ-rand
cells with per-type CR verdicts, gated against the Albers–Quedenfeld 2d
(and d·e/(e−1)) aggregate bounds.  Both grids also sweep
``DEFERRAL_SLACKS``: deferral cells run the defer-then-provision path and
are gated on the latency-SLO verdict (``slo_ok`` — zero deadline misses,
p99 queueing delay within the granted slack) on top of the CR bound.

Both legs also record the v5 ``streaming`` section
(:func:`streaming_latency`): the ``FleetProvisioner.advance()`` stepper
driven at T_chunk ∈ {1, 64, 1024}, its plan-latency p50/p99 from the
``PlanMetrics`` substrate, and a hard gate that the warmed loop adds zero
jit traces (the O(1)-state stepper's steady-state claim).  The latency
columns are machine facts — ``bench_diff.py`` diffs them informationally,
never gated.
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys

from repro.core import ServerGroup
from repro.eval import EvalGrid, EvalReport, evaluate
from repro.lint.sanitize import tracer_sanitizer
from repro.obs import (
    Telemetry,
    install_monitoring,
    profile_to,
    telemetry_session,
)
from repro.scenarios import Scenario

#: the benchmark's heterogeneous fleet: two server generations (Albers–
#: Quedenfeld d=2).  "efficient" is the paper's normalized server; "legacy"
#: burns 1.5× the power with proportionally pricier toggles (same Δ, so the
#: per-type ski-rental structure is identical and only routing differs).
TYPED_GROUPS = (
    ServerGroup("efficient", 96, P=1.0, beta_on=3.0, beta_off=3.0),
    ServerGroup("legacy", 96, P=1.5, beta_on=4.5, beta_off=4.5),
)

#: the deferral-slack sweep (slots): 0 is the rigid fixed point (bit-exact
#: with no deferral at all), the rest trace the cost-vs-slack curve
DEFERRAL_SLACKS = (0, 2, 6, 12)

#: the serving-loop chunk sizes the streaming section measures — one slot
#: at a time (the latency floor), a typical scrape interval, and a bulk
#: backfill chunk
STREAM_CHUNKS = (1, 64, 1024)

SMOKE_GRID = EvalGrid(
    noise_stds=(0.0, 0.2),
    windows=(0, 2, 4),
    n_traces=4,
    n_slots=288,
    typed_groups=TYPED_GROUPS,
    deferral_slacks=DEFERRAL_SLACKS,
)

FULL_GRID = EvalGrid(
    noise_stds=(0.0, 0.1, 0.25, 0.5),
    windows=(0, 1, 2, 3, 4, 5),
    n_traces=16,
    typed_groups=TYPED_GROUPS,
    deferral_slacks=DEFERRAL_SLACKS,
)


def mesh_smoke() -> None:
    """One mesh-path grid cell through ``evaluate``: the sharded Pallas
    fleet engine must reproduce the lax.scan cells bit-exactly AND compile
    exactly one ``_sharded_grid`` program for the whole (policy, scenario)
    block — the fleet-path analogue of the existing no-recompile gates."""
    import jax

    from repro.core.jax_provision import _sharded_grid

    grid = EvalGrid(
        policies=("A1",),
        scenarios=(Scenario("sinusoidal", target_pmr=4.0, mean_jobs=16.0),),
        noise_stds=(0.0, 0.2),
        windows=(0, 2),
        n_traces=2,
        n_slots=144,
    )
    plain = evaluate(grid)
    # the gated sanitizer raises RecompileError unless the whole block
    # compiled exactly one _sharded_grid program (degrades silently when
    # the private cache API is gone, like the hand-rolled delta it replaced)
    with tracer_sanitizer(fns=(_sharded_grid,), exact_compiles=1):
        meshed = evaluate(dataclasses.replace(
            grid, mesh=jax.make_mesh((len(jax.devices()),), ("data",))
        ))
    if meshed.cells != plain.cells:
        raise AssertionError(
            "mesh-path eval cells diverge from the lax.scan path: the "
            "Pallas fleet engine is supposed to be bit-exact"
        )
    print(
        f"# mesh smoke: {len(meshed.cells)} cells bit-exact through the "
        "fleet path, 1 sharded compile", file=sys.stderr,
    )


def streaming_latency(smoke: bool) -> list:
    """The v5 ``streaming`` section: drive ``FleetProvisioner.advance()``
    at each ``STREAM_CHUNKS`` size over one demand stream, record the
    stepper's plan-latency p50/p99 through the ``PlanMetrics`` substrate,
    and gate the zero-steady-state-recompile claim — after the warmup call
    owns the chunk bucket's trace, the measured loop must add no jit
    entries at all."""
    import numpy as np

    from repro.core.costs import PAPER_COSTS
    from repro.eval.report import StreamingRow
    from repro.serving import stepper
    from repro.serving.autoscaler import FleetProvisioner
    from repro.serving.metrics import PlanMetrics

    rows = []
    rng = np.random.default_rng(0)
    for t_chunk in STREAM_CHUNKS:
        chunks = min(32, max(4, (256 if smoke else 8192) // t_chunk))
        demand = rng.integers(0, 48, size=((chunks + 1) * t_chunk,))
        prov = FleetProvisioner(PAPER_COSTS, policy="A1", max_replicas=64)
        prov.advance(demand[:t_chunk])      # warmup owns the bucket's trace
        prov.metrics = PlanMetrics()
        # hard zero-recompile gate on the warmed steady state (RecompileError
        # on violation), while watch.added still feeds the report row
        with tracer_sanitizer(fns=(stepper.stepper_chunk,)) as watch:
            for i in range(1, chunks + 1):
                prov.advance(demand[i * t_chunk:(i + 1) * t_chunk])
        rows.append(StreamingRow(
            policy="A1", t_chunk=t_chunk, chunks=chunks,
            slots=chunks * t_chunk, compiles=watch.added,
            p50_ms=prov.metrics.latency_quantile(0.5),
            p99_ms=prov.metrics.latency_quantile(0.99),
        ))
    print(
        "# streaming: " + "; ".join(
            f"t_chunk={r.t_chunk} p50={r.p50_ms:.2f}ms p99={r.p99_ms:.2f}ms "
            f"compiles={r.compiles}" for r in rows
        ),
        file=sys.stderr,
    )
    return rows


def run(grid: EvalGrid, out: pathlib.Path, check_warm: bool = True,
        streaming: list | None = None) -> EvalReport:
    report = evaluate(grid)
    report.streaming = streaming
    try:
        if check_warm:
            # the grid again, same shapes: every cell must hit the jit cache
            second = evaluate(grid)
            if second.jit_entries_added > 0:
                raise AssertionError(
                    f"warmed re-run recompiled {second.jit_entries_added} "
                    "program(s): a spec field leaked into the compile keys"
                )
        if report.jit_entries_added > report.expected_compiles:
            raise AssertionError(
                f"{report.jit_entries_added} compiles for "
                f"{len(report.grid['policies'])} policies — expected at most "
                f"{report.expected_compiles} (one per policy + offline); "
                "per-cell recompiles defeat the batched harness"
            )
        if not report.bounds_ok:
            lines = "\n".join(
                f"  {c.policy} on {c.scenario} (std={c.noise_std:g}, w={c.window}): "
                f"mean CR {c.mean_cr:.4f} > bound {c.bound:.4f}"
                for c in report.violations()
            )
            raise AssertionError(f"paper-bound violations:\n{lines}")
        if report.grid.get("typed_groups"):
            d = len(report.grid["typed_groups"])
            det = [c for c in report.cells
                   if c.group_mean_cr is not None and c.policy == "AQ-det"]
            if not det:
                raise AssertionError(
                    "grid declares typed_groups but produced no AQ-det "
                    "multi-type cell"
                )
            off = [c for c in det if c.bound != 2.0 * d]
            if off:
                raise AssertionError(
                    f"AQ-det typed cells must carry the 2d = {2.0 * d:g} "
                    f"aggregate bound, got {sorted({c.bound for c in off})}"
                )
        if report.grid.get("deferral_slacks"):
            dcells = [c for c in report.cells if c.slack is not None]
            want = (
                len(report.grid["deferral_slacks"])
                * len(report.grid["deferral_policies"])
                * len(report.grid["scenario_labels"])
            )
            if len(dcells) != want:
                raise AssertionError(
                    f"grid declares deferral_slacks but produced "
                    f"{len(dcells)} deferral cells, expected {want}"
                )
            bad_slo = [c for c in dcells if not c.slo_ok]
            if bad_slo:
                lines = "\n".join(
                    f"  {c.policy} on {c.scenario} slack={c.slack}: "
                    f"p99={c.p99_delay} misses={c.deadline_misses}"
                    for c in bad_slo
                )
                raise AssertionError(f"latency-SLO violations:\n{lines}")
            # the slack axis must actually buy something: per (policy,
            # scenario), the widest-slack cell may not cost more than rigid
            by_ps: dict[tuple, list] = {}
            for c in dcells:
                by_ps.setdefault((c.policy, c.scenario), []).append(c)
            for (policy, scenario), cs in by_ps.items():
                cs = sorted(cs, key=lambda c: c.slack)
                if cs[-1].mean_cost > cs[0].mean_cost:
                    raise AssertionError(
                        f"deferral bought nothing: {policy} on {scenario} "
                        f"costs {cs[0].mean_cost:.1f} rigid but "
                        f"{cs[-1].mean_cost:.1f} at slack={cs[-1].slack}"
                    )
    finally:
        # always leave the report on disk — a gate failure is exactly when
        # the per-cell diagnostics are needed (CI uploads it unconditionally)
        report.save(out)
    return report


def write_telemetry_artifacts(tel: Telemetry, out: pathlib.Path) -> None:
    """Drop the Chrome trace + metrics JSONL next to the report and assert
    both load back (the trace must be Perfetto-openable: a ``traceEvents``
    list with at least the harness's eval spans in it)."""
    import json

    trace_path = out.with_name(out.stem + ".trace.json")
    metrics_path = out.with_name(out.stem + ".metrics.jsonl")
    tel.write_chrome_trace(trace_path)
    tel.write_metrics_jsonl(metrics_path)
    loaded = json.loads(trace_path.read_text())
    events = loaded.get("traceEvents")
    if not isinstance(events, list) or not any(
        e.get("name", "").startswith("eval/") for e in events
    ):
        raise AssertionError(
            f"{trace_path} is not a loadable Chrome trace with eval spans"
        )
    records = [json.loads(line) for line in
               metrics_path.read_text().splitlines() if line]
    if not any(r.get("name", "").startswith("span/eval/") for r in records):
        raise AssertionError(f"{metrics_path} is missing the eval span metrics")
    print(f"# wrote {trace_path} ({len(events)} events) and "
          f"{metrics_path} ({len(records)} records)", file=sys.stderr)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (short traces, fewer cells)")
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path(__file__).parent.parent / "BENCH_provision.json",
                    help="report path (default: repo-root BENCH_provision.json)")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="write a jax.profiler trace of the run to DIR")
    args = ap.parse_args()

    install_monitoring()
    with telemetry_session() as tel, profile_to(args.profile):
        if args.smoke:
            mesh_smoke()
        stream_rows = streaming_latency(smoke=args.smoke)
        report = run(SMOKE_GRID if args.smoke else FULL_GRID, args.out,
                     streaming=stream_rows)
    if args.smoke:
        write_telemetry_artifacts(tel, args.out)
    for line in report.summary_lines():
        print(line)
    worst = report.worst(1)[0]
    print(
        f"# {len(report.cells)} cells ({'smoke' if args.smoke else 'full'}), "
        f"backend={report.backend}, {report.elapsed_s:.1f}s, "
        f"compiles={report.jit_entries_added}/{report.expected_compiles}, "
        f"tightest cell: {worst.policy} on {worst.scenario} "
        f"(mean CR {worst.mean_cr:.4f} vs bound {worst.bound:.4f})",
        file=sys.stderr,
    )
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
