"""Competitive-ratio evaluation CLI: the paper's claims as a JSON artifact.

Runs ``repro.eval.evaluate`` over the scenario library and writes the
:class:`~repro.eval.report.EvalReport` to ``BENCH_provision.json`` — the
repo's provisioning-quality trajectory (CI uploads it per commit).

    PYTHONPATH=src python benchmarks/cr_eval.py --smoke   # CI leg, ~30 s
    PYTHONPATH=src python benchmarks/cr_eval.py           # full grid

Both legs hard-fail if any (policy, scenario, noise, α) cell's empirical CR
violates its paper bound beyond the grid tolerance, or if re-running the
grid recompiles anything (the whole grid must execute as warmed batched
device programs — one program per (policy, scenario), shapes shared across
scenarios).
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys

from repro.eval import EvalGrid, EvalReport, evaluate
from repro.scenarios import Scenario

SMOKE_GRID = EvalGrid(
    noise_stds=(0.0, 0.2),
    windows=(0, 2, 4),
    n_traces=4,
    n_slots=288,
)

FULL_GRID = EvalGrid(
    noise_stds=(0.0, 0.1, 0.25, 0.5),
    windows=(0, 1, 2, 3, 4, 5),
    n_traces=16,
)


def mesh_smoke() -> None:
    """One mesh-path grid cell through ``evaluate``: the sharded Pallas
    fleet engine must reproduce the lax.scan cells bit-exactly AND compile
    exactly one ``_sharded_grid`` program for the whole (policy, scenario)
    block — the fleet-path analogue of the existing no-recompile gates."""
    import jax

    from repro.core.jax_provision import _sharded_grid

    grid = EvalGrid(
        policies=("A1",),
        scenarios=(Scenario("sinusoidal", target_pmr=4.0, mean_jobs=16.0),),
        noise_stds=(0.0, 0.2),
        windows=(0, 2),
        n_traces=2,
        n_slots=144,
    )
    plain = evaluate(grid)
    counted = hasattr(_sharded_grid, "_cache_size")
    before = _sharded_grid._cache_size() if counted else -1
    meshed = evaluate(dataclasses.replace(
        grid, mesh=jax.make_mesh((len(jax.devices()),), ("data",))
    ))
    if meshed.cells != plain.cells:
        raise AssertionError(
            "mesh-path eval cells diverge from the lax.scan path: the "
            "Pallas fleet engine is supposed to be bit-exact"
        )
    if counted:
        grew = _sharded_grid._cache_size() - before
        if grew != 1:
            raise AssertionError(
                f"mesh-path eval compiled {grew} _sharded_grid program(s) "
                "for one (policy, scenario) block — expected exactly 1"
            )
    print(
        f"# mesh smoke: {len(meshed.cells)} cells bit-exact through the "
        "fleet path, 1 sharded compile", file=sys.stderr,
    )


def run(grid: EvalGrid, out: pathlib.Path, check_warm: bool = True) -> EvalReport:
    report = evaluate(grid)
    try:
        if check_warm:
            # the grid again, same shapes: every cell must hit the jit cache
            second = evaluate(grid)
            if second.jit_entries_added > 0:
                raise AssertionError(
                    f"warmed re-run recompiled {second.jit_entries_added} "
                    "program(s): a spec field leaked into the compile keys"
                )
        if report.jit_entries_added > report.expected_compiles:
            raise AssertionError(
                f"{report.jit_entries_added} compiles for "
                f"{len(report.grid['policies'])} policies — expected at most "
                f"{report.expected_compiles} (one per policy + offline); "
                "per-cell recompiles defeat the batched harness"
            )
        if not report.bounds_ok:
            lines = "\n".join(
                f"  {c.policy} on {c.scenario} (std={c.noise_std:g}, w={c.window}): "
                f"mean CR {c.mean_cr:.4f} > bound {c.bound:.4f}"
                for c in report.violations()
            )
            raise AssertionError(f"paper-bound violations:\n{lines}")
    finally:
        # always leave the report on disk — a gate failure is exactly when
        # the per-cell diagnostics are needed (CI uploads it unconditionally)
        report.save(out)
    return report


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (short traces, fewer cells)")
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path(__file__).parent.parent / "BENCH_provision.json",
                    help="report path (default: repo-root BENCH_provision.json)")
    args = ap.parse_args()

    if args.smoke:
        mesh_smoke()
    report = run(SMOKE_GRID if args.smoke else FULL_GRID, args.out)
    for line in report.summary_lines():
        print(line)
    worst = report.worst(1)[0]
    print(
        f"# {len(report.cells)} cells ({'smoke' if args.smoke else 'full'}), "
        f"backend={report.backend}, {report.elapsed_s:.1f}s, "
        f"compiles={report.jit_entries_added}/{report.expected_compiles}, "
        f"tightest cell: {worst.policy} on {worst.scenario} "
        f"(mean CR {worst.mean_cr:.4f} vs bound {worst.bound:.4f})",
        file=sys.stderr,
    )
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
