"""Data pipelines: synthetic token streams (training) and request/session
generators (serving), both deterministic and shardable."""
from .tokens import TokenPipeline, make_token_batch
from .requests import Session, SessionTrace, generate_sessions

__all__ = [
    "TokenPipeline",
    "make_token_batch",
    "Session",
    "SessionTrace",
    "generate_sessions",
]
