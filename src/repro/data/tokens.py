"""Synthetic LM token pipeline: deterministic, seekable, dp-shardable.

A real deployment swaps this for a file-backed loader; the interface —
``batch_at(step)`` returning the globally-consistent batch for a step — is
what the fault-tolerant trainer depends on (restart at step k reproduces the
exact stream, no data loss/duplication across restarts or elastic resizes).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

_ZIPF_EXPONENT = 1.1
_zipf_cdf_cache: dict[int, np.ndarray] = {}


def _zipf_tokens(rng: np.random.Generator, vocab: int, shape: tuple) -> np.ndarray:
    """Zipf-distributed token ids: p(k) ~ 1/(k+2)^s.

    Uniform tokens carry zero learnable signal (the loss floor is log(V) and
    any training step is pure noise), so convergence tests were measuring the
    optimizer's random walk.  A Zipfian unigram stream gives the model real
    structure to learn while keeping batch_at(step) pure and seekable.
    """
    cdf = _zipf_cdf_cache.get(vocab)
    if cdf is None:
        p = 1.0 / np.power(np.arange(vocab, dtype=np.float64) + 2.0, _ZIPF_EXPONENT)
        cdf = np.cumsum(p / p.sum())
        _zipf_cdf_cache[vocab] = cdf
    # the float64 CDF endpoint can land just below 1.0, in which case a draw
    # above it would index one past the vocabulary — clamp to the last id
    ids = np.searchsorted(cdf, rng.uniform(size=shape))
    return np.minimum(ids, vocab - 1).astype(np.int64)


def make_token_batch(cfg: ModelConfig, rng: np.random.Generator, batch: int,
                     seq: int) -> dict:
    """One host-side random batch (smoke tests / examples)."""
    out: dict = {}
    if cfg.frontend == "vision_stub":
        nf = cfg.n_frontend_tokens
        out["tokens"] = jnp.asarray(
            _zipf_tokens(rng, cfg.vocab_size, (batch, seq - nf)), jnp.int32
        )
        out["frontend"] = jnp.asarray(
            rng.standard_normal((batch, nf, cfg.d_model)), jnp.bfloat16
        )
    elif cfg.frontend == "audio_stub":
        out["tokens"] = jnp.asarray(
            _zipf_tokens(rng, cfg.vocab_size, (batch, seq)), jnp.int32
        )
        out["frontend"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)), jnp.bfloat16
        )
    else:
        out["tokens"] = jnp.asarray(
            _zipf_tokens(rng, cfg.vocab_size, (batch, seq)), jnp.int32
        )
    return out


@dataclasses.dataclass
class TokenPipeline:
    """Deterministic step-indexed stream: batch_at(step) is pure."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        return make_token_batch(self.cfg, rng, self.batch, self.seq)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
