"""Serving request/session generators.

Sessions are the paper's "elephant" jobs: a session occupies one replica slot
for its entire lifetime (its KV cache pins it — no migration).  The generator
produces a BrickTrace-compatible session stream whose concurrency profile
follows a fluid trace (e.g. the MSR-like weekly workload), so the paper's
experiments drive the serving cluster directly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.events import BrickTrace, Job
from repro.core.traces import brick_trace_from_fluid, msr_like_trace


@dataclasses.dataclass
class Session:
    session_id: int
    arrival: float
    departure: float          # known only to the simulator, not the policy
    prompt_tokens: int = 64
    max_new_tokens: int = 128


@dataclasses.dataclass
class SessionTrace:
    sessions: list[Session]
    horizon: float

    def to_brick(self) -> BrickTrace:
        return BrickTrace(
            [Job(s.arrival, s.departure) for s in self.sessions], self.horizon
        )


def generate_sessions(
    rng: np.random.Generator,
    n_slots: int = 200,
    mean_concurrency: float = 8.0,
    prompt_tokens: int = 64,
    max_new_tokens: int = 128,
) -> SessionTrace:
    """Session stream whose concurrency follows an MSR-like fluid trace."""
    a = msr_like_trace(rng, n_slots=n_slots, mean_jobs=mean_concurrency)
    brick = brick_trace_from_fluid(a, rng)
    sessions = [
        Session(
            session_id=i,
            arrival=j.arrival,
            departure=j.departure,
            prompt_tokens=int(rng.integers(prompt_tokens // 2, prompt_tokens * 2)),
            max_new_tokens=int(rng.integers(max_new_tokens // 2, max_new_tokens * 2)),
        )
        for i, j in enumerate(brick.jobs)
    ]
    return SessionTrace(sessions=sessions, horizon=brick.horizon)
