"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile to Mosaic.  ``interpret`` is auto-detected from the default backend.
"""
from __future__ import annotations

import functools

import jax

from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=512, block_k=512):
    return _flash(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=_on_cpu(),
    )


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, lengths, *, block_k=1024):
    return _decode(
        q, k_cache, v_cache, lengths,
        block_k=block_k,
        interpret=_on_cpu(),
    )
