"""Version tolerance for Pallas TPU API drift.

``pltpu.TPUCompilerParams`` (jax <= 0.4.x) was renamed to
``pltpu.CompilerParams`` (jax >= 0.5); resolve whichever exists so the
kernels compile under both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

__all__ = ["CompilerParams"]
