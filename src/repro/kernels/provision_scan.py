"""Fused per-level provisioning scan as a Pallas TPU kernel.

The provisioning engine's inner loop (repro.core.jax_provision) is a
sequential scan over slots with an embarrassingly parallel level axis.  For
large fleets the lax.scan path materializes (T, N) intermediates per step;
this kernel fuses the whole scan into one program per (cell, level block):

  grid = (G, N/BN); each program runs ONE sweep cell — a (noise-std,
  window, trace) combination — over its level block, keeping the block's
  state (idle run length, on/off bit, sampled wait threshold) in
  registers/VMEM across all T slots and streaming the on-matrix out row by
  row.  ``G = S*W*B`` covers the full prediction-noise x window x trace
  grid of a :class:`~repro.core.provision.ProvisionSpec` in one launch.

The demand batch ``(B, T)`` and the predicted-trace rows ``(R, T)`` are
scalar-prefetched into SMEM once and *indexed per cell*: four small
``(G,)`` cell maps (also scalar-prefetched) tell each program which demand
row drives its dispatcher compare, which predicted row its peek reads, and
which threshold/horizon table rows it consumes.  The threshold and horizon
tables are blocked into VMEM via scalar-prefetch-driven index maps, so a
program only ever sees its own cell's rows — no HBM traffic beyond those
blocks and the output.

Each lane additionally carries its *routing id* in a blocked ``(1, BN)``
``routes`` row: the dispatcher compares demand against the routed id, not
the lane's storage position.  For a plain fleet the ids are just
``base_level + arange(N)`` (the default), but typed fleets
(``CostModel.from_groups``) store their levels group-aligned — each server
type padded out to its own block boundary so a threshold/horizon block
never straddles two types — and then storage position ≠ level id; the
routes row is what keeps the greedy demand split exact under that packing.
Pad lanes get a sentinel id larger than any demand, so they can never turn
on.

Thresholds are constant rows for the deterministic policies (A1's
``max(0, Δ_l−w−1)`` per window, DELAYEDOFF's and AQ-DET's ``Δ_l``) or
``(T, N)`` tables of sampled waits for A2/A3/AQ-RAND (entry [t, l] is
consumed iff level l becomes newly idle in slot t, matching the engine's
PRNG contract; the table for cell (s, w, b) depends on (w, b) only — noise
sweeps share wait draws — and for the window-free AQ-RAND on b alone).
Heterogeneous fleets give each level its own Δ, hence its own threshold
*and* its own peek reach: ``level_horizon`` rows are per-level floats
masking the statically unrolled ``horizon`` peek to ``min(w+1, Δ_l)``
slots (fractional Δ_l included: slot ``h`` is peeked iff ``h < Δ_l``).

Off-TPU the kernel runs in interpret mode (auto-detected; override with
the ``REPRO_PALLAS_INTERPRET`` env var — see :func:`_resolve_interpret`),
so the sharded fleet path is testable on CPU.

Two kernels share the slot semantics:

  * :func:`provision_scan_grid` — the monolithic layout: whole traces
    scalar-prefetched into SMEM, the on-matrix written as a ``(G, T, BN)``
    VMEM block.  Memory is O(B·T) in SMEM, which caps the horizon long
    before HBM does — fine for planning windows, not for month-long traces.
  * :func:`provision_scan_stream` — the streaming layout: demand/predicted
    rows live in HBM (``pltpu.ANY``) and are pulled in fixed ``t_chunk``
    tiles with double-buffered async copies into SMEM/VMEM scratch; the
    per-level ``(run-length, on-bit, wait)`` state is carried across tiles
    in registers and returned to the caller, so a call's working set is
    O(t_chunk + BN) regardless of T and consecutive calls chain bit-exactly
    via the carry (see docs/provisioning_engine.md "Streaming & long
    traces").
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

DEFAULT_BN = 128     # level-block width (lane dimension)

#: default streaming tile length (slots per double-buffered DMA)
DEFAULT_T_CHUNK = 512


def _resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the Pallas execution route and record it as a telemetry gauge.

    ``None`` consults the ``REPRO_PALLAS_INTERPRET`` env var (truthy
    ``1/true/yes/on`` forces interpret mode, falsy ``0/false/no/off``
    forces the compiled route even off-TPU — useful for debugging lowering
    errors on CPU), falling back to backend auto-detection (interpret
    everywhere but TPU).  The chosen route lands on the active telemetry
    registry as the ``kernels/pallas_interpret`` gauge (1 = interpret,
    0 = compiled), so BENCH rows are attributable to hardware.
    """
    if interpret is None:
        env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
        if env in ("1", "true", "yes", "on"):
            interpret = True
        elif env in ("0", "false", "no", "off"):
            interpret = False
        elif env:
            raise ValueError(
                f"REPRO_PALLAS_INTERPRET={env!r}: expected one of "
                "1/true/yes/on or 0/false/no/off (or unset for backend "
                "auto-detection)"
            )
        else:
            interpret = jax.default_backend() != "tpu"
    from repro.obs.telemetry import get_telemetry

    tel = get_telemetry()
    if tel.enabled:
        tel.gauge("kernels/pallas_interpret", 1.0 if interpret else 0.0)
    return bool(interpret)

#: routing id given to pad lanes: larger than any int32 demand value, so a
#: padded lane's dispatcher compare is never true and it can never turn on
PAD_ROUTE = 2**30


def _grid_scan_kernel(
    cb_ref, cp_ref, ct_ref, ch_ref,   # scalar prefetch (SMEM): (G,) cell maps
    a_ref,                            # scalar prefetch (SMEM): (B, T+max_h) demand
    p_ref,                            # scalar prefetch (SMEM): (R, T+max_h) predicted
    m_ref,                            # (1, 1 | T, BN) f32 wait thresholds (cell block)
    h_ref,                            # (1, BN) f32 per-level peek horizon (cell block)
    r_ref,                            # (1, BN) int32 routing ids (level block)
    o_ref,                            # (1, T, BN) int32 on-matrix block
    *rest,                            # record=True: (1, 4, BN) int32 counts block
    T: int, bn: int, horizon: int, time_varying: bool, record: bool = False,
):
    g = pl.program_id(0)
    levels = r_ref[pl.ds(0, 1), :]    # routed level ids for this lane block
    b = cb_ref[g]                     # demand row for this cell
    p = cp_ref[g]                     # predicted row for this cell
    h_row = h_ref[pl.ds(0, 1), :]

    def body(t, carry):
        if record:
            r, on, wait, c_rise, c_wait, c_peek, c_off = carry
        else:
            r, on, wait = carry                     # (1, BN) f32, bool, f32
        busy = a_ref[b, t] > levels
        if record:
            # dispatcher turn-on edge; t=0 is the free initial state
            # x(0)=a(0) (the carry starts all-off only as an encoding), so
            # it is not a rise — matching the lax.scan route's init
            rise = busy & ~on & (t > 0)
        on = on | busy                              # dispatcher turn-on
        r = jnp.where(busy, 0.0, r)
        idle = on & ~busy
        if time_varying:
            wait = jnp.where(idle & (r == 0.0), m_ref[0, pl.ds(t, 1), :], wait)
        r = jnp.where(idle, r + 1.0, r)
        seen = jnp.zeros_like(busy)
        for h in range(horizon):                    # static unroll, <= max Delta
            seen = seen | ((p_ref[p, t + 1 + h] > levels) & (float(h) < h_row))
        expired = idle & (r - 1.0 >= wait)
        off_now = expired & ~seen
        on = on & ~off_now
        r = jnp.where(off_now, 0.0, r)
        o_ref[0, pl.ds(t, 1), :] = on.astype(jnp.int32)
        if record:
            return (r, on, wait,
                    c_rise + rise.astype(jnp.int32),
                    c_wait + expired.astype(jnp.int32),
                    c_peek + (expired & seen).astype(jnp.int32),
                    c_off + off_now.astype(jnp.int32))
        return (r, on, wait)

    init = (
        jnp.zeros((1, bn), jnp.float32),
        jnp.zeros((1, bn), jnp.bool_),              # x(0) = a(0): busy turns it on
        jnp.zeros((1, bn), jnp.float32) if time_varying else m_ref[0, pl.ds(0, 1), :],
    )
    if record:
        init = init + tuple(jnp.zeros((1, bn), jnp.int32) for _ in range(4))
    final = jax.lax.fori_loop(0, T, body, init)
    if record:
        c_ref = rest[0]
        for i, cnt in enumerate(final[3:]):         # provenance.COUNT_ORDER rows
            c_ref[0, pl.ds(i, 1), :] = cnt


def provision_scan_grid(
    traces: jax.Array,          # (B, T) int32 demand rows
    predicted: jax.Array,       # (R, T) int32 predicted rows the peek reads
    thresholds: jax.Array,      # (K, 1, N) constant or (K, T, N) sampled waits
    cell_trace: jax.Array,      # (G,) int32 demand row per cell
    cell_pred: jax.Array,       # (G,) int32 predicted row per cell
    cell_thr: jax.Array,        # (G,) int32 threshold-table row per cell
    cell_hor: jax.Array,        # (G,) int32 horizon-table row per cell
    *,
    delta: int,                 # static pad/peek bound: ceil(max per-level Delta)
    horizon: int,               # peek slots unrolled: min(max_w+1, delta), 0 = none
    base_level: jax.Array | int = 0,
    routes: jax.Array | None = None,  # (N,) int32 routed level id per lane
    level_horizon: jax.Array | None = None,  # (H, N) per-level peek reach rows
    block_levels: int = DEFAULT_BN,
    interpret: bool | None = None,
    record: bool = False,
) -> jax.Array:
    """(G, T, N) bool on-matrix: one (noise, window, trace) cell per row.

    Cell ``g`` runs the slot scan with demand ``traces[cell_trace[g]]``,
    peek trace ``predicted[cell_pred[g]]``, wait thresholds
    ``thresholds[cell_thr[g]]`` and per-level peek reach
    ``level_horizon[cell_hor[g]]``.  Lane ``j`` dispatches against level id
    ``routes[j]`` — defaulting to the contiguous ``base_level + j`` — so a
    group-aligned typed layout can interleave pad lanes freely; block
    padding always uses the never-on :data:`PAD_ROUTE` sentinel.

    ``record=True`` returns ``(ons, counts)`` with ``counts`` (G, 4, N)
    int32 — aggregate per-lane decision counters accumulated in the scan
    carry, rows in :data:`repro.obs.provenance.COUNT_ORDER` order
    (demand-rise, wait-expired, peek-fired, toggle-off).  Aggregates, not
    per-slot codes: a (G, T, N) uint8 provenance stream would double the
    kernel's HBM traffic, so full codes stay a lax.scan-path feature.
    """
    traces = jnp.asarray(traces, jnp.int32)
    predicted = jnp.asarray(predicted, jnp.int32)
    assert traces.ndim == 2 and predicted.ndim == 2, (traces.shape, predicted.shape)
    T = traces.shape[1]
    max_h = int(delta)
    assert 0 <= horizon <= max_h, (horizon, delta)
    thresholds = jnp.asarray(thresholds, jnp.float32)
    assert thresholds.ndim == 3, thresholds.shape
    time_varying = thresholds.shape[1] != 1
    n = thresholds.shape[-1]
    G = cell_trace.shape[0]
    bn = block_levels
    n_padded = -(-n // bn) * bn
    pad_n = n_padded - n
    m3d = thresholds
    if level_horizon is None:
        h2d = jnp.full((1, n), float(horizon), jnp.float32)
    else:
        h2d = jnp.asarray(level_horizon, jnp.float32)
    if routes is None:
        routes = jnp.asarray(base_level, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    r2d = jnp.asarray(routes, jnp.int32).reshape(1, n)
    if pad_n:
        m3d = jnp.pad(m3d, ((0, 0), (0, 0), (0, pad_n)))
        h2d = jnp.pad(h2d, ((0, 0), (0, pad_n)))
        r2d = jnp.pad(r2d, ((0, 0), (0, pad_n)), constant_values=PAD_ROUTE)
    a_pad = jnp.pad(traces, ((0, 0), (0, max_h)))
    p_pad = jnp.pad(predicted, ((0, 0), (0, max_h)))
    cells = tuple(jnp.asarray(c, jnp.int32) for c in
                  (cell_trace, cell_pred, cell_thr, cell_hor))
    interpret = _resolve_interpret(interpret)

    kernel = functools.partial(
        _grid_scan_kernel, T=T, bn=bn, horizon=horizon,
        time_varying=time_varying, record=record,
    )
    out_specs = pl.BlockSpec((1, T, bn), lambda g, j, *p: (g, 0, j))
    out_shape = jax.ShapeDtypeStruct((G, T, n_padded), jnp.int32)
    if record:
        out_specs = [out_specs, pl.BlockSpec((1, 4, bn), lambda g, j, *p: (g, 0, j))]
        out_shape = [out_shape, jax.ShapeDtypeStruct((G, 4, n_padded), jnp.int32)]
    # index maps receive the scalar-prefetch refs: p[2]/p[3] are the
    # cell -> (threshold row, horizon row) maps, so each program's VMEM
    # blocks are exactly its own cell's tables; the routes row is blocked
    # by level block only (shared across cells)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(G, n_padded // bn),
        in_specs=[
            pl.BlockSpec((1, m3d.shape[1], bn), lambda g, j, *p: (p[2][g], 0, j)),
            pl.BlockSpec((1, bn), lambda g, j, *p: (p[3][g], j)),
            pl.BlockSpec((1, bn), lambda g, j, *p: (0, j)),
        ],
        out_specs=out_specs,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(*cells, a_pad, p_pad, m3d, h2d, r2d)
    if record:
        ons, counts = out
        return ons[:, :, :n].astype(bool), counts[:, :, :n]
    return out[:, :, :n].astype(bool)


def _stream_scan_kernel(
    cb_ref, cp_ref, ct_ref, ch_ref,   # scalar prefetch (SMEM): (G,) cell maps
    fl_ref,                           # scalar prefetch (SMEM): (2,) [fresh, n_levels]
    a_hbm,                            # ANY: (B, T_pad) demand rows
    p_hbm,                            # ANY: (R, T_pad + horizon) predicted rows
    m_ref,                            # ANY (K, T_pad, NP) waits | (1, 1, BN) VMEM block
    h_ref,                            # (1, BN) f32 per-level peek horizon (cell block)
    r_ref,                            # (1, BN) int32 routing ids (level block)
    si_ref,                           # (1, 2, BN) f32 carry in: rows [r, wait]
    oni_ref,                          # (1, BN) int32 carry in: on bits
    x_hbm,                            # ANY out: (G, NBLK, T_pad) int32 x partials
    acc_ref,                          # (1, n_acc, BN) int32 out: run/up/down [+counts]
    so_ref,                           # (1, 2, BN) f32 carry out: rows [r, wait]
    ono_ref,                          # (1, BN) int32 carry out: on bits
    *scratch,
    T: int, t_chunk: int, n_tiles: int, bn: int, horizon: int,
    time_varying: bool, record: bool,
):
    if time_varying:
        a_scr, p_scr, x_scr, thr_scr, a_sem, p_sem, x_sem, thr_sem = scratch
    else:
        a_scr, p_scr, x_scr, a_sem, p_sem, x_sem = scratch
    g = pl.program_id(0)
    j = pl.program_id(1)
    b = cb_ref[g]
    pr = cp_ref[g]
    kt = ct_ref[g]
    fresh = fl_ref[0] == 1
    nlv = fl_ref[1]
    levels = r_ref[pl.ds(0, 1), :]
    h_row = h_ref[pl.ds(0, 1), :]
    lane_ok = levels < nlv

    def a_dma(slot, i):
        return pltpu.make_async_copy(
            a_hbm.at[b, pl.ds(i * t_chunk, t_chunk)],
            a_scr.at[slot], a_sem.at[slot],
        )

    def p_dma(slot, i):
        return pltpu.make_async_copy(
            p_hbm.at[pr, pl.ds(i * t_chunk, t_chunk + horizon)],
            p_scr.at[slot], p_sem.at[slot],
        )

    def thr_dma(slot, i):
        return pltpu.make_async_copy(
            m_ref.at[kt, pl.ds(i * t_chunk, t_chunk), pl.ds(j * bn, bn)],
            thr_scr.at[slot], thr_sem.at[slot],
        )

    def x_dma(slot, i):
        return pltpu.make_async_copy(
            x_scr.at[slot],
            x_hbm.at[g, j, pl.ds(i * t_chunk, t_chunk)],
            x_sem.at[slot],
        )

    def start_in(slot, i):
        a_dma(slot, i).start()
        p_dma(slot, i).start()
        if time_varying:
            thr_dma(slot, i).start()

    start_in(0, 0)

    if time_varying:
        wait0 = si_ref[0, pl.ds(1, 1), :]
    else:
        wait0 = m_ref[0, pl.ds(0, 1), :]     # constant row; carry is redundant
    init = (
        si_ref[0, pl.ds(0, 1), :],           # r
        oni_ref[pl.ds(0, 1), :] != 0,        # on
        wait0,
    ) + tuple(jnp.zeros((1, bn), jnp.int32) for _ in range(7 if record else 3))

    def tile_body(i, st):
        slot = jax.lax.rem(i, 2)
        nxt = 1 - slot

        @pl.when(i + 1 < n_tiles)
        def _():
            start_in(nxt, i + 1)

        a_dma(slot, i).wait()
        p_dma(slot, i).wait()
        if time_varying:
            thr_dma(slot, i).wait()

        # the x slot is reused every other tile: its previous DMA-out must
        # have landed before this tile's slot loop overwrites the buffer
        @pl.when(i >= 2)
        def _():
            x_dma(slot, i - 2).wait()

        def slot_body(tl, s):
            if record:
                r, on, wait, run, up, down, c1, c2, c3, c4 = s
            else:
                r, on, wait, run, up, down = s
            t_glob = i * t_chunk + tl
            valid = t_glob < T                     # frozen tail of the pad
            first = fresh & (t_glob == 0)
            busy = a_scr[slot, tl] > levels
            # virtual boundary: x(0) = a(0) is the free initial state, so
            # at the very first slot of a fresh trace the previous on-state
            # is the busy pattern itself (no toggle, no rise) — matching
            # _cost_terms' first_on convention; a continuation call's
            # previous state is simply the carried on bits
            prev_eff = jnp.where(first, busy, on)
            if record:
                rise = busy & ~on & ~first
            on_n = on | busy                       # dispatcher turn-on
            r_n = jnp.where(busy, 0.0, r)
            idle = on_n & ~busy
            if time_varying:
                wait_n = jnp.where(
                    idle & (r_n == 0.0), thr_scr[slot, pl.ds(tl, 1), :], wait
                )
            else:
                wait_n = wait
            r_n = jnp.where(idle, r_n + 1.0, r_n)
            seen = jnp.zeros_like(busy)
            for h in range(horizon):               # static unroll, <= max Delta
                seen = seen | ((p_scr[slot, tl + 1 + h] > levels)
                               & (float(h) < h_row))
            expired = idle & (r_n - 1.0 >= wait_n)
            off_now = expired & ~seen
            on_f = on_n & ~off_now
            r_n = jnp.where(off_now, 0.0, r_n)
            ok = on_f & lane_ok
            x_scr[slot, tl] = jnp.sum(ok.astype(jnp.int32))

            def acc(tot, inc):
                return jnp.where(valid, tot + inc.astype(jnp.int32), tot)

            out = (
                jnp.where(valid, r_n, r),
                jnp.where(valid, on_f, on),
                jnp.where(valid, wait_n, wait),
                acc(run, ok),
                acc(up, on_f & ~prev_eff & lane_ok),
                acc(down, prev_eff & ~on_f & lane_ok),
            )
            if record:
                out = out + (
                    acc(c1, rise & lane_ok),
                    acc(c2, expired & lane_ok),
                    acc(c3, expired & seen & lane_ok),
                    acc(c4, off_now & lane_ok),
                )
            return out

        st = jax.lax.fori_loop(0, t_chunk, slot_body, st)
        x_dma(slot, i).start()
        return st

    final = jax.lax.fori_loop(0, n_tiles, tile_body, init)

    # drain the in-flight x DMAs (at most the last two tiles')
    if n_tiles >= 2:
        x_dma((n_tiles - 2) % 2, n_tiles - 2).wait()
    x_dma((n_tiles - 1) % 2, n_tiles - 1).wait()

    so_ref[0, pl.ds(0, 1), :] = final[0]
    so_ref[0, pl.ds(1, 1), :] = final[2]
    ono_ref[pl.ds(0, 1), :] = final[1].astype(jnp.int32)
    for k, tot in enumerate(final[3:]):
        acc_ref[0, pl.ds(k, 1), :] = tot


def provision_scan_stream(
    traces: jax.Array,          # (B, T) int32 demand rows
    predicted: jax.Array,       # (R, T) int32 predicted rows the peek reads
    thresholds: jax.Array,      # (K, 1, N) constant or (K, T, N) sampled waits
    cell_trace: jax.Array,      # (G,) int32 demand row per cell
    cell_pred: jax.Array,       # (G,) int32 predicted row per cell
    cell_thr: jax.Array,        # (G,) int32 threshold-table row per cell
    cell_hor: jax.Array,        # (G,) int32 horizon-table row per cell
    *,
    horizon: int,               # peek slots unrolled: min(max_w+1, delta), 0 = none
    t_chunk: int = DEFAULT_T_CHUNK,
    n_levels: int | None = None,  # real level count for the x mask (default N)
    base_level: jax.Array | int = 0,
    routes: jax.Array | None = None,  # (N,) int32 routed level id per lane
    level_horizon: jax.Array | None = None,  # (H, N) per-level peek reach rows
    block_levels: int = DEFAULT_BN,
    interpret: bool | None = None,
    record: bool = False,
    carry: dict | None = None,  # {"r","on","wait"} each (G, N) — None = fresh
) -> tuple[jax.Array, dict, dict]:
    """Streaming provisioning scan: O(t_chunk + levels) working set, any T.

    The same per-cell slot semantics as :func:`provision_scan_grid`, but
    the demand/predicted rows (and the (K, T, N) wait tables of the
    randomized policies) stay in HBM (``pltpu.ANY``) and are streamed in
    ``t_chunk``-slot tiles with double-buffered async copies; x(t) partials
    are DMA'd back out per tile.  Instead of the on-matrix, the kernel
    returns what the engine actually reduces it to:

    - ``x`` (G, T) int32 — on-lane count per slot (lanes masked to
      ``routes < n_levels``, like the sharded path's lane mask);
    - ``acc`` — per-lane int32 totals (G, N): ``run`` (on-slots), ``up`` /
      ``down`` (toggle edges against the virtual x(0)=a(0) boundary; the
      forced x(T)=a(T) final off is the *caller's* adjustment, since only
      the caller knows whether this call ends the trace), plus the four
      provenance counters (:data:`repro.obs.provenance.COUNT_ORDER`) when
      ``record=True``;
    - ``carry`` — ``{"r", "on", "wait"}`` (G, N) per-lane engine state
      after the last slot.  Feed it back via ``carry=`` and the next call
      continues the trace bit-exactly: chunking a trace across calls and
      accumulating ``acc`` reproduces the monolithic call (property-gated
      in tests/test_streaming.py).

    ``T`` need not be a multiple of ``t_chunk`` — the pad tail freezes the
    carry.  The peek reads ``horizon`` extra slots of each predicted tile,
    so a chunk boundary never truncates the lookahead *within one call*;
    across calls the caller chooses where to split (``provision_stream``
    streams whole traces in one call, so no peek ever straddles a split).
    """
    traces = jnp.asarray(traces, jnp.int32)
    predicted = jnp.asarray(predicted, jnp.int32)
    assert traces.ndim == 2 and predicted.ndim == 2, (traces.shape, predicted.shape)
    T = traces.shape[1]
    t_chunk = int(min(t_chunk, max(T, 1)))
    thresholds = jnp.asarray(thresholds, jnp.float32)
    assert thresholds.ndim == 3, thresholds.shape
    time_varying = thresholds.shape[1] != 1
    if time_varying:
        assert thresholds.shape[1] == T, (thresholds.shape, T)
    n = thresholds.shape[-1]
    if n_levels is None:
        n_levels = n
    G = cell_trace.shape[0]
    bn = block_levels
    n_padded = -(-n // bn) * bn
    pad_n = n_padded - n
    n_tiles = -(-T // t_chunk)
    T_pad = n_tiles * t_chunk
    assert 0 <= horizon, horizon

    m3d = thresholds
    if level_horizon is None:
        h2d = jnp.full((1, n), float(horizon), jnp.float32)
    else:
        h2d = jnp.asarray(level_horizon, jnp.float32)
    if routes is None:
        routes = jnp.asarray(base_level, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    r2d = jnp.asarray(routes, jnp.int32).reshape(1, n)
    if carry is None:
        fresh = 1
        c_r = jnp.zeros((G, n), jnp.float32)
        c_on = jnp.zeros((G, n), jnp.int32)
        c_w = jnp.zeros((G, n), jnp.float32)
    else:
        fresh = 0
        c_r = jnp.asarray(carry["r"], jnp.float32)
        c_on = jnp.asarray(carry["on"]).astype(jnp.int32)
        c_w = jnp.asarray(carry["wait"], jnp.float32)
        assert c_r.shape == (G, n), (c_r.shape, (G, n))
    if pad_n:
        m3d = jnp.pad(m3d, ((0, 0), (0, 0), (0, pad_n)))
        h2d = jnp.pad(h2d, ((0, 0), (0, pad_n)))
        r2d = jnp.pad(r2d, ((0, 0), (0, pad_n)), constant_values=PAD_ROUTE)
        c_r = jnp.pad(c_r, ((0, 0), (0, pad_n)))
        c_on = jnp.pad(c_on, ((0, 0), (0, pad_n)))
        c_w = jnp.pad(c_w, ((0, 0), (0, pad_n)))
    if time_varying:
        m3d = jnp.pad(m3d, ((0, 0), (0, T_pad - T), (0, 0)))
    a_pad = jnp.pad(traces, ((0, 0), (0, T_pad - T)))
    p_pad = jnp.pad(predicted, ((0, 0), (0, T_pad - T + horizon)))
    st_in = jnp.stack([c_r, c_w], axis=1)            # (G, 2, NP)
    cells = tuple(jnp.asarray(c, jnp.int32) for c in
                  (cell_trace, cell_pred, cell_thr, cell_hor))
    flags = jnp.asarray([fresh, n_levels], jnp.int32)
    interpret = _resolve_interpret(interpret)
    n_acc = 7 if record else 3
    nblk = n_padded // bn

    kernel = functools.partial(
        _stream_scan_kernel, T=T, t_chunk=t_chunk, n_tiles=n_tiles, bn=bn,
        horizon=horizon, time_varying=time_varying, record=record,
    )
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    m_spec = (
        any_spec if time_varying
        else pl.BlockSpec((1, 1, bn), lambda g, j, *p: (p[2][g], 0, j))
    )
    scratch = [
        pltpu.SMEM((2, t_chunk), jnp.int32),             # a tiles
        pltpu.SMEM((2, t_chunk + horizon), jnp.int32),   # p tiles (+ lookahead)
        pltpu.SMEM((2, t_chunk), jnp.int32),             # x partials out
    ]
    if time_varying:
        scratch.append(pltpu.VMEM((2, t_chunk, bn), jnp.float32))
    scratch += [pltpu.SemaphoreType.DMA((2,))] * (4 if time_varying else 3)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(G, nblk),
        in_specs=[
            any_spec,                                            # a
            any_spec,                                            # p
            m_spec,                                              # thresholds
            pl.BlockSpec((1, bn), lambda g, j, *p: (p[3][g], j)),  # horizon
            pl.BlockSpec((1, bn), lambda g, j, *p: (0, j)),        # routes
            pl.BlockSpec((1, 2, bn), lambda g, j, *p: (g, 0, j)),  # r/wait in
            pl.BlockSpec((1, bn), lambda g, j, *p: (g, j)),        # on in
        ],
        out_specs=[
            any_spec,                                              # x partials
            pl.BlockSpec((1, n_acc, bn), lambda g, j, *p: (g, 0, j)),
            pl.BlockSpec((1, 2, bn), lambda g, j, *p: (g, 0, j)),  # r/wait out
            pl.BlockSpec((1, bn), lambda g, j, *p: (g, j)),        # on out
        ],
        scratch_shapes=scratch,
    )
    x_part, acc, st_out, on_out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((G, nblk, T_pad), jnp.int32),
            jax.ShapeDtypeStruct((G, n_acc, n_padded), jnp.int32),
            jax.ShapeDtypeStruct((G, 2, n_padded), jnp.float32),
            jax.ShapeDtypeStruct((G, n_padded), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(*cells, flags, a_pad, p_pad, m3d, h2d, r2d, st_in, c_on)
    x = x_part.sum(axis=1)[:, :T].astype(jnp.int32)
    names = ("run", "up", "down")
    if record:
        names = names + ("demand_rise", "wait_expired", "peek_fired", "toggle_off")
    accs = {name: acc[:, k, :n] for k, name in enumerate(names)}
    carry_out = {
        "r": st_out[:, 0, :n],
        "on": on_out[:, :n] != 0,
        "wait": st_out[:, 1, :n],
    }
    return x, accs, carry_out


def provision_scan(
    a: jax.Array,               # (T,) int32 demand per slot
    thresholds: jax.Array,      # (N,) constant waits or (T, N) sampled waits
    *,
    delta: int,                 # static pad/peek bound: ceil(max per-level Delta)
    horizon: int,               # peek slots unrolled: min(w+1, delta), 0 = no peek
    base_level: jax.Array | int = 0,
    predicted: jax.Array | None = None,   # (T,) trace the peek reads; default a
    level_horizon: jax.Array | None = None,  # (N,) per-level peek reach (slots)
    block_levels: int = DEFAULT_BN,
    interpret: bool | None = None,
) -> jax.Array:
    """(T, N) bool on-matrix for levels [base_level, base_level + N).

    The single-cell convenience wrapper over :func:`provision_scan_grid`
    (one trace, one window, one noise level — ``G = 1``).
    """
    a = jnp.asarray(a, jnp.int32)
    thresholds = jnp.asarray(thresholds, jnp.float32)
    if thresholds.ndim == 2:
        m3d = thresholds[None]                      # (1, T, N)
    else:
        m3d = thresholds[None, None]                # (1, 1, N)
    pred = a if predicted is None else jnp.asarray(predicted, jnp.int32)
    lh = None if level_horizon is None else jnp.asarray(level_horizon)[None]
    zero = jnp.zeros((1,), jnp.int32)
    out = provision_scan_grid(
        a[None], pred[None], m3d, zero, zero, zero, zero,
        delta=delta, horizon=horizon, base_level=base_level,
        level_horizon=lh, block_levels=block_levels, interpret=interpret,
    )
    return out[0]
