"""Fused per-level provisioning scan as a Pallas TPU kernel.

The provisioning engine's inner loop (repro.core.jax_provision) is a
sequential scan over slots with an embarrassingly parallel level axis.  For
large fleets the lax.scan path materializes (T, N) intermediates per step;
this kernel fuses the whole scan into one program per (cell, level block):

  grid = (G, N/BN); each program runs ONE sweep cell — a (noise-std,
  window, trace) combination — over its level block, keeping the block's
  state (idle run length, on/off bit, sampled wait threshold) in
  registers/VMEM across all T slots and streaming the on-matrix out row by
  row.  ``G = S*W*B`` covers the full prediction-noise x window x trace
  grid of a :class:`~repro.core.provision.ProvisionSpec` in one launch.

The demand batch ``(B, T)`` and the predicted-trace rows ``(R, T)`` are
scalar-prefetched into SMEM once and *indexed per cell*: four small
``(G,)`` cell maps (also scalar-prefetched) tell each program which demand
row drives its dispatcher compare, which predicted row its peek reads, and
which threshold/horizon table rows it consumes.  The threshold and horizon
tables are blocked into VMEM via scalar-prefetch-driven index maps, so a
program only ever sees its own cell's rows — no HBM traffic beyond those
blocks and the output.

Each lane additionally carries its *routing id* in a blocked ``(1, BN)``
``routes`` row: the dispatcher compares demand against the routed id, not
the lane's storage position.  For a plain fleet the ids are just
``base_level + arange(N)`` (the default), but typed fleets
(``CostModel.from_groups``) store their levels group-aligned — each server
type padded out to its own block boundary so a threshold/horizon block
never straddles two types — and then storage position ≠ level id; the
routes row is what keeps the greedy demand split exact under that packing.
Pad lanes get a sentinel id larger than any demand, so they can never turn
on.

Thresholds are constant rows for the deterministic policies (A1's
``max(0, Δ_l−w−1)`` per window, DELAYEDOFF's and AQ-DET's ``Δ_l``) or
``(T, N)`` tables of sampled waits for A2/A3/AQ-RAND (entry [t, l] is
consumed iff level l becomes newly idle in slot t, matching the engine's
PRNG contract; the table for cell (s, w, b) depends on (w, b) only — noise
sweeps share wait draws — and for the window-free AQ-RAND on b alone).
Heterogeneous fleets give each level its own Δ, hence its own threshold
*and* its own peek reach: ``level_horizon`` rows are per-level floats
masking the statically unrolled ``horizon`` peek to ``min(w+1, Δ_l)``
slots (fractional Δ_l included: slot ``h`` is peeked iff ``h < Δ_l``).

Off-TPU the kernel runs in interpret mode (auto-detected), so the sharded
fleet path is testable on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

DEFAULT_BN = 128     # level-block width (lane dimension)

#: routing id given to pad lanes: larger than any int32 demand value, so a
#: padded lane's dispatcher compare is never true and it can never turn on
PAD_ROUTE = 2**30


def _grid_scan_kernel(
    cb_ref, cp_ref, ct_ref, ch_ref,   # scalar prefetch (SMEM): (G,) cell maps
    a_ref,                            # scalar prefetch (SMEM): (B, T+max_h) demand
    p_ref,                            # scalar prefetch (SMEM): (R, T+max_h) predicted
    m_ref,                            # (1, 1 | T, BN) f32 wait thresholds (cell block)
    h_ref,                            # (1, BN) f32 per-level peek horizon (cell block)
    r_ref,                            # (1, BN) int32 routing ids (level block)
    o_ref,                            # (1, T, BN) int32 on-matrix block
    *rest,                            # record=True: (1, 4, BN) int32 counts block
    T: int, bn: int, horizon: int, time_varying: bool, record: bool = False,
):
    g = pl.program_id(0)
    levels = r_ref[pl.ds(0, 1), :]    # routed level ids for this lane block
    b = cb_ref[g]                     # demand row for this cell
    p = cp_ref[g]                     # predicted row for this cell
    h_row = h_ref[pl.ds(0, 1), :]

    def body(t, carry):
        if record:
            r, on, wait, c_rise, c_wait, c_peek, c_off = carry
        else:
            r, on, wait = carry                     # (1, BN) f32, bool, f32
        busy = a_ref[b, t] > levels
        if record:
            # dispatcher turn-on edge; t=0 is the free initial state
            # x(0)=a(0) (the carry starts all-off only as an encoding), so
            # it is not a rise — matching the lax.scan route's init
            rise = busy & ~on & (t > 0)
        on = on | busy                              # dispatcher turn-on
        r = jnp.where(busy, 0.0, r)
        idle = on & ~busy
        if time_varying:
            wait = jnp.where(idle & (r == 0.0), m_ref[0, pl.ds(t, 1), :], wait)
        r = jnp.where(idle, r + 1.0, r)
        seen = jnp.zeros_like(busy)
        for h in range(horizon):                    # static unroll, <= max Delta
            seen = seen | ((p_ref[p, t + 1 + h] > levels) & (float(h) < h_row))
        expired = idle & (r - 1.0 >= wait)
        off_now = expired & ~seen
        on = on & ~off_now
        r = jnp.where(off_now, 0.0, r)
        o_ref[0, pl.ds(t, 1), :] = on.astype(jnp.int32)
        if record:
            return (r, on, wait,
                    c_rise + rise.astype(jnp.int32),
                    c_wait + expired.astype(jnp.int32),
                    c_peek + (expired & seen).astype(jnp.int32),
                    c_off + off_now.astype(jnp.int32))
        return (r, on, wait)

    init = (
        jnp.zeros((1, bn), jnp.float32),
        jnp.zeros((1, bn), jnp.bool_),              # x(0) = a(0): busy turns it on
        jnp.zeros((1, bn), jnp.float32) if time_varying else m_ref[0, pl.ds(0, 1), :],
    )
    if record:
        init = init + tuple(jnp.zeros((1, bn), jnp.int32) for _ in range(4))
    final = jax.lax.fori_loop(0, T, body, init)
    if record:
        c_ref = rest[0]
        for i, cnt in enumerate(final[3:]):         # provenance.COUNT_ORDER rows
            c_ref[0, pl.ds(i, 1), :] = cnt


def provision_scan_grid(
    traces: jax.Array,          # (B, T) int32 demand rows
    predicted: jax.Array,       # (R, T) int32 predicted rows the peek reads
    thresholds: jax.Array,      # (K, 1, N) constant or (K, T, N) sampled waits
    cell_trace: jax.Array,      # (G,) int32 demand row per cell
    cell_pred: jax.Array,       # (G,) int32 predicted row per cell
    cell_thr: jax.Array,        # (G,) int32 threshold-table row per cell
    cell_hor: jax.Array,        # (G,) int32 horizon-table row per cell
    *,
    delta: int,                 # static pad/peek bound: ceil(max per-level Delta)
    horizon: int,               # peek slots unrolled: min(max_w+1, delta), 0 = none
    base_level: jax.Array | int = 0,
    routes: jax.Array | None = None,  # (N,) int32 routed level id per lane
    level_horizon: jax.Array | None = None,  # (H, N) per-level peek reach rows
    block_levels: int = DEFAULT_BN,
    interpret: bool | None = None,
    record: bool = False,
) -> jax.Array:
    """(G, T, N) bool on-matrix: one (noise, window, trace) cell per row.

    Cell ``g`` runs the slot scan with demand ``traces[cell_trace[g]]``,
    peek trace ``predicted[cell_pred[g]]``, wait thresholds
    ``thresholds[cell_thr[g]]`` and per-level peek reach
    ``level_horizon[cell_hor[g]]``.  Lane ``j`` dispatches against level id
    ``routes[j]`` — defaulting to the contiguous ``base_level + j`` — so a
    group-aligned typed layout can interleave pad lanes freely; block
    padding always uses the never-on :data:`PAD_ROUTE` sentinel.

    ``record=True`` returns ``(ons, counts)`` with ``counts`` (G, 4, N)
    int32 — aggregate per-lane decision counters accumulated in the scan
    carry, rows in :data:`repro.obs.provenance.COUNT_ORDER` order
    (demand-rise, wait-expired, peek-fired, toggle-off).  Aggregates, not
    per-slot codes: a (G, T, N) uint8 provenance stream would double the
    kernel's HBM traffic, so full codes stay a lax.scan-path feature.
    """
    traces = jnp.asarray(traces, jnp.int32)
    predicted = jnp.asarray(predicted, jnp.int32)
    assert traces.ndim == 2 and predicted.ndim == 2, (traces.shape, predicted.shape)
    T = traces.shape[1]
    max_h = int(delta)
    assert 0 <= horizon <= max_h, (horizon, delta)
    thresholds = jnp.asarray(thresholds, jnp.float32)
    assert thresholds.ndim == 3, thresholds.shape
    time_varying = thresholds.shape[1] != 1
    n = thresholds.shape[-1]
    G = cell_trace.shape[0]
    bn = block_levels
    n_padded = -(-n // bn) * bn
    pad_n = n_padded - n
    m3d = thresholds
    if level_horizon is None:
        h2d = jnp.full((1, n), float(horizon), jnp.float32)
    else:
        h2d = jnp.asarray(level_horizon, jnp.float32)
    if routes is None:
        routes = jnp.asarray(base_level, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    r2d = jnp.asarray(routes, jnp.int32).reshape(1, n)
    if pad_n:
        m3d = jnp.pad(m3d, ((0, 0), (0, 0), (0, pad_n)))
        h2d = jnp.pad(h2d, ((0, 0), (0, pad_n)))
        r2d = jnp.pad(r2d, ((0, 0), (0, pad_n)), constant_values=PAD_ROUTE)
    a_pad = jnp.pad(traces, ((0, 0), (0, max_h)))
    p_pad = jnp.pad(predicted, ((0, 0), (0, max_h)))
    cells = tuple(jnp.asarray(c, jnp.int32) for c in
                  (cell_trace, cell_pred, cell_thr, cell_hor))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _grid_scan_kernel, T=T, bn=bn, horizon=horizon,
        time_varying=time_varying, record=record,
    )
    out_specs = pl.BlockSpec((1, T, bn), lambda g, j, *p: (g, 0, j))
    out_shape = jax.ShapeDtypeStruct((G, T, n_padded), jnp.int32)
    if record:
        out_specs = [out_specs, pl.BlockSpec((1, 4, bn), lambda g, j, *p: (g, 0, j))]
        out_shape = [out_shape, jax.ShapeDtypeStruct((G, 4, n_padded), jnp.int32)]
    # index maps receive the scalar-prefetch refs: p[2]/p[3] are the
    # cell -> (threshold row, horizon row) maps, so each program's VMEM
    # blocks are exactly its own cell's tables; the routes row is blocked
    # by level block only (shared across cells)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(G, n_padded // bn),
        in_specs=[
            pl.BlockSpec((1, m3d.shape[1], bn), lambda g, j, *p: (p[2][g], 0, j)),
            pl.BlockSpec((1, bn), lambda g, j, *p: (p[3][g], j)),
            pl.BlockSpec((1, bn), lambda g, j, *p: (0, j)),
        ],
        out_specs=out_specs,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(*cells, a_pad, p_pad, m3d, h2d, r2d)
    if record:
        ons, counts = out
        return ons[:, :, :n].astype(bool), counts[:, :, :n]
    return out[:, :, :n].astype(bool)


def provision_scan(
    a: jax.Array,               # (T,) int32 demand per slot
    thresholds: jax.Array,      # (N,) constant waits or (T, N) sampled waits
    *,
    delta: int,                 # static pad/peek bound: ceil(max per-level Delta)
    horizon: int,               # peek slots unrolled: min(w+1, delta), 0 = no peek
    base_level: jax.Array | int = 0,
    predicted: jax.Array | None = None,   # (T,) trace the peek reads; default a
    level_horizon: jax.Array | None = None,  # (N,) per-level peek reach (slots)
    block_levels: int = DEFAULT_BN,
    interpret: bool | None = None,
) -> jax.Array:
    """(T, N) bool on-matrix for levels [base_level, base_level + N).

    The single-cell convenience wrapper over :func:`provision_scan_grid`
    (one trace, one window, one noise level — ``G = 1``).
    """
    a = jnp.asarray(a, jnp.int32)
    thresholds = jnp.asarray(thresholds, jnp.float32)
    if thresholds.ndim == 2:
        m3d = thresholds[None]                      # (1, T, N)
    else:
        m3d = thresholds[None, None]                # (1, 1, N)
    pred = a if predicted is None else jnp.asarray(predicted, jnp.int32)
    lh = None if level_horizon is None else jnp.asarray(level_horizon)[None]
    zero = jnp.zeros((1,), jnp.int32)
    out = provision_scan_grid(
        a[None], pred[None], m3d, zero, zero, zero, zero,
        delta=delta, horizon=horizon, base_level=base_level,
        level_horizon=lh, block_levels=block_levels, interpret=interpret,
    )
    return out[0]
