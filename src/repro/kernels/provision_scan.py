"""Fused per-level provisioning scan as a Pallas TPU kernel.

The provisioning engine's inner loop (repro.core.jax_provision) is a
sequential scan over slots with an embarrassingly parallel level axis.  For
large fleets the lax.scan path materializes (T, N) intermediates per step;
this kernel fuses the whole scan into one program per level block:

  grid = (N/BN,); each program keeps its block's state — idle run length,
  on/off bit, sampled wait threshold — in registers/VMEM across all T slots
  and streams the on-matrix out row by row.

Two traces are scalar-prefetched into SMEM: the true demand (drives the
dispatcher's ``a(t) > level`` compare) and the *predicted* trace (drives
the ``horizon``-slot peek) — so erroneous-prediction experiments (paper
Sec. V-C) run through the fleet path too, and exact-prediction callers just
pass the same array twice.  Both compares are SMEM scalar reads against a
resident level-id vector — no HBM traffic beyond the threshold table and
the output.

Thresholds are (N,) constants for the deterministic policies (A1's
``max(0, Δ_l−w−1)``, DELAYEDOFF's ``Δ_l``) or a (T, N) table of sampled
waits for A2/A3 (entry [t, l] is consumed iff level l becomes newly idle in
slot t, matching the engine's PRNG contract).  Heterogeneous fleets give
each level its own Δ, hence its own threshold *and* its own peek reach:
``level_horizon`` is a per-level float row masking the statically unrolled
``horizon`` peek to ``min(w+1, Δ_l)`` slots.

Off-TPU the kernel runs in interpret mode (auto-detected), so the sharded
fleet path is testable on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

DEFAULT_BN = 128     # level-block width (lane dimension)


def _scan_kernel(
    base_ref, a_ref, p_ref,     # scalar prefetch (SMEM): (1,), (T+max_h,), (T+max_h,)
    m_ref,                      # (1 | T, BN) f32 wait thresholds
    h_ref,                      # (1, BN) f32 per-level peek horizon (slots)
    o_ref,                      # (T, BN) int32 on-matrix block
    *, T: int, bn: int, horizon: int, time_varying: bool,
):
    blk = pl.program_id(0)
    levels = base_ref[0] + blk * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    h_row = h_ref[pl.ds(0, 1), :]

    def body(t, carry):
        r, on, wait = carry                         # (1, BN) f32, bool, f32
        busy = a_ref[t] > levels
        on = on | busy                              # dispatcher turn-on
        r = jnp.where(busy, 0.0, r)
        idle = on & ~busy
        if time_varying:
            wait = jnp.where(idle & (r == 0.0), m_ref[pl.ds(t, 1), :], wait)
        r = jnp.where(idle, r + 1.0, r)
        seen = jnp.zeros_like(busy)
        for h in range(horizon):                    # static unroll, <= max Delta
            seen = seen | ((p_ref[t + 1 + h] > levels) & (float(h) < h_row))
        off_now = idle & (r - 1.0 >= wait) & ~seen
        on = on & ~off_now
        r = jnp.where(off_now, 0.0, r)
        o_ref[pl.ds(t, 1), :] = on.astype(jnp.int32)
        return (r, on, wait)

    init = (
        jnp.zeros((1, bn), jnp.float32),
        jnp.zeros((1, bn), jnp.bool_),              # x(0) = a(0): busy turns it on
        jnp.zeros((1, bn), jnp.float32) if time_varying else m_ref[pl.ds(0, 1), :],
    )
    jax.lax.fori_loop(0, T, body, init)


def provision_scan(
    a: jax.Array,               # (T,) int32 demand per slot
    thresholds: jax.Array,      # (N,) constant waits or (T, N) sampled waits
    *,
    delta: int,                 # static pad/peek bound: ceil(max per-level Delta)
    horizon: int,               # peek slots unrolled: min(w+1, delta), 0 = no peek
    base_level: jax.Array | int = 0,
    predicted: jax.Array | None = None,   # (T,) trace the peek reads; default a
    level_horizon: jax.Array | None = None,  # (N,) per-level peek reach (slots)
    block_levels: int = DEFAULT_BN,
    interpret: bool | None = None,
) -> jax.Array:
    """(T, N) bool on-matrix for levels [base_level, base_level + N)."""
    a = jnp.asarray(a, jnp.int32)
    T = a.shape[0]
    max_h = int(delta)
    assert 0 <= horizon <= max_h, (horizon, delta)
    thresholds = jnp.asarray(thresholds, jnp.float32)
    time_varying = thresholds.ndim == 2
    n = thresholds.shape[-1]
    bn = block_levels
    n_padded = -(-n // bn) * bn
    pad_n = n_padded - n
    m2d = thresholds if time_varying else thresholds[None, :]
    if level_horizon is None:
        h2d = jnp.full((1, n), float(horizon), jnp.float32)
    else:
        h2d = jnp.asarray(level_horizon, jnp.float32)[None, :]
    if pad_n:
        m2d = jnp.pad(m2d, ((0, 0), (0, pad_n)))
        h2d = jnp.pad(h2d, ((0, 0), (0, pad_n)))
    pred = a if predicted is None else jnp.asarray(predicted, jnp.int32)
    a_pad = jnp.concatenate([a, jnp.zeros((max_h,), jnp.int32)])
    p_pad = jnp.concatenate([pred, jnp.zeros((max_h,), jnp.int32)])
    base = jnp.asarray(base_level, jnp.int32).reshape((1,))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _scan_kernel, T=T, bn=bn, horizon=horizon, time_varying=time_varying
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_padded // bn,),
        in_specs=[
            pl.BlockSpec((m2d.shape[0], bn), lambda i, base, ap, pp: (0, i)),
            pl.BlockSpec((1, bn), lambda i, base, ap, pp: (0, i)),
        ],
        out_specs=pl.BlockSpec((T, bn), lambda i, base, ap, pp: (0, i)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, n_padded), jnp.int32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(base, a_pad, p_pad, m2d, h2d)
    return out[:, :n].astype(bool)
