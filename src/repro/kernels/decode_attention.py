"""Single-token decode attention over a long KV cache (Pallas TPU kernel).

Decode attention is memory-bound: the whole cache streams HBM -> VMEM once
per token.  The kernel tiles the cache along sequence (BK) and keeps the
query-head group for one KV head resident:

  grid = (B, KVH, S/BK); innermost "arbitrary" so running max/sum/acc for
  the (rep, hd) group live in VMEM scratch across cache tiles.

Per-sequence ``lengths`` masks unwritten slots, so ragged batches (paper-
style sessions pinned to replicas) decode without repacking.

VMEM per program: rep*hd (q) + 2*BK*hd (k,v tiles) + rep*(hd+2) scratch —
BK=1024, hd=128, rep=8: ~0.8 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

DEFAULT_BK = 1024
NEG_INF = float(-1e30)


def _decode_kernel(
    len_ref,                    # scalar prefetch: (B,) int32
    q_ref, k_ref, v_ref,        # (1, 1, rep, hd), (1, BK, 1, hd), (1, BK, 1, hd)
    o_ref,                      # (1, 1, rep, hd)
    m_scr, l_scr, acc_scr,      # (rep,), (rep,), (rep, hd) fp32
    *, scale: float, bk: int, n_kv: int, rep: int,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = ki * bk

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                  # (rep, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (BK, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                            # (rep, BK)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (rep, bk), 1)
        mask = k_pos < length
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (BK, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,               # (B, H, hd)
    k_cache: jax.Array,         # (B, S, KVH, hd)
    v_cache: jax.Array,         # (B, S, KVH, hd)
    lengths: jax.Array,         # (B,) int32
    *,
    scale: float | None = None,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    B, S, kvh, hd = k_cache.shape
    H = q.shape[1]
    rep = H // kvh
    scale = hd ** -0.5 if scale is None else scale
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    n_kv = S // bk

    qg = q.reshape(B, kvh, rep, hd)

    kernel = functools.partial(
        _decode_kernel, scale=scale, bk=bk, n_kv=n_kv, rep=rep
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, kvh, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, h, j, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, j, lens: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, j, lens: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, h, j, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kvh, rep, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, H, hd)
