"""Flash attention (causal / sliding-window, GQA) as a Pallas TPU kernel.

Streaming-softmax tiling designed for the TPU memory hierarchy:
  * grid = (B, H, S/BQ, S/BK); the innermost axis is "arbitrary" so the
    running max / sum / accumulator live in VMEM scratch across KV tiles.
  * every matmul is (BQ, hd) x (hd, BK) or (BQ, BK) x (BK, hd) with
    BQ/BK multiples of 128 and hd in {64, 128, 256} — MXU-aligned.
  * GQA: the k/v BlockSpec index map divides the head index, so KV tiles are
    fetched once per kv-head and reused by its query-head group.
  * causal: KV tiles strictly above the diagonal skip their compute via
    @pl.when; the diagonal tile is masked inline.

VMEM footprint per program: BQ*hd (q) + 2*BK*hd (k,v) + BQ*BK (scores)
+ BQ*(hd+2) fp32 scratch — e.g. BQ=BK=512, hd=128: ~1.9 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = float(-1e30)


def _flash_kernel(
    q_ref, k_ref, v_ref,       # (BQ, hd), (BK, hd), (BK, hd)
    o_ref,                     # (BQ, hd)
    m_scr, l_scr, acc_scr,     # VMEM scratch: (BQ,), (BQ,), (BQ, hd) fp32
    *, scale: float, causal: bool, window: int, bq: int, bk: int, n_kv: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # Skip fully-masked tiles (above the diagonal / outside the window).
    # NOTE: @pl.when skips the compute but the tile was still prefetched;
    # a triangle-packed grid would also save the HBM fetch (perf lever).
    run = jnp.bool_(True)
    if causal:
        run = k_start <= q_start + bq - 1
    if window > 0:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                           # (BQ, BK)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)                     # (BQ,)
        p = jnp.exp(s - m_new[:, None])                     # (BQ, BK)
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,              # (B, S, H, hd)
    k: jax.Array,              # (B, S, KVH, hd)
    v: jax.Array,              # (B, S, KVH, hd)
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    kvh = k.shape[2]
    rep = H // kvh
    scale = hd ** -0.5 if scale is None else scale
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_kv = S // bk

    grid = (B, H, S // bq, n_kv)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        n_kv=n_kv,
    )
    # layout: move heads next to batch so blocks are (1,1,BQ,hd)
    qt = q.transpose(0, 2, 1, 3)      # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)      # (B, KVH, S, hd)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)  # back to (B, S, H, hd)
