"""Pallas TPU kernels for the attention hot spots (+ pure-jnp oracles).

The model's portable einsum path is used for dry-run lowering; these kernels
are the TPU execution path and are validated against ref.py in interpret
mode on CPU (tests/test_kernels.py).
"""
from .ops import decode_attention, flash_attention
from .provision_scan import provision_scan, provision_scan_grid

__all__ = [
    "decode_attention",
    "flash_attention",
    "provision_scan",
    "provision_scan_grid",
]
