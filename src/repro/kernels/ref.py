"""Pure-jnp oracles for the Pallas kernels (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,          # (B, S, H, hd)
    k: jax.Array,          # (B, S, KVH, hd)
    v: jax.Array,          # (B, S, KVH, hd)
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
) -> jax.Array:
    B, S, H, hd = q.shape
    kvh = k.shape[2]
    rep = H // kvh
    scale = hd ** -0.5 if scale is None else scale
    kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    q_pos = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (S, S), 1)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,          # (B, H, hd) — single new token per sequence
    k_cache: jax.Array,    # (B, S, KVH, hd)
    v_cache: jax.Array,    # (B, S, KVH, hd)
    lengths: jax.Array,    # (B,) int32 — valid cache entries per sequence
    *,
    scale: float | None = None,
) -> jax.Array:
    B, S, kvh, hd = k_cache.shape
    H = q.shape[1]
    rep = H // kvh
    scale = hd ** -0.5 if scale is None else scale
    kk = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
    vv = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
    scores = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    k_pos = jnp.arange(S)[None, None, :]
    mask = k_pos < lengths[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)
