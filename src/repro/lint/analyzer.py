"""File walking, rule dispatch and suppression filtering.

:func:`lint_paths` is the programmatic entrypoint behind the CLI and the
self-check test: walk the given files/directories (skipping
``__pycache__``-style noise and the deliberately-violating
``tests/lint_fixtures``), parse each module once, run every rule over the
shared :class:`~repro.lint.context.ModuleContext`, and mark findings that a
``# repro-lint: disable=...`` comment covers as suppressed (they still count
in the summary, so suppression drift shows in the findings diff).
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, Iterator, Sequence

from .context import ModuleContext
from .findings import Finding, active, summarize
from .rules import RULES, Rule

#: directory basenames never walked into (explicit file arguments bypass
#: this — the rule fixture tests lint files under lint_fixtures directly)
EXCLUDED_DIRS = frozenset({
    "__pycache__", ".git", ".venv", "node_modules", "lint_fixtures",
    ".mypy_cache", ".ruff_cache", ".pytest_cache",
})


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]
    files: int
    parse_errors: list[Finding]
    unknown_suppressions: list[Finding]

    @property
    def ok(self) -> bool:
        return not active(self.findings) and not self.parse_errors

    def strict_ok(self) -> bool:
        return self.ok and not self.unknown_suppressions

    def summary(self, paths: Sequence[str] = ()) -> dict:
        return summarize(
            self.findings + self.parse_errors,
            files=self.files,
            rule_ids=RULES,
            paths=list(paths),
        )


def iter_python_files(paths: Iterable[str]) -> Iterator[str]:
    """Yield ``.py`` files under the given files/directories.  Arguments
    that are neither are skipped here; :func:`lint_paths` turns them into
    gating ``path-error`` findings so a typo'd CI path cannot silently
    lint nothing."""
    for path in paths:
        if os.path.isfile(path):
            yield path
        elif os.path.isdir(path):
            for root, dirs, files in os.walk(path):
                dirs[:] = sorted(d for d in dirs if d not in EXCLUDED_DIRS)
                for name in sorted(files):
                    if name.endswith(".py"):
                        yield os.path.join(root, name)


def lint_file(
    path: str, *, rules: Sequence[Rule] | None = None, source: str | None = None
) -> LintResult:
    """Lint one module; a syntax error becomes a single ``parse-error``
    finding instead of an exception (rendered like a rule hit, gated by
    ``--strict`` and the default exit code alike)."""
    if source is None:
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return LintResult(
            findings=[],
            files=1,
            parse_errors=[Finding(
                path, e.lineno or 1, (e.offset or 1) - 1,
                "parse-error", f"cannot parse: {e.msg}",
            )],
            unknown_suppressions=[],
        )
    ctx = ModuleContext(path, source, tree)
    findings: list[Finding] = []
    for rule in rules if rules is not None else RULES.values():
        for line, col, message in rule.check(ctx):
            findings.append(Finding(
                path, line, col, rule.id, message,
                suppressed=ctx.is_suppressed(rule.id, line),
            ))
    unknown = [
        Finding(
            path, line, 0, "unknown-suppression",
            f"suppression names unknown rule id `{rid}`",
        )
        for line, rid in ctx.unknown_suppressions
    ]
    return LintResult(sorted(findings), 1, [], unknown)


def lint_paths(
    paths: Sequence[str], *, rules: Sequence[Rule] | None = None
) -> LintResult:
    findings: list[Finding] = []
    parse_errors: list[Finding] = []
    unknown: list[Finding] = []
    files = 0
    for path in paths:
        if not os.path.isfile(path) and not os.path.isdir(path):
            parse_errors.append(Finding(
                path, 1, 0, "path-error",
                "path is neither a file nor a directory — nothing was "
                "linted under this argument (typo in the invocation?)",
            ))
    for path in iter_python_files(paths):
        res = lint_file(path, rules=rules)
        files += 1
        findings.extend(res.findings)
        parse_errors.extend(res.parse_errors)
        unknown.extend(res.unknown_suppressions)
    return LintResult(sorted(findings), files, sorted(parse_errors),
                      sorted(unknown))
