"""repro.lint — JAX/Pallas-aware static analysis + runtime sanitizer.

Static side (``python -m repro.lint src/ --strict``): six repo-specific AST
rules (RPL001–RPL006) that mechanically enforce the engine's implementation
invariants — PRNG key hygiene, static-vs-data jit arguments, no host
branches or host calls under trace, pytree registration, CompileWatcher
ownership of compile accounting.  Runtime side
(:func:`repro.lint.sanitize.tracer_sanitizer`): one gated recompile/leak
check replacing the hand-rolled jit-cache gates in tests and benchmarks.

See ``docs/static_analysis.md`` for the rule ↔ invariant table and
suppression syntax (``# repro-lint: disable=RPL003``).
"""
from typing import TYPE_CHECKING, Any

from .analyzer import (
    EXCLUDED_DIRS,
    LintResult,
    iter_python_files,
    lint_file,
    lint_paths,
)
from .findings import Finding, diff_summaries, summarize
from .rules import RULES, STATIC_ALLOWLIST, Rule

if TYPE_CHECKING:
    from .sanitize import (
        RecompileError,
        UnobservableCacheError,
        tracer_sanitizer,
    )

#: resolved lazily via module __getattr__ — the static side of the package
#: (CLI, rules, findings) must stay stdlib-only so the CI lint job can run
#: ``python -m repro.lint`` without jax installed; only touching the
#: sanitizer pulls in jax and repro.obs
_SANITIZE_EXPORTS = frozenset(
    {"RecompileError", "UnobservableCacheError", "tracer_sanitizer"}
)


def __getattr__(name: str) -> Any:
    if name in _SANITIZE_EXPORTS:
        from . import sanitize

        return getattr(sanitize, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "EXCLUDED_DIRS",
    "Finding",
    "LintResult",
    "RULES",
    "RecompileError",
    "Rule",
    "STATIC_ALLOWLIST",
    "UnobservableCacheError",
    "diff_summaries",
    "iter_python_files",
    "lint_file",
    "lint_paths",
    "summarize",
    "tracer_sanitizer",
]
