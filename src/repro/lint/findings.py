"""Finding model and output formats for :mod:`repro.lint`.

One :class:`Finding` per rule hit, sortable into (path, line, col) order.
Three render targets: ``text`` (editor-clickable ``path:line:col``),
``github`` (workflow-command annotations that surface inline on PR diffs),
and ``json`` (the machine-readable summary document the CI job uploads next
to ``BENCH_provision.json``, schema ``repro.lint/v1``).  Suppressed findings
never render but are counted in the summary, so suppression drift is visible
in the per-PR findings diff (:func:`diff_summaries`).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Mapping, Sequence

SCHEMA = "repro.lint/v1"


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location (1-indexed line, 0-indexed
    col, matching CPython's ``ast`` convention)."""

    path: str
    line: int
    col: int
    rule: str
    message: str
    suppressed: bool = False

    def to_dict(self) -> dict:
        return {
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "rule": self.rule,
            "message": self.message,
            "suppressed": self.suppressed,
        }


def active(findings: Iterable[Finding]) -> list[Finding]:
    """The findings that actually gate: everything not suppressed."""
    return [f for f in findings if not f.suppressed]


def format_text(findings: Sequence[Finding]) -> str:
    return "\n".join(
        f"{f.path}:{f.line}:{f.col + 1}: {f.rule} {f.message}"
        for f in active(findings)
    )


def format_github(findings: Sequence[Finding]) -> str:
    """GitHub Actions workflow-command annotations (``--format github``)."""

    def esc(s: str) -> str:
        # the workflow-command grammar reserves %, \r, \n in values
        return s.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")

    def esc_prop(s: str) -> str:
        # property values (file=, title=) additionally reserve the
        # parameter separators , and :
        return esc(s).replace(",", "%2C").replace(":", "%3A")

    return "\n".join(
        f"::error file={esc_prop(f.path)},line={f.line},col={f.col + 1},"
        f"title={esc_prop(f.rule)}::{esc(f.message)}"
        for f in active(findings)
    )


def summarize(
    findings: Sequence[Finding],
    *,
    files: int,
    rule_ids: Iterable[str],
    paths: Sequence[str] = (),
) -> dict:
    """The ``repro.lint/v1`` JSON document: per-rule active/suppressed
    counts plus the full finding list."""
    rules = {
        rid: {"count": 0, "suppressed": 0} for rid in sorted(rule_ids)
    }
    for f in findings:
        row = rules.setdefault(f.rule, {"count": 0, "suppressed": 0})
        row["suppressed" if f.suppressed else "count"] += 1
    return {
        "schema": SCHEMA,
        "paths": list(paths),
        "files": files,
        "findings_total": sum(r["count"] for r in rules.values()),
        "suppressed_total": sum(r["suppressed"] for r in rules.values()),
        "rules": rules,
        "findings": [f.to_dict() for f in sorted(findings)],
    }


def format_json(summary: Mapping) -> str:
    return json.dumps(summary, indent=2, sort_keys=False)


def diff_summaries(old: Mapping, new: Mapping) -> str:
    """Informational per-rule drift between two summary documents — the
    ``bench_diff.py``-style trajectory line the CI lint job prints.  Never
    raises and never gates; rule-count drift is a review signal, not an
    error (new rules and new suppressions both show up here)."""
    lines = [
        f"lint diff: files {old.get('files', 0)} -> {new.get('files', 0)}, "
        f"findings {old.get('findings_total', 0)} -> "
        f"{new.get('findings_total', 0)}, "
        f"suppressed {old.get('suppressed_total', 0)} -> "
        f"{new.get('suppressed_total', 0)}"
    ]
    old_rules = dict(old.get("rules", {}))
    new_rules = dict(new.get("rules", {}))
    for rid in sorted(set(old_rules) | set(new_rules)):
        o = old_rules.get(rid, {"count": 0, "suppressed": 0})
        n = new_rules.get(rid, {"count": 0, "suppressed": 0})
        if (o["count"], o["suppressed"]) != (n["count"], n["suppressed"]):
            lines.append(
                f"  {rid}: count {o['count']} -> {n['count']}, "
                f"suppressed {o['suppressed']} -> {n['suppressed']}"
            )
    if len(lines) == 1:
        lines.append("  per-rule counts unchanged")
    return "\n".join(lines)
