"""Runtime sanitizer: one gated implementation of the compile/leak checks.

:func:`tracer_sanitizer` wraps a region in the two runtime invariants the
static rules cannot prove from source alone:

- **no unexpected recompiles** — a :class:`~repro.obs.jaxwatch.CompileWatcher`
  over the engine's countable jitted entrypoints (or any explicit ``fns``)
  hard-fails with :class:`RecompileError` when the region adds more compiled
  programs than ``max_compiles`` allows (``exact_compiles`` pins the count
  exactly — the "cold compile == 1" form of the gate);
- **no tracer leaks** — ``jax.checking_leaks()`` makes any jit trace in the
  region raise on tracers escaping into closures (``check_leaks=False``
  opts a region out, e.g. deliberately-cached warmup code).

This replaces the hand-rolled compile gates that used to sit in
``tests/test_deferral.py``, ``tests/test_streaming.py`` and the benchmark
CLIs; the pytest fixture of the same name (``tests/conftest.py``) adds
skip-when-unobservable semantics on top.
"""
from __future__ import annotations

import contextlib
from typing import Iterator

import jax

from repro.obs.jaxwatch import CompileWatcher


class RecompileError(AssertionError):
    """The sanitized region compiled more (or other than) it declared."""


class UnobservableCacheError(RuntimeError):
    """JAX's private jit-cache API is gone, so the recompile gate cannot
    run (raised only under ``require_observable=True``; the default is to
    degrade silently, matching :class:`CompileWatcher`)."""


@contextlib.contextmanager
def tracer_sanitizer(
    fns=None,
    *,
    max_compiles: int | None = 0,
    exact_compiles: int | None = None,
    check_leaks: bool = True,
    require_observable: bool = False,
) -> Iterator[CompileWatcher]:
    """Gate a region on zero (or a declared number of) recompiles + no
    tracer leaks.  Yields the live :class:`CompileWatcher`; after the block
    its ``added`` holds the compile delta (-1 when unobservable).

    ``max_compiles=None`` disables the compile gate (leak checking only);
    ``exact_compiles`` overrides ``max_compiles`` with an equality check.
    """
    watcher = CompileWatcher(fns=fns)
    leak_ctx = jax.checking_leaks() if check_leaks else contextlib.nullcontext()
    with leak_ctx:
        with watcher:
            yield watcher
    added = watcher.added
    if added < 0:
        if require_observable and (max_compiles is not None
                                   or exact_compiles is not None):
            raise UnobservableCacheError(
                "jit cache unobservable (private _cache_size API missing) "
                "but require_observable=True"
            )
        return
    if exact_compiles is not None:
        if added != exact_compiles:
            raise RecompileError(
                f"region compiled {added} program(s), declared exactly "
                f"{exact_compiles}"
            )
    elif max_compiles is not None and added > max_compiles:
        raise RecompileError(
            f"region compiled {added} program(s), declared at most "
            f"{max_compiles} — an argument that should be jit data is "
            "probably keying the cache"
        )
