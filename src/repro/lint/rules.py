"""The repo-specific rules: RPL001–RPL006.

Each rule mechanically checks one implementation invariant the runtime test
suite otherwise only catches after the fact (see ``docs/static_analysis.md``
for the rule ↔ invariant table):

- **RPL001** — PRNG key reuse: the same key expression consumed by two
  ``jax.random.*`` sampler calls with no intervening ``split``/``fold_in``.
- **RPL002** — host control flow (``if``/``while``/``assert``) on values
  derived from the *traced* (non-static) arguments of a jitted function —
  the ``ConcretizationTypeError`` class of bug.
- **RPL003** — ``static_argnames`` outside the declared allowlist of
  genuinely static names; cost-model/workload fields must flow as jit
  *data* (the no-recompile contract).
- **RPL004** — host-library calls (``numpy``, ``time``, ``datetime``,
  stdlib ``random``) inside jitted or Pallas-kernel bodies.
- **RPL005** — array-carrying dataclasses missing
  ``jax.tree_util.register_dataclass`` wiring.
- **RPL006** — direct ``_cache_size`` pokes outside ``obs/jaxwatch.py``
  (compile accounting goes through ``CompileWatcher``).

Rules are flow-light by design: linear statement order with branch forks,
no inter-procedural analysis.  Heuristic misses are acceptable; false
positives on ``src/repro`` at HEAD are not (the CI job runs ``--strict``).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Iterator

from .context import ModuleContext, TracedRegion

RawFinding = tuple[int, int, str]  # (line, col, message)


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    check: Callable[[ModuleContext], Iterator[RawFinding]]


# ---------------------------------------------------------------------------
# RPL001 — PRNG key reuse
# ---------------------------------------------------------------------------

#: jax.random functions that *derive* keys rather than consume them
_KEY_DERIVERS = {
    "split", "fold_in", "PRNGKey", "key", "key_data", "wrap_key_data",
    "clone", "key_impl",
}


def _key_expr_id(node: ast.AST) -> str | None:
    """A stable identifier for a key expression: a bare name (``key``) or a
    dotted chain of names (``self.key``).  Anything else — calls, subscripts
    — produces a fresh key per evaluation and is not tracked."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _jax_random_attr(call: ast.Call, ctx: ModuleContext) -> str | None:
    """The ``jax.random`` function name a call resolves to, else None."""
    dotted = ctx.dotted(call.func)
    if dotted is None:
        return None
    if dotted.startswith("jax.random."):
        return dotted.removeprefix("jax.random.")
    return None


def _key_events(stmt: ast.stmt, ctx: ModuleContext) -> list[tuple]:
    """(line, col, kind, ident) events within one statement, source order.
    ``kind`` is 'consume' (key fed to a sampler), 'derive' (split/fold_in —
    reuse of the *source* key is fine) or 'assign'."""
    events: list[tuple] = []
    for node in ast.walk(stmt):
        if isinstance(node, ast.Call):
            attr = _jax_random_attr(node, ctx)
            if attr is None:
                continue
            key_arg = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "key":
                    key_arg = kw.value
            ident = _key_expr_id(key_arg) if key_arg is not None else None
            if ident is not None:
                kind = "derive" if attr in _KEY_DERIVERS else "consume"
                events.append((node.lineno, node.col_offset, kind, ident))
        elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                               ast.NamedExpr, ast.For)):
            targets: list[ast.AST]
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, ast.For):
                targets = [node.target]
            else:
                targets = [node.target]
            for tgt in targets:
                for leaf in ast.walk(tgt):
                    ident = _key_expr_id(leaf)
                    if ident is not None and isinstance(
                        leaf, (ast.Name, ast.Attribute)
                    ):
                        events.append(
                            (leaf.lineno, leaf.col_offset, "assign", ident)
                        )
    return sorted(events, key=lambda e: (e[0], e[1]))


def _scan_key_block(
    stmts: list[ast.stmt],
    counts: dict[str, int],
    ctx: ModuleContext,
    out: list[RawFinding],
) -> dict[str, int]:
    """Linear scan with branch forks: ``counts`` maps key ident -> consumes
    since last (re)assignment.  Branches fork the state and merge by max —
    one consume per exclusive branch is fine, a consume before *and* inside
    a branch is not."""
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            _scan_key_scope(stmt, ctx, out)
            continue
        if isinstance(stmt, ast.If):
            _events_into(stmt.test, counts, ctx, out)
            merged = _fork(stmt.body, stmt.orelse, counts, ctx, out)
            counts.clear()
            counts.update(merged)
            continue
        if isinstance(stmt, (ast.Try,)):
            branches = [stmt.body] + [h.body for h in stmt.handlers]
            states = [
                _scan_key_block(list(b), dict(counts), ctx, out)
                for b in branches
            ]
            merged = {}
            for st in states:
                for k, v in st.items():
                    merged[k] = max(merged.get(k, 0), v)
            counts.clear()
            counts.update(merged)
            _scan_key_block(list(stmt.finalbody), counts, ctx, out)
            continue
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While, ast.With,
                             ast.AsyncWith)):
            _events_into(stmt, counts, ctx, out, shallow=True)
            body = list(getattr(stmt, "body", []))
            _scan_key_block(body, counts, ctx, out)
            _scan_key_block(list(getattr(stmt, "orelse", [])), counts, ctx, out)
            continue
        _events_into(stmt, counts, ctx, out)
    return counts


def _fork(body, orelse, counts, ctx, out) -> dict[str, int]:
    a = _scan_key_block(list(body), dict(counts), ctx, out)
    b = _scan_key_block(list(orelse), dict(counts), ctx, out)
    merged: dict[str, int] = {}
    for st in (a, b):
        for k, v in st.items():
            merged[k] = max(merged.get(k, 0), v)
    return merged


def _events_into(node, counts, ctx, out, *, shallow=False) -> None:
    """Apply the key events of one statement (or header, for compound
    statements with ``shallow=True``) to ``counts``, emitting findings."""
    if shallow:
        # only the statement header (iter/test/items), not the nested body
        header = ast.Expr(
            value=getattr(node, "iter", None)
            or getattr(node, "test", None)
            or ast.Constant(value=None)
        )
        events = _key_events(header, ctx) if header.value is not None else []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            for leaf in ast.walk(node.target):
                ident = _key_expr_id(leaf)
                if ident is not None:
                    events.append((leaf.lineno, leaf.col_offset, "assign", ident))
    else:
        events = _key_events(node, ctx)
    for line, col, kind, ident in events:
        if kind == "assign":
            counts[ident] = 0
        elif kind == "derive":
            counts.setdefault(ident, 0)
        else:  # consume
            n = counts.get(ident, 0) + 1
            counts[ident] = n
            if n > 1:
                out.append((
                    line, col,
                    f"PRNG key `{ident}` consumed by more than one "
                    "jax.random call without an intervening split/fold_in "
                    "— identical streams alias",
                ))


def _scan_key_scope(scope, ctx: ModuleContext, out: list[RawFinding]) -> None:
    _scan_key_block(list(scope.body), {}, ctx, out)


def check_rpl001(ctx: ModuleContext) -> Iterator[RawFinding]:
    out: list[RawFinding] = []
    _scan_key_block(list(ctx.tree.body), {}, ctx, out)
    yield from out


# ---------------------------------------------------------------------------
# RPL002 — host control flow on traced values
# ---------------------------------------------------------------------------

#: attributes that are concrete at trace time even on a tracer
_TRACE_SAFE_ATTRS = {
    "shape", "ndim", "dtype", "size", "aval", "itemsize", "sharding",
    "weak_type",
}
_TRACE_SAFE_CALLS = {"len", "isinstance", "type", "id"}


def _tainted_value_uses(
    expr: ast.AST, tainted: set[str]
) -> list[tuple[int, int, str]]:
    """Name nodes in ``expr`` that read a tainted binding as a *value* —
    excluding shape/dtype-style metadata access, ``len()``, and
    ``is``/``is not`` identity tests (all concrete under trace)."""
    exempt: set[int] = set()
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in _TRACE_SAFE_ATTRS:
            for leaf in ast.walk(node.value):
                exempt.add(id(leaf))
        elif isinstance(node, ast.Call):
            fname = node.func.id if isinstance(node.func, ast.Name) else None
            if fname in _TRACE_SAFE_CALLS:
                for arg in node.args:
                    for leaf in ast.walk(arg):
                        exempt.add(id(leaf))
        elif isinstance(node, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops
        ):
            for sub in [node.left] + list(node.comparators):
                for leaf in ast.walk(sub):
                    exempt.add(id(leaf))
    uses = []
    for node in ast.walk(expr):
        if (
            isinstance(node, ast.Name)
            and isinstance(node.ctx, ast.Load)
            and node.id in tainted
            and id(node) not in exempt
        ):
            uses.append((node.lineno, node.col_offset, node.id))
    return uses


def _region_param_names(region: TracedRegion) -> set[str]:
    """Traced parameter names: the region's own args plus those of nested
    defs (vmapped/scanned inner bodies), minus static names and ``self``."""
    names: set[str] = set()
    for node in ast.walk(region.node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            a = node.args
            for arg in a.posonlyargs + a.args + a.kwonlyargs:
                names.add(arg.arg)
            if a.vararg:
                names.add(a.vararg.arg)
            if a.kwarg:
                names.add(a.kwarg.arg)
    return names - set(region.static_names) - {"self", "cls"}


def check_rpl002(ctx: ModuleContext) -> Iterator[RawFinding]:
    for region in ctx.traced_regions:
        tainted = set(_region_param_names(region))
        # one linear pass in source order: assignments propagate taint,
        # control-flow tests on tainted values are findings
        stmts = sorted(
            (n for n in ast.walk(region.node) if isinstance(n, ast.stmt)),
            key=lambda n: (n.lineno, n.col_offset),
        )
        kind = "Pallas kernel" if region.kind == "kernel" else "jitted function"
        for stmt in stmts:
            if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                value = stmt.value
                if value is None:
                    continue
                rhs_tainted = bool(_tainted_value_uses(value, tainted))
                targets = (
                    stmt.targets if isinstance(stmt, ast.Assign)
                    else [stmt.target]
                )
                # an AugAssign target reads itself (`x += rhs` is
                # `x = x + rhs`), so a clean rhs never clears its existing
                # taint — only a plain reassignment does
                retains = isinstance(stmt, ast.AugAssign)
                for tgt in targets:
                    for leaf in ast.walk(tgt):
                        if isinstance(leaf, ast.Name):
                            if rhs_tainted:
                                tainted.add(leaf.id)
                            elif not retains:
                                tainted.discard(leaf.id)
            test = None
            label = None
            if isinstance(stmt, (ast.If, ast.While)):
                test, label = stmt.test, type(stmt).__name__.lower()
            elif isinstance(stmt, ast.Assert):
                test, label = stmt.test, "assert"
            if test is None:
                continue
            for line, col, name in _tainted_value_uses(test, tainted):
                yield (
                    line, col,
                    f"host `{label}` on `{name}`, which derives from a "
                    f"traced argument of {kind} `{region.node.name}` — "
                    "this raises ConcretizationTypeError under jit (use "
                    "lax.cond/lax.select, or declare the argument in "
                    "static_argnames)",
                )


# ---------------------------------------------------------------------------
# RPL003 — static_argnames allowlist
# ---------------------------------------------------------------------------

#: The declared set of genuinely static jit argument names in this repo.
#: Everything here is a *compile-shape* fact: policy identity, level/horizon
#: counts, kernel block sizes, mesh topology, dispatch-rule strings.  Cost
#: and workload values (P/beta_on/beta_off/delta/slack/prices/demand) must
#: NEVER appear — they flow as pytree data so re-pricing and re-slacking
#: reuse the compiled program (the PR 2 / PR 7 no-recompile contracts).
STATIC_ALLOWLIST = frozenset({
    # engine shape/identity keys
    "n_levels", "max_h", "policy", "record", "t_chunk", "t_pad", "n_valid_max",
    # mesh/fleet topology
    "mesh", "axis", "h_unroll", "use_pallas", "group_sizes",
    # deferral/queue static bounds
    "cap", "rule", "max_slack",
    # serving stepper
    "window",
    # attention kernel block shapes
    "causal", "block_q", "block_k",
})

#: names that are definitely data — a hit here gets the sharper message
_KNOWN_DATA_FIELDS = frozenset({
    "P", "beta_on", "beta_off", "P_lv", "beta_on_lv", "beta_off_lv",
    "delta", "delta_lv", "slack", "prices", "price", "demand", "a", "ab",
    "predicted", "predb", "keys", "key", "windows",
})


def check_rpl003(ctx: ModuleContext) -> Iterator[RawFinding]:
    for region in ctx.traced_regions:
        if region.kind != "jit":
            # kernel partial-binds are Python closure values, not jit
            # static_argnames — nothing to allowlist
            continue
        for name in sorted(region.static_names):
            if name in STATIC_ALLOWLIST:
                continue
            if name in _KNOWN_DATA_FIELDS:
                why = (
                    "is a cost/workload field and must flow as jit data — "
                    "making it static recompiles per value and breaks the "
                    "no-recompile contract"
                )
            else:
                why = (
                    "is not in repro.lint.rules.STATIC_ALLOWLIST — if it is "
                    "genuinely static (a shape/identity compile key), add "
                    "it to the allowlist; if it is data, drop it from "
                    "static_argnames"
                )
            yield (
                region.decorator_line, 0,
                f"static_argnames entry `{name}` on `{region.node.name}` "
                f"{why}",
            )


# ---------------------------------------------------------------------------
# RPL004 — host calls inside traced bodies
# ---------------------------------------------------------------------------

#: numpy attributes that are legitimate at trace time (dtype constructors
#: and dtype queries produce concrete metadata, not host arrays)
_NP_TRACE_OK = frozenset({
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "dtype", "iinfo",
    "finfo", "promote_types", "result_type",
})

_HOST_CLOCK_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic", "time.process_time",
    "time.time_ns", "time.perf_counter_ns", "time.monotonic_ns",
    "datetime.datetime.now", "datetime.datetime.today",
    "datetime.datetime.utcnow", "datetime.date.today",
}


def check_rpl004(ctx: ModuleContext) -> Iterator[RawFinding]:
    for region in ctx.traced_regions:
        kind = "Pallas kernel" if region.kind == "kernel" else "jitted function"
        for node in ast.walk(region.node):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.dotted(node.func)
            if dotted is None:
                continue
            msg = None
            if dotted.startswith("numpy."):
                attr = dotted.removeprefix("numpy.")
                if attr.split(".")[0] not in _NP_TRACE_OK:
                    msg = (
                        f"host numpy call `{attr}` inside {kind} "
                        f"`{region.node.name}` executes at trace time on "
                        "the host — use jax.numpy so it traces"
                    )
            elif dotted in _HOST_CLOCK_CALLS:
                msg = (
                    f"host clock call `{dotted}` inside {kind} "
                    f"`{region.node.name}` is baked in at trace time and "
                    "frozen into the compiled program"
                )
            elif dotted.startswith("random."):
                msg = (
                    f"stdlib `{dotted}` inside {kind} `{region.node.name}` "
                    "draws host randomness at trace time — use jax.random "
                    "with an explicit key"
                )
            if msg is not None:
                yield (node.lineno, node.col_offset, msg)


# ---------------------------------------------------------------------------
# RPL005 — unregistered array-carrying dataclasses
# ---------------------------------------------------------------------------

_REGISTER_CALLS = {
    "jax.tree_util.register_dataclass",
    "jax.tree_util.register_pytree_node",
    "jax.tree_util.register_pytree_node_class",
    "jax.tree_util.register_static",
}


def _is_dataclass_decorated(node: ast.ClassDef, ctx: ModuleContext) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if ctx.dotted(target) in ("dataclasses.dataclass", "dataclass"):
            return True
    return False


def _has_array_field(node: ast.ClassDef) -> bool:
    for stmt in node.body:
        if isinstance(stmt, ast.AnnAssign):
            ann = ast.unparse(stmt.annotation)
            if "Array" in ann or "ndarray" in ann:
                return True
    return False


def check_rpl005(ctx: ModuleContext) -> Iterator[RawFinding]:
    registered: set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and ctx.dotted(node.func) in _REGISTER_CALLS:
            for cand in node.args[:1] + [
                kw.value for kw in node.keywords if kw.arg == "nodetype"
            ]:
                if isinstance(cand, ast.Name):
                    registered.add(cand.id)
        elif isinstance(node, ast.ClassDef):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if ctx.dotted(target) in _REGISTER_CALLS:
                    registered.add(node.name)
    for node in ast.walk(ctx.tree):
        if (
            isinstance(node, ast.ClassDef)
            and _is_dataclass_decorated(node, ctx)
            and _has_array_field(node)
            and node.name not in registered
        ):
            yield (
                node.lineno, node.col_offset,
                f"dataclass `{node.name}` carries jax.Array fields but has "
                "no jax.tree_util.register_dataclass wiring — it will not "
                "flow through jit/vmap as a pytree (register it, or "
                "suppress if it is deliberately host-only)",
            )


# ---------------------------------------------------------------------------
# RPL006 — _cache_size outside obs/jaxwatch.py
# ---------------------------------------------------------------------------

_CACHE_SIZE_HOME = ("obs/jaxwatch.py", "obs\\jaxwatch.py")


def check_rpl006(ctx: ModuleContext) -> Iterator[RawFinding]:
    if ctx.path.endswith(_CACHE_SIZE_HOME):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and node.attr == "_cache_size":
            yield (
                node.lineno, node.col_offset,
                "direct `_cache_size` access outside obs/jaxwatch.py — "
                "compile accounting goes through "
                "repro.obs.CompileWatcher (or "
                "repro.lint.sanitize.tracer_sanitizer), which owns the "
                "degradation path when the private JAX API changes",
            )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

RULES: dict[str, Rule] = {
    r.id: r
    for r in (
        Rule("RPL001", "PRNG key reuse without split/fold_in", check_rpl001),
        Rule("RPL002", "host control flow on traced values", check_rpl002),
        Rule("RPL003", "static_argnames outside the declared allowlist",
             check_rpl003),
        Rule("RPL004", "host library calls inside traced bodies",
             check_rpl004),
        Rule("RPL005", "array dataclass missing pytree registration",
             check_rpl005),
        Rule("RPL006", "_cache_size access outside obs/jaxwatch.py",
             check_rpl006),
    )
}
