"""CLI: ``python -m repro.lint src/ --strict --format github``.

Exit codes: 0 clean; 1 findings or parse errors; 2 strict-mode meta
failures (a suppression comment naming an unknown rule id).  ``--diff`` is
always informational — per-rule count drift against a baseline JSON is a
review signal, never a gate (``bench_diff.py`` convention).
"""
from __future__ import annotations

import argparse
import json
import sys

from .analyzer import lint_paths
from .findings import (
    diff_summaries,
    format_github,
    format_json,
    format_text,
)
from .rules import RULES


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX/Pallas-aware static analysis for the repro engine "
                    "(rules RPL001-RPL006; see docs/static_analysis.md)",
    )
    parser.add_argument("paths", nargs="+", help="files or directories")
    parser.add_argument("--format", choices=("text", "json", "github"),
                        default="text", help="stdout format")
    parser.add_argument("--strict", action="store_true",
                        help="also fail on suppressions naming unknown rules")
    parser.add_argument("--select", default=None,
                        help="comma-separated rule ids to run (default: all)")
    parser.add_argument("--json-out", default=None, metavar="PATH",
                        help="write the JSON summary document to PATH")
    parser.add_argument("--diff", default=None, metavar="BASELINE",
                        help="print informational per-rule drift vs a "
                             "baseline JSON summary (never affects the "
                             "exit code)")
    args = parser.parse_args(argv)

    rules = None
    if args.select:
        ids = [s.strip() for s in args.select.split(",") if s.strip()]
        missing = [s for s in ids if s not in RULES]
        if missing:
            parser.error(f"unknown rule id(s): {', '.join(missing)} "
                         f"(known: {', '.join(sorted(RULES))})")
        rules = [RULES[s] for s in ids]

    result = lint_paths(args.paths, rules=rules)
    summary = result.summary(paths=args.paths)

    visible = result.findings + result.parse_errors
    if args.format == "json":
        print(format_json(summary))
    elif args.format == "github":
        out = format_github(visible)
        if out:
            print(out)
    else:
        out = format_text(visible)
        if out:
            print(out)
        print(
            f"repro.lint: {result.files} files, "
            f"{summary['findings_total']} finding(s), "
            f"{summary['suppressed_total']} suppressed",
            file=sys.stderr,
        )

    if args.strict and result.unknown_suppressions:
        for f in result.unknown_suppressions:
            print(f"{f.path}:{f.line}: {f.message}", file=sys.stderr)

    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            fh.write(format_json(summary) + "\n")

    if args.diff:
        try:
            with open(args.diff, encoding="utf-8") as fh:
                baseline = json.load(fh)
        except (OSError, ValueError) as e:
            print(f"lint diff: unreadable baseline {args.diff!r}: {e}",
                  file=sys.stderr)
        else:
            print(diff_summaries(baseline, summary), file=sys.stderr)

    if not result.ok:
        return 1
    if args.strict and not result.strict_ok():
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
