"""Per-module analysis context shared by every lint rule.

One parse per file: :class:`ModuleContext` resolves import aliases to dotted
module paths (``jrandom.uniform`` -> ``jax.random.uniform`` under ``import
jax.random as jrandom``), discovers the module's *traced regions* — functions
decorated with ``jax.jit`` (bare or via ``functools.partial``) and Pallas
kernel bodies handed to ``pl.pallas_call`` — with their ``static_argnames``,
and indexes ``# repro-lint: disable=...`` suppression comments by line.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Iterator

#: names that alias ``jax.jit`` once resolved through the import map
JIT_CALLABLES = {"jax.jit", "jax.experimental.pjit.pjit"}
PARTIAL_CALLABLES = {"functools.partial"}

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-file)\s*=\s*"
    r"([A-Za-z0-9_]+(?:\s*,\s*[A-Za-z0-9_]+)*|all)"
)


@dataclasses.dataclass(frozen=True)
class TracedRegion:
    """One function whose body executes under trace: a jitted function or a
    Pallas kernel body.  ``static_names`` are its ``static_argnames`` (for
    kernels: empty — every ref is runtime state)."""

    node: ast.FunctionDef
    kind: str                     # "jit" | "kernel"
    static_names: frozenset[str]
    decorator_line: int


class ModuleContext:
    """Everything rules need to know about one parsed module."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.tree = tree
        self.import_map = _collect_imports(tree)
        (
            self.suppressions,
            self.standalone_lines,
            self.file_suppressions,
            self.unknown_suppressions,
        ) = _collect_suppressions(source)
        self.traced_regions = _collect_traced_regions(tree, self)

    # -- name resolution ----------------------------------------------------
    def dotted(self, node: ast.AST) -> str | None:
        """Resolve ``a.b.c`` through the import map to a dotted path, or
        None when the base is not a known import binding."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.import_map.get(node.id)
        if base is None:
            return None
        parts.append(base)
        return ".".join(reversed(parts))

    def is_suppressed(self, rule: str, line: int) -> bool:
        """True when ``rule`` is disabled at ``line`` — by a trailing
        comment on the line itself, a standalone suppression comment on the
        line above, or a file-level ``disable-file``."""
        if rule in self.file_suppressions or "all" in self.file_suppressions:
            return True
        here = self.suppressions.get(line, ())
        if rule in here or "all" in here:
            return True
        if line - 1 in self.standalone_lines:
            above = self.suppressions.get(line - 1, ())
            if rule in above or "all" in above:
                return True
        return False


def _collect_imports(tree: ast.Module) -> dict[str, str]:
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                local = alias.asname or alias.name.split(".")[0]
                out[local] = alias.name if alias.asname else alias.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom):
            mod = ("." * node.level) + (node.module or "")
            for alias in node.names:
                if alias.name == "*":
                    continue
                local = alias.asname or alias.name
                out[local] = f"{mod}.{alias.name}" if mod else alias.name
    return out


def _collect_suppressions(
    source: str,
) -> tuple[dict[int, set[str]], set[int], set[str], list[tuple[int, str]]]:
    """Map line -> suppressed rule ids, the lines whose suppression comment
    stands alone (those scope to the *next* line too), file-level
    suppressions, and ``(line, id)`` pairs whose id is not a known rule
    (reported under ``--strict``)."""
    from .rules import RULES  # late import: rules.py imports this module

    by_line: dict[int, set[str]] = {}
    file_level: set[str] = set()
    unknown: list[tuple[int, str]] = []
    standalone: set[int] = set()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        tokens = []
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        kind, ids_raw = m.group(1), m.group(2)
        ids = {s.strip() for s in ids_raw.split(",")}
        for rid in ids:
            if rid != "all" and rid not in RULES:
                unknown.append((tok.start[0], rid))
        if kind == "disable-file":
            file_level |= ids
        else:
            line = tok.start[0]
            by_line.setdefault(line, set()).update(ids)
            if tok.line[: tok.start[1]].strip() == "":
                standalone.add(line)
    return by_line, standalone, file_level, unknown


def _static_names_from_call(
    call: ast.Call, fn_args: list[str]
) -> frozenset[str]:
    """Extract static argument names from a ``partial(jax.jit, ...)`` or
    ``jax.jit(...)`` call: ``static_argnames`` literals plus
    ``static_argnums`` indices mapped onto the function signature."""
    names: set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names |= set(_str_elements(kw.value))
        elif kw.arg == "static_argnums":
            for idx in _int_elements(kw.value):
                if 0 <= idx < len(fn_args):
                    names.add(fn_args[idx])
    return frozenset(names)


def _str_elements(node: ast.AST) -> Iterator[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                yield elt.value


def _int_elements(node: ast.AST) -> Iterator[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        yield node.value
    elif isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                yield elt.value


def _fn_arg_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [x.arg for x in a.posonlyargs + a.args]


def _collect_traced_regions(
    tree: ast.Module, ctx: ModuleContext
) -> list[TracedRegion]:
    regions: list[TracedRegion] = []
    #: kernel fn name -> (call line, partial-bound kwarg names, n positional
    #: partial binds).  Partial-bound arguments are *static* at trace time —
    #: only the remaining (ref) parameters are traced state.
    kernel_sites: dict[str, tuple[int, frozenset[str], int]] = {}

    # pass 0: local bindings `kernel = functools.partial(_fn, …)` / `k = _fn`,
    # kept per line so `pl.pallas_call(kernel, …)` resolves to the *nearest
    # preceding* binding of that name
    bindings: dict[str, list[tuple[int, ast.expr]]] = {}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            bindings.setdefault(node.targets[0].id, []).append(
                (node.lineno, node.value)
            )

    def _resolve(cand: ast.expr, at_line: int) -> ast.expr:
        if isinstance(cand, ast.Name):
            best = None
            for line, value in bindings.get(cand.id, ()):
                if line <= at_line and (best is None or line > best[0]):
                    best = (line, value)
            if best is not None and not isinstance(best[1], ast.Name):
                return best[1]
        return cand

    # pass 1: kernels handed to a pallas_call anywhere in the module —
    # directly, through functools.partial(kernel_fn, ...), or via a local
    # binding from pass 0
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = ctx.dotted(node.func)
        if callee is None or not callee.endswith("pallas_call"):
            continue
        cands = list(node.args[:1]) + [
            kw.value for kw in node.keywords if kw.arg in ("kernel", "f")
        ]
        for cand in cands:
            cand = _resolve(cand, node.lineno)
            bound_kw: frozenset[str] = frozenset()
            n_pos = 0
            if (
                isinstance(cand, ast.Call)
                and ctx.dotted(cand.func) in PARTIAL_CALLABLES
                and cand.args
            ):
                bound_kw = frozenset(
                    kw.arg for kw in cand.keywords if kw.arg is not None
                )
                n_pos = len(cand.args) - 1
                cand = cand.args[0]
            if isinstance(cand, ast.Name):
                kernel_sites[cand.id] = (node.lineno, bound_kw, n_pos)

    # pass 2: function defs — jit decorators and kernel-name matches
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        jitted = None
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            dotted = ctx.dotted(target)
            if dotted in JIT_CALLABLES:
                static = (
                    _static_names_from_call(dec, _fn_arg_names(node))
                    if isinstance(dec, ast.Call)
                    else frozenset()
                )
                jitted = TracedRegion(node, "jit", static, dec.lineno)
            elif (
                isinstance(dec, ast.Call)
                and dotted in PARTIAL_CALLABLES
                and dec.args
                and ctx.dotted(dec.args[0]) in JIT_CALLABLES
            ):
                jitted = TracedRegion(
                    node,
                    "jit",
                    _static_names_from_call(dec, _fn_arg_names(node)),
                    dec.lineno,
                )
        if jitted is not None:
            regions.append(jitted)
        elif node.name in kernel_sites:
            _line, bound_kw, n_pos = kernel_sites[node.name]
            params = _fn_arg_names(node)
            static = frozenset(params[:n_pos]) | bound_kw
            regions.append(
                TracedRegion(node, "kernel", static, node.lineno)
            )
    return regions
