"""Piecewise-constant, right-continuous step functions on [0, T]."""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Sequence


@dataclasses.dataclass
class StepFn:
    """Right-continuous step function: value ``values[i]`` on [times[i], times[i+1])."""

    times: list[float]   # strictly increasing, times[0] == 0
    values: list[float]
    horizon: float

    def __post_init__(self) -> None:
        assert self.times and self.times[0] == 0.0
        assert len(self.times) == len(self.values)
        for u, v in zip(self.times[:-1], self.times[1:]):
            assert v > u, f"times must be strictly increasing, got {u} -> {v}"

    def at(self, t: float) -> float:
        i = bisect.bisect_right(self.times, t) - 1
        return self.values[max(i, 0)]

    def before(self, t: float) -> float:
        i = bisect.bisect_left(self.times, t) - 1
        return self.values[max(i, 0)]

    def integral(self) -> float:
        total = 0.0
        for i, v in enumerate(self.values):
            t0 = self.times[i]
            t1 = self.times[i + 1] if i + 1 < len(self.times) else self.horizon
            total += v * (t1 - t0)
        return total

    def switching(self) -> tuple[float, float]:
        """(total up-moves, total down-moves) across breakpoints."""
        up = down = 0.0
        for u, v in zip(self.values[:-1], self.values[1:]):
            if v > u:
                up += v - u
            else:
                down += u - v
        return up, down

    def simplified(self) -> "StepFn":
        """Merge consecutive intervals with equal values."""
        ts, vs = [self.times[0]], [self.values[0]]
        for t, v in zip(self.times[1:], self.values[1:]):
            if v != vs[-1]:
                ts.append(t)
                vs.append(v)
        return StepFn(ts, vs, self.horizon)

    def equals(self, other: "StepFn", tol: float = 0.0) -> bool:
        a, b = self.simplified(), other.simplified()
        if len(a.times) != len(b.times):
            return False
        return all(
            abs(ta - tb) <= tol and va == vb
            for ta, tb, va, vb in zip(a.times, b.times, a.values, b.values)
        )


def from_breakpoints(times: Sequence[float], values: Sequence[float], horizon: float) -> StepFn:
    return StepFn(list(times), list(values), horizon).simplified()


def pointwise_max(f: StepFn, g: StepFn) -> StepFn:
    times = sorted(set(f.times) | set(g.times))
    vals = [max(f.at(t), g.at(t)) for t in times]
    return StepFn(times, vals, f.horizon).simplified()


def build(horizon: float, breaks: Sequence[tuple[float, float]]) -> StepFn:
    """breaks: (time, new value) pairs; first must be (0, v0)."""
    ts = [b[0] for b in breaks]
    vs = [b[1] for b in breaks]
    return StepFn(ts, vs, horizon).simplified()


def map_values(f: StepFn, fn: Callable[[float], float]) -> StepFn:
    return StepFn(list(f.times), [fn(v) for v in f.values], f.horizon).simplified()
