"""Critical times / critical segments (paper Section III-A, Proposition 1).

Implements the paper's Critical Segment Construction Procedure on a
:class:`~repro.core.events.BrickTrace` and classifies every segment as one of
the four workload types:

  Type-I   non-decreasing
  Type-II  step-decreasing (drops by one at the left end, never recovers)
  Type-III U-shape (drops by one, flat, recovers exactly at the right end)
  Type-IV  canyon-shape (drops, wanders strictly below, recovers at right end)
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

from .events import ARRIVAL, DEPARTURE, BrickTrace


class SegmentType(enum.Enum):
    TYPE_I = "I"
    TYPE_II = "II"
    TYPE_III = "III"
    TYPE_IV = "IV"


@dataclasses.dataclass(frozen=True)
class CriticalSegment:
    start: float
    end: float
    start_level: int          # a at the segment start (left limit for departures)
    end_level: int
    seg_type: SegmentType


def critical_times(trace: BrickTrace) -> list[float]:
    """The paper's Critical Segment Construction Procedure.

    T_1 = 0 (treated as an arrival epoch when no event occurs there).  Then
    inductively:
      * from an arrival epoch, the next critical time is the first departure;
      * from a departure epoch with pre-departure level L, the next critical
        time is the first later arrival that returns a(.) to L; if none
        exists, the next departure epoch; if neither exists, the horizon T.
    """
    events = trace.events
    T = trace.horizon

    # Prefix values: a right after event i.
    a0 = trace.initial_count()
    after = []
    cur = a0
    for e in events:
        cur += 1 if e.kind == ARRIVAL else -1
        after.append(cur)

    def a_after_index(i: int) -> int:
        return after[i] if i >= 0 else a0

    crits = [0.0]
    # Determine the kind of the current critical time.
    if events and events[0].time == 0.0:
        kind = events[0].kind
        idx = 0
    else:
        kind = ARRIVAL  # "if no job departs or arrives at T_1, it is an arrival epoch"
        idx = -1        # index of the event at the current critical time (-1: none)

    while True:
        if kind == ARRIVAL:
            # next critical time: first departure epoch after current
            nxt = None
            for j in range(idx + 1, len(events)):
                if events[j].kind == DEPARTURE:
                    nxt = j
                    break
            if nxt is None:
                if crits[-1] < T:
                    crits.append(T)
                break
            crits.append(events[nxt].time)
            idx, kind = nxt, DEPARTURE
        else:
            # departure epoch: level before this departure
            level_before = a_after_index(idx - 1) if idx >= 0 else a0
            # first arrival tau after idx with a(tau) == level_before
            nxt = None
            for j in range(idx + 1, len(events)):
                if events[j].kind == ARRIVAL and after[j] == level_before:
                    nxt = j
                    break
            if nxt is not None:
                crits.append(events[nxt].time)
                idx, kind = nxt, ARRIVAL
                continue
            # otherwise: next departure epoch
            nxt = None
            for j in range(idx + 1, len(events)):
                if events[j].kind == DEPARTURE:
                    nxt = j
                    break
            if nxt is None:
                if crits[-1] < T:
                    crits.append(T)
                break
            crits.append(events[nxt].time)
            idx, kind = nxt, DEPARTURE
    return crits


def classify_segment(trace: BrickTrace, t0: float, t1: float) -> SegmentType:
    """Classify workload on [t0, t1] per Proposition 1."""
    # Values strictly inside the segment plus boundary limits.
    lvl0 = trace.a_before(t0) if _is_departure_at(trace, t0) else trace.a_at(t0)
    lvl1 = trace.a_at(t1)
    interior = _interior_values(trace, t0, t1)
    if not _is_departure_at(trace, t0):
        return SegmentType.TYPE_I
    # t0 is a departure: level drops to lvl0 - 1 right after t0.
    if lvl1 == lvl0:
        if all(v == lvl0 - 1 for v in interior):
            return SegmentType.TYPE_III
        return SegmentType.TYPE_IV
    return SegmentType.TYPE_II


def critical_segments(trace: BrickTrace) -> list[CriticalSegment]:
    crits = critical_times(trace)
    segs = []
    for t0, t1 in zip(crits[:-1], crits[1:]):
        st = classify_segment(trace, t0, t1)
        lvl0 = trace.a_before(t0) if _is_departure_at(trace, t0) else trace.a_at(t0)
        segs.append(CriticalSegment(t0, t1, lvl0, trace.a_at(t1), st))
    return segs


def _is_departure_at(trace: BrickTrace, t: float) -> bool:
    return any(e.time == t and e.kind == DEPARTURE for e in trace.events)


def _interior_values(trace: BrickTrace, t0: float, t1: float) -> Sequence[int]:
    times, vals = trace.a_breakpoints()
    out = []
    for tt, vv in zip(times, vals):
        if t0 < tt < t1:
            out.append(vv)
    # Also the value right after t0 (constant until the first interior event).
    out.insert(0, trace.a_at(t0))
    return out
