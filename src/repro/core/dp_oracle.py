"""Brute-force dynamic-programming oracle for SCP (validation only).

Solves the discrete-slot problem exactly:

    min  sum_t P * x_t  +  sum_t beta_on*[x_t - x_{t-1}]+ + beta_off*[...]-
    s.t. x_t >= a_t,  x_0 = a_0,  x_{T-1} = a_{T-1},  x_t integer

by DP over (slot, level).  O(T * X^2) with X = max(a) + slack; used in tests
to certify the critical-segment construction and the per-level decomposition.
"""
from __future__ import annotations

import numpy as np

from .costs import CostModel


def dp_optimal_cost(a: np.ndarray, costs: CostModel, slack: int | None = None) -> float:
    a = np.asarray(a, dtype=np.int64)
    T = len(a)
    if T == 0:
        return 0.0
    x_max = int(a.max()) + (slack if slack is not None else int(a.max()) + 1)
    levels = np.arange(x_max + 1, dtype=np.float64)

    INF = np.inf
    # dp[x] = min cost of slots 0..t with x_t = x
    dp = np.full(x_max + 1, INF)
    dp[int(a[0])] = costs.P * a[0]
    for t in range(1, T):
        # transition cost from y (prev) to x: beta_on*(x-y)+ + beta_off*(y-x)+
        diff = levels[None, :] - levels[:, None]       # [prev y, next x]
        trans = np.where(diff > 0, costs.beta_on * diff, -costs.beta_off * diff)
        cand = dp[:, None] + trans                     # [y, x]
        ndp = cand.min(axis=0) + costs.P * levels
        ndp[: int(a[t])] = INF                         # x_t >= a_t
        if t == T - 1:
            keep = np.full_like(ndp, INF)
            keep[int(a[t])] = ndp[int(a[t])]           # x_{T-1} = a_{T-1}
            ndp = keep
        dp = ndp
    return float(dp.min())
