"""Competitive-ratio and cost-saving analysis helpers (paper Section V)."""
from __future__ import annotations

import dataclasses

import numpy as np

from . import fluid
from .costs import CostModel
from .events import BrickTrace
from .offline import a0_cost
from .online import simulate
from .ski_rental import (
    A1Deterministic,
    A2Randomized,
    A3Randomized,
    theoretical_ratio,
)

POLICY_CLASSES = {
    "A1": A1Deterministic,
    "A2": A2Randomized,
    "A3": A3Randomized,
}


@dataclasses.dataclass
class RatioReport:
    policy: str
    alpha: float
    empirical: float
    theoretical: float


def empirical_ratio_brick(
    trace: BrickTrace,
    policy_name: str,
    alpha: float,
    costs: CostModel,
    n_runs: int = 1,
    seed: int = 0,
) -> RatioReport:
    """Empirical competitive ratio of a policy on one brick trace."""
    opt = a0_cost(trace, costs)
    tot = 0.0
    for r in range(n_runs):
        rng = np.random.default_rng(seed + r)
        pol = POLICY_CLASSES[policy_name](alpha=alpha)
        tot += simulate(trace, pol, costs, rng=rng).cost
    emp = (tot / n_runs) / opt
    return RatioReport(policy_name, alpha, emp, theoretical_ratio(policy_name, alpha))


def empirical_ratio_fluid(
    a: np.ndarray,
    policy_name: str,
    window: int,
    costs: CostModel,
    n_runs: int = 1,
    seed: int = 0,
) -> RatioReport:
    opt = fluid.fluid_cost(a, "offline", costs).cost
    tot = 0.0
    for r in range(n_runs):
        rng = np.random.default_rng(seed + r)
        tot += fluid.fluid_cost(a, policy_name, costs, window=window, rng=rng).cost
    alpha = min(1.0, (window + 1) / costs.delta)
    return RatioReport(policy_name, alpha, (tot / n_runs) / opt,
                       theoretical_ratio(policy_name, alpha))


def cost_reduction_table(
    a: np.ndarray,
    costs: CostModel,
    windows: list[int],
    n_runs: int = 5,
    seed: int = 0,
) -> dict[str, list[float]]:
    """Paper Fig. 4b: cost reduction vs static provisioning per window size."""
    static = fluid.fluid_cost(a, "static", costs).cost
    out: dict[str, list[float]] = {"window": [float(w) for w in windows]}
    out["offline"] = [1.0 - fluid.fluid_cost(a, "offline", costs).cost / static] * len(windows)
    for name in ("A1", "A2", "A3"):
        vals = []
        for w in windows:
            tot = 0.0
            for r in range(n_runs):
                rng = np.random.default_rng(seed + r)
                tot += fluid.fluid_cost(a, name, costs, window=w, rng=rng).cost
            vals.append(1.0 - (tot / n_runs) / static)
        out[name] = vals
    out["delayedoff"] = [
        1.0 - fluid.fluid_cost(a, "delayedoff", costs).cost / static
    ] * len(windows)
    out["lcp"] = [
        (1.0 - fluid.fluid_cost(a, "lcp", costs, window=w).cost / static)
        if w >= 1
        else float("nan")
        for w in windows
    ]
    return out
