"""Offline optimum for problem SCP (paper Section III).

Two independent implementations, cross-checked in tests:

1. :func:`optimal_schedule_constructed` — the literal *Optimal Solution
   Construction Procedure*: visit critical segments, apply the Type I-IV
   rules (with the greedy (tau_l, tau_l') pairing inside Type-IV segments).

2. :func:`a0_schedule` / :func:`a0_cost` — the decentralized offline
   algorithm A0 (Section III-D): last-empty-server-first dispatch + each
   server solving its ski-rental instance with hindsight.  Its schedule is
   ``x(t) = a(t) + #idle servers``, where a server whose LIFO empty period
   has length g stays idle iff g <= Delta.  Theorem 5: both coincide.
"""
from __future__ import annotations

from .costs import CostModel, schedule_cost
from .events import BrickTrace
from .segments import SegmentType, critical_segments
from .stepfn import StepFn, from_breakpoints


# ---------------------------------------------------------------------------
# A0: decentralized offline optimum from the LIFO matching
# ---------------------------------------------------------------------------

def a0_schedule(trace: BrickTrace, costs: CostModel) -> StepFn:
    """x(t) produced by algorithm A0 (optimal, Theorem 5)."""
    delta = costs.delta
    times, vals = trace.a_breakpoints()
    # Idle-server increments: for each matched empty period [dep, arr] with
    # arr - dep <= Delta the server stays idle, adding +1 to x on [dep, arr).
    deltas: dict[float, int] = {}
    for dep, arr in trace.empty_periods():
        if arr is not None and (arr - dep) <= delta:
            deltas[dep] = deltas.get(dep, 0) + 1
            deltas[arr] = deltas.get(arr, 0) - 1
    all_times = sorted(set(times) | set(deltas))
    x_vals = []
    idle = 0
    ai = 0
    cur_a = vals[0]
    for t in all_times:
        while ai + 1 < len(times) and times[ai + 1] <= t:
            ai += 1
            cur_a = vals[ai]
        idle += deltas.get(t, 0)
        x_vals.append(cur_a + idle)
    return from_breakpoints(all_times, x_vals, trace.horizon)


def a0_cost(trace: BrickTrace, costs: CostModel) -> float:
    """Closed-form optimal cost from the LIFO matching.

    cost = P * busy + sum_matched min(P*gap, beta_on+beta_off)
         + beta_off * (#unmatched departures)   [forced by x(T)=a(T)]
         + beta_on  * (#unmatched arrivals)     [pre-t0 off servers popped]
    """
    total = costs.P * trace.busy_time()
    for dep, arr in trace.empty_periods():
        if arr is None:
            total += costs.beta_off
        else:
            total += min(costs.P * (arr - dep), costs.beta)
    total += costs.beta_on * trace.unmatched_arrivals()
    return total


# ---------------------------------------------------------------------------
# Literal Optimal Solution Construction Procedure
# ---------------------------------------------------------------------------

def optimal_schedule_constructed(trace: BrickTrace, costs: CostModel) -> StepFn:
    delta = costs.delta
    segs = critical_segments(trace)
    breaks: list[tuple[float, float]] = [(0.0, float(trace.initial_count()))]

    def set_piece(t0: float, t1: float, fn_breaks: list[tuple[float, float]]) -> None:
        breaks.extend(fn_breaks)

    a_times, a_vals = trace.a_breakpoints()

    def a_breaks_in(t0: float, t1: float) -> list[tuple[float, float]]:
        """Breakpoints of a(t) restricted to [t0, t1)."""
        out = [(t0, float(_a_at(a_times, a_vals, t0)))]
        for tt, vv in zip(a_times, a_vals):
            if t0 < tt < t1:
                out.append((tt, float(vv)))
        return out

    for seg in segs:
        t0, t1 = seg.start, seg.end
        if seg.seg_type in (SegmentType.TYPE_I, SegmentType.TYPE_II):
            set_piece(t0, t1, a_breaks_in(t0, t1))
        elif seg.seg_type == SegmentType.TYPE_III:
            if costs.beta >= costs.P * (t1 - t0):
                set_piece(t0, t1, [(t0, float(seg.start_level))])
            else:
                set_piece(t0, t1, a_breaks_in(t0, t1))
        else:  # TYPE_IV
            if costs.beta >= costs.P * (t1 - t0):
                set_piece(t0, t1, [(t0, float(seg.start_level))])
            else:
                pairs = _greedy_pairs(trace, t0, t1, delta)
                cursor = t0
                for dep, arr in pairs:
                    if dep > cursor:
                        set_piece(cursor, dep, a_breaks_in(cursor, dep))
                    # flat at the pre-departure level across [dep, arr)
                    lvl = float(_a_before(a_times, a_vals, dep))
                    set_piece(dep, arr, [(dep, lvl)])
                    cursor = arr
                if cursor < t1:
                    set_piece(cursor, t1, a_breaks_in(cursor, t1))
    # De-duplicate times keeping the last value written at each breakpoint
    # (segment boundaries are written by both neighbours).
    by_time: dict[float, float] = {}
    for t, v in breaks:
        by_time[t] = v
    ts = sorted(by_time)
    return from_breakpoints(ts, [by_time[t] for t in ts], trace.horizon)


def _greedy_pairs(
    trace: BrickTrace, t0: float, t1: float, delta: float
) -> list[tuple[float, float]]:
    """The (tau_l, tau_l') pairs of the Type-IV rule.

    Scan departures in [t0, t1] in time order; select the first whose LIFO
    matched arrival satisfies gap <= Delta; skip to after its arrival; repeat.
    """
    match = trace.lifo_matching()
    deps = sorted(
        (trace.events[i].time, arr)
        for i, arr in match.items()
        if arr is not None and t0 < trace.events[i].time and arr <= t1
    )
    pairs = []
    cursor = t0
    for dep, arr in deps:
        if dep < cursor:
            continue
        if arr - dep <= delta:
            pairs.append((dep, arr))
            cursor = arr
    return pairs


def _a_at(times: list[float], vals: list[int], t: float) -> int:
    v = vals[0]
    for tt, vv in zip(times, vals):
        if tt <= t:
            v = vv
        else:
            break
    return v


def _a_before(times: list[float], vals: list[int], t: float) -> int:
    v = vals[0]
    for tt, vv in zip(times, vals):
        if tt < t:
            v = vv
        else:
            break
    return v


def optimal_cost(trace: BrickTrace, costs: CostModel) -> float:
    """Optimal SCP cost (via the constructed schedule)."""
    x = optimal_schedule_constructed(trace, costs)
    return schedule_cost(x, costs, final_level=float(trace.final_count()))
