"""Future-aware ski-rental policies (paper Section IV).

Each policy answers a single question for a just-emptied server: *how long do
I stay idle before (peeking into the prediction window and possibly) turning
off?*  The prediction window has size ``alpha * Delta``; with the
last-empty-server-first dispatch a server can tell from predicted workload
whether it will be popped during the window (Section IV-B).

Policies return a wait time ``W``; the simulator then peeks: if the server's
next pop is within ``(t_dep + W, t_dep + W + alpha*Delta]`` it stays idle,
otherwise it turns off.

NOTE on A3's distribution: the paper's stated ``P(Z=0) = 1 - alpha/(e-1+alpha)``
does not normalize against its own density (whose total mass is
``1 - alpha/(e-1+alpha)``).  We use the corrected atom
``P(Z=0) = alpha/(e-1+alpha)``; tests verify the resulting empirical
competitive ratio is within the claimed ``e/(e-1+alpha)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Protocol

import numpy as np

E = math.e


class SkiRentalPolicy(Protocol):
    alpha: float

    def wait_time(self, delta: float, rng: np.random.Generator) -> float:
        """Idle duration before the (single) peek-and-decide moment."""
        ...


@dataclasses.dataclass(frozen=True)
class OfflinePolicy:
    """Hindsight-optimal: handled specially by the simulator (gap vs Delta)."""

    alpha: float = 1.0

    def wait_time(self, delta: float, rng: np.random.Generator) -> float:  # pragma: no cover
        return 0.0


@dataclasses.dataclass(frozen=True)
class A1Deterministic:
    """Algorithm A1: wait (1-alpha)*Delta, then peek. Ratio 2 - alpha."""

    alpha: float = 0.0

    def wait_time(self, delta: float, rng: np.random.Generator) -> float:
        return (1.0 - self.alpha) * delta

    def competitive_ratio(self) -> float:
        return 2.0 - self.alpha


@dataclasses.dataclass(frozen=True)
class A2Randomized:
    """Algorithm A2: Z ~ e^{z/((1-a)D)} / ((e-1)(1-a)D) on [0,(1-a)D].

    Ratio (e - alpha) / (e - 1).
    """

    alpha: float = 0.0

    def wait_time(self, delta: float, rng: np.random.Generator) -> float:
        span = (1.0 - self.alpha) * delta
        if span <= 0.0:
            return 0.0
        u = rng.uniform()
        return span * math.log1p(u * (E - 1.0))

    def competitive_ratio(self) -> float:
        return (E - self.alpha) / (E - 1.0)


@dataclasses.dataclass(frozen=True)
class A3Randomized:
    """Algorithm A3: atom at 0 w.p. alpha/(e-1+alpha), else A2's density.

    Ratio e / (e - 1 + alpha) — optimal randomized under LIFO dispatch.
    """

    alpha: float = 0.0

    def wait_time(self, delta: float, rng: np.random.Generator) -> float:
        p0 = self.alpha / (E - 1.0 + self.alpha)
        if rng.uniform() < p0:
            return 0.0
        span = (1.0 - self.alpha) * delta
        if span <= 0.0:
            return 0.0
        u = rng.uniform()
        return span * math.log1p(u * (E - 1.0))

    def competitive_ratio(self) -> float:
        return E / (E - 1.0 + self.alpha)


@dataclasses.dataclass(frozen=True)
class BreakEven:
    """Classic break-even (no future info): wait Delta then turn off. Ratio 2.

    Identical to A1 with alpha = 0 (special case noted in Section IV-A).
    """

    alpha: float = 0.0

    def wait_time(self, delta: float, rng: np.random.Generator) -> float:
        return delta


@dataclasses.dataclass(frozen=True)
class DelayedOffPolicy:
    """DELAYEDOFF's per-server timer (t_wait = Delta by default); no peek."""

    alpha: float = 0.0  # never uses future info
    t_wait_factor: float = 1.0

    def wait_time(self, delta: float, rng: np.random.Generator) -> float:
        return self.t_wait_factor * delta


def theoretical_ratio(name: str, alpha: float) -> float:
    if name == "A1":
        return 2.0 - alpha
    if name == "A2":
        return (E - alpha) / (E - 1.0)
    if name == "A3":
        return E / (E - 1.0 + alpha)
    raise KeyError(name)
