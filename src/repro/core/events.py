"""Continuous-time "brick" workload model (paper Section II-A).

Jobs are "elephants": each occupies one full server for its entire sojourn.
``a(t)`` is the number of concurrent jobs; it changes by +/-1 at arrival /
departure epochs and no two epochs coincide.

The central combinatorial object is the *LIFO matching* between departures and
arrivals induced by the paper's last-empty-server-first dispatching: when a job
departs, its server is pushed on a stack; an arrival pops the most recently
pushed server.  A departure at time ``tau`` is therefore matched to the first
arrival ``tau' > tau`` with ``a(tau'^-) + 1 == a(tau^-)`` and
``a(t) < a(tau^-)`` for all ``t`` in ``(tau, tau')`` — the parenthesis
structure used throughout Section III/IV of the paper.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

ARRIVAL = 1
DEPARTURE = -1


@dataclasses.dataclass(frozen=True)
class Job:
    """One elephant job: occupies one server on [arrival, departure)."""

    arrival: float
    departure: float

    def __post_init__(self) -> None:
        if not self.departure > self.arrival:
            raise ValueError(f"job must have departure > arrival, got {self}")


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    kind: int  # ARRIVAL or DEPARTURE
    job: int   # index into the trace's job list


class BrickTrace:
    """A finite set of jobs on a horizon [0, T] with distinct event epochs."""

    def __init__(self, jobs: Sequence[Job], horizon: float):
        self.jobs = list(jobs)
        self.horizon = float(horizon)
        for j in self.jobs:
            if j.arrival < 0 or j.departure > self.horizon:
                raise ValueError(f"job {j} outside horizon [0, {self.horizon}]")
        events = []
        for idx, j in enumerate(self.jobs):
            if j.arrival > 0:
                events.append(Event(j.arrival, ARRIVAL, idx))
            if j.departure < self.horizon:
                events.append(Event(j.departure, DEPARTURE, idx))
        events.sort(key=lambda e: e.time)
        times = [e.time for e in events]
        if len(set(times)) != len(times):
            raise ValueError("simultaneous events are not allowed (paper assumption)")
        self.events: list[Event] = events
        self._times = times

    # ----- workload step function a(t) (right-continuous) -----
    def initial_count(self) -> int:
        return sum(1 for j in self.jobs if j.arrival <= 0)

    def a_breakpoints(self) -> tuple[list[float], list[int]]:
        """Breakpoint times (starting at 0) and right-continuous values of a(t)."""
        times = [0.0]
        vals = [self.initial_count()]
        for e in self.events:
            times.append(e.time)
            vals.append(vals[-1] + (1 if e.kind == ARRIVAL else -1))
        return times, vals

    def a_at(self, t: float) -> int:
        """Right-continuous a(t)."""
        times, vals = self.a_breakpoints()
        i = bisect.bisect_right(times, t) - 1
        return vals[max(i, 0)]

    def a_before(self, t: float) -> int:
        """Left limit a(t^-)."""
        times, vals = self.a_breakpoints()
        i = bisect.bisect_left(times, t) - 1
        return vals[max(i, 0)]

    def final_count(self) -> int:
        times, vals = self.a_breakpoints()
        return vals[-1]

    # ----- LIFO matching -----
    def lifo_matching(self) -> dict[int, float | None]:
        """Map departure-event index -> matched arrival time (or None).

        Mirrors the last-empty-server-first stack: a departure pushes, an
        arrival pops the most recent unmatched departure.  Arrivals with an
        empty stack pop a server that was off before t=0 (unmatched arrival).
        """
        match: dict[int, float | None] = {}
        stack: list[int] = []  # indices into self.events of unmatched departures
        for i, e in enumerate(self.events):
            if e.kind == DEPARTURE:
                stack.append(i)
                match[i] = None
            else:
                if stack:
                    match[stack.pop()] = e.time
        return match

    def empty_periods(self) -> list[tuple[float, float | None]]:
        """(departure time, matched arrival time or None) per departure event."""
        m = self.lifo_matching()
        return [(self.events[i].time, m[i]) for i in sorted(m)]

    def unmatched_arrivals(self) -> int:
        """Arrivals that pop a pre-t0 off server (incur beta_on)."""
        stack = 0
        unmatched = 0
        for e in self.events:
            if e.kind == DEPARTURE:
                stack += 1
            else:
                if stack:
                    stack -= 1
                else:
                    unmatched += 1
        return unmatched

    def busy_time(self) -> float:
        """Total server-busy time inside the horizon."""
        return sum(
            min(j.departure, self.horizon) - max(j.arrival, 0.0) for j in self.jobs
        )

    def max_concurrency(self) -> int:
        _, vals = self.a_breakpoints()
        return max(vals) if vals else 0


# --------------------------------------------------------------------------
# Generators
# --------------------------------------------------------------------------

def generate_brick_trace(
    rng: np.random.Generator,
    horizon: float = 200.0,
    rate: float = 1.0,
    mean_duration: float = 4.0,
    diurnal: bool = True,
    max_jobs: int = 100_000,
) -> BrickTrace:
    """Poisson-ish arrivals with time-varying rate and exponential sojourns.

    Event times are de-duplicated by tiny jitter so no two epochs coincide.
    """
    jobs: list[Job] = []
    t = 0.0
    while t < horizon and len(jobs) < max_jobs:
        lam = rate
        if diurnal:
            lam = rate * (1.0 + 0.8 * math.sin(2 * math.pi * t / max(horizon / 3.0, 1e-9)))
            lam = max(lam, 0.05 * rate)
        t += rng.exponential(1.0 / lam)
        if t >= horizon:
            break
        dur = rng.exponential(mean_duration)
        dep = min(t + max(dur, 1e-6), horizon - 1e-9)
        if dep > t:
            jobs.append(Job(t, dep))
    return _deduplicate(jobs, horizon, rng)


def _deduplicate(jobs: Iterable[Job], horizon: float, rng: np.random.Generator) -> BrickTrace:
    """Jitter event epochs until all are distinct (paper's no-tie assumption)."""
    jobs = list(jobs)
    for _ in range(100):
        times = []
        for j in jobs:
            times.extend((j.arrival, j.departure))
        if len(set(times)) == len(times):
            break
        seen: set[float] = set()
        fixed: list[Job] = []
        for j in jobs:
            a, d = j.arrival, j.departure
            while a in seen:
                a += float(rng.uniform(1e-7, 1e-5))
            seen.add(a)
            while d in seen or d <= a:
                d += float(rng.uniform(1e-7, 1e-5))
            seen.add(d)
            fixed.append(Job(min(a, horizon - 1e-9), min(max(d, a + 1e-9), horizon)))
        jobs = fixed
    return BrickTrace(jobs, horizon)


def trace_from_intervals(intervals: Sequence[tuple[float, float]], horizon: float) -> BrickTrace:
    """Build a trace from explicit (arrival, departure) pairs (for tests)."""
    return BrickTrace([Job(a, d) for a, d in intervals], horizon)
