"""Event-driven online simulator for the brick model (paper Section IV).

The simulator replays a :class:`BrickTrace` against:
  * the central last-empty-server-first dispatcher (a LIFO stack), and
  * a per-server ski-rental policy (A1/A2/A3/offline/...).

Because LIFO dispatch depends only on past arrivals/departures (Lemma 6), the
pop time of every pushed server equals its offline LIFO-matched arrival, which
the simulator precomputes; the *policy* never reads it except through the
permitted prediction window (the peek step).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .costs import CostModel
from .events import ARRIVAL, BrickTrace
from .ski_rental import OfflinePolicy, SkiRentalPolicy
from .stepfn import StepFn, from_breakpoints

_TRACE_EVENT = 0   # processed before timers at equal times (measure-zero ties)
_TIMER = 1


@dataclasses.dataclass
class SimResult:
    cost: float
    energy: float
    toggle_cost: float
    n_on: StepFn                        # x(t): number of running servers
    assignments: list[tuple[int, int]]  # (job index, server id) in dispatch order
    n_turn_on: int
    n_turn_off: int


def simulate(
    trace: BrickTrace,
    policy: SkiRentalPolicy,
    costs: CostModel,
    rng: np.random.Generator | None = None,
    predicted_pop: dict[int, float | None] | None = None,
) -> SimResult:
    """Run the LIFO dispatcher + per-server policy over the trace.

    ``predicted_pop``: optional map departure-event-index -> predicted pop
    time, used by the peek step instead of the true pop (prediction-error
    experiments).  Defaults to the exact LIFO matching (accurate prediction).
    """
    rng = rng or np.random.default_rng(0)
    delta = costs.delta
    alpha = float(getattr(policy, "alpha", 0.0))
    offline = isinstance(policy, OfflinePolicy)

    match = trace.lifo_matching()            # dep event idx -> true pop time
    if predicted_pop is None:
        predicted_pop = match

    T = trace.horizon
    n0 = trace.initial_count()

    busy_job_to_server: dict[int, int] = {}
    next_fresh = n0
    stack: list[dict] = []   # LIFO of idle/off server entries
    energy = 0.0
    toggles_on = 0
    toggles_off = 0

    init_jobs = [i for i, j in enumerate(trace.jobs) if j.arrival <= 0]
    for sid, ji in enumerate(init_jobs):
        busy_job_to_server[ji] = sid
    assignments: list[tuple[int, int]] = [(ji, busy_job_to_server[ji]) for ji in init_jobs]

    x_breaks: list[tuple[float, int]] = [(0.0, n0)]
    state = {"x": n0}

    def record_x(t: float, dx: int) -> None:
        state["x"] += dx
        x_breaks.append((t, state["x"]))

    def decide(entry: dict, t: float) -> None:
        """The peek-and-decide moment for an idle server (policy's W elapsed)."""
        nonlocal energy, toggles_off
        pop = predicted_pop.get(entry["dep_idx"])
        will_pop = pop is not None and t < pop <= t + alpha * delta
        if not will_pop:
            energy += costs.P * (t - entry["since"])  # idle energy until now
            entry["state"] = "off"
            entry["since"] = t
            toggles_off += 1
            record_x(t, -1)
        # else: stay idle; energy accounted when popped (or at horizon)

    heap: list[tuple[float, int, int, tuple]] = []
    seq = 0
    for i, e in enumerate(trace.events):
        heapq.heappush(heap, (e.time, _TRACE_EVENT, seq, ("trace", i)))
        seq += 1

    def schedule_timer(t: float, entry: dict) -> None:
        nonlocal seq
        if t <= T:
            heapq.heappush(heap, (t, _TIMER, seq, ("timer", entry)))
            seq += 1
        # a timer beyond the horizon never fires; finalization handles it

    while heap:
        t, _, _, payload = heapq.heappop(heap)
        if payload[0] == "trace":
            e = trace.events[payload[1]]
            if e.kind == ARRIVAL:
                if stack:
                    entry = stack.pop()
                    sid = entry["sid"]
                    entry["cancelled"] = True
                    if entry["state"] == "idle":
                        energy += costs.P * (t - entry["since"])
                    else:  # off -> turn on
                        toggles_on += 1
                        record_x(t, +1)
                else:
                    sid = next_fresh
                    next_fresh += 1
                    toggles_on += 1
                    record_x(t, +1)
                busy_job_to_server[e.job] = sid
                assignments.append((e.job, sid))
            else:  # departure
                sid = busy_job_to_server.pop(e.job)
                entry = {
                    "sid": sid,
                    "dep_idx": payload[1],
                    "since": t,
                    "state": "idle",
                    "cancelled": False,
                }
                stack.append(entry)
                if offline:
                    pop = match.get(payload[1])
                    if not (pop is not None and (pop - t) <= delta):
                        entry["state"] = "off"
                        toggles_off += 1
                        record_x(t, -1)
                else:
                    w = policy.wait_time(delta, rng)
                    if w <= 0.0:
                        decide(entry, t)
                    else:
                        schedule_timer(t + w, entry)
        else:  # timer
            entry = payload[1]
            if entry["cancelled"] or entry["state"] != "idle":
                continue
            decide(entry, t)

    # Finalize: idle servers at the horizon are forced off by x(T) = a(T).
    for entry in stack:
        if not entry["cancelled"] and entry["state"] == "idle":
            energy += costs.P * (T - entry["since"])
            toggles_off += 1
            record_x(T, -1)

    energy += costs.P * trace.busy_time()

    toggle_cost = costs.beta_on * toggles_on + costs.beta_off * toggles_off
    by_time: dict[float, int] = {}
    for tt, vv in x_breaks:
        by_time[tt] = vv
    ts = sorted(by_time)
    x = from_breakpoints(ts, [float(by_time[tt]) for tt in ts], T)
    return SimResult(
        cost=energy + toggle_cost,
        energy=energy,
        toggle_cost=toggle_cost,
        n_on=x,
        assignments=assignments,
        n_turn_on=toggles_on,
        n_turn_off=toggles_off,
    )
