"""Workload traces for the discrete-time fluid model (paper Section V).

The MSR Cambridge volume traces used by the paper are not redistributable /
available offline, so :func:`msr_like_trace` synthesizes a one-week trace at
10-minute granularity with diurnal + weekly structure calibrated to the
paper's peak-to-mean ratio (PMR = 4.63).  The PMR sweep transform
``a' = K * a^gamma`` (keeping the mean constant) is the one the paper uses in
Section V-D.
"""
from __future__ import annotations

import numpy as np

SLOTS_PER_DAY = 144          # 10-minute slots
WEEK_SLOTS = 7 * SLOTS_PER_DAY


def msr_like_trace(
    rng: np.random.Generator | None = None,
    n_slots: int = WEEK_SLOTS,
    mean_jobs: float = 40.0,
    target_pmr: float = 4.63,
    noise: float = 0.08,
    spike_prob: float = 0.004,
) -> np.ndarray:
    """Synthetic one-week fluid workload (jobs per slot, integer >= 0)."""
    rng = rng or np.random.default_rng(0)
    t = np.arange(n_slots)
    day_phase = 2 * np.pi * (t % SLOTS_PER_DAY) / SLOTS_PER_DAY
    # business-hours hump + secondary evening hump
    diurnal = (
        0.25
        + np.clip(np.sin(day_phase - np.pi / 2), 0, None) ** 1.5
        + 0.35 * np.clip(np.sin(2 * day_phase - np.pi / 3), 0, None) ** 2
    )
    dow = (t // SLOTS_PER_DAY) % 7
    weekly = np.where(dow < 5, 1.0, 0.45)     # weekends quieter
    base = diurnal * weekly
    base = base * (1.0 + noise * rng.standard_normal(n_slots))
    # occasional flash crowds ("Lady Gaga" events, footnote 2)
    spikes = (rng.uniform(size=n_slots) < spike_prob) * rng.uniform(2.0, 4.0, n_slots)
    base = np.clip(base + spikes, 0.02, None)
    a = scale_to_pmr(base, target_pmr)
    a = a / a.mean() * mean_jobs
    return np.maximum(np.rint(a).astype(np.int64), 0)


def scale_to_pmr(a: np.ndarray, target_pmr: float, tol: float = 1e-3) -> np.ndarray:
    """Rescale a' = K * a^gamma (mean preserved) to hit a target peak-to-mean
    ratio — the transform used by the paper's Section V-D sweep."""
    a = np.asarray(a, dtype=np.float64)
    a = np.clip(a, 1e-9, None)
    lo, hi = 0.05, 20.0
    for _ in range(200):
        gamma = 0.5 * (lo + hi)
        b = a ** gamma
        b = b / b.mean()
        pmr = b.max()
        if abs(pmr - target_pmr) < tol:
            break
        if pmr < target_pmr:
            lo = gamma
        else:
            hi = gamma
    b = a ** gamma
    return b / b.mean() * a.mean()


def pmr(a: np.ndarray) -> float:
    a = np.asarray(a, dtype=np.float64)
    return float(a.max() / a.mean())


def with_prediction_error(
    a: np.ndarray,
    rng: np.random.Generator,
    std_frac: float,
) -> np.ndarray:
    """Zero-mean Gaussian error, std = std_frac * actual workload (Sec. V-C)."""
    err = rng.standard_normal(a.shape) * std_frac * np.asarray(a, np.float64)
    return np.maximum(np.rint(a + err).astype(np.int64), 0)


def brick_trace_from_fluid(
    a: np.ndarray,
    rng: np.random.Generator | None = None,
    slot_len: float = 1.0,
):
    """Convert a fluid trace (jobs per slot) to a brick trace.

    Whenever a(t) increases by k, k jobs arrive; when it decreases, the most
    recent jobs depart (consistent with LIFO semantics).  Event epochs are
    spread inside the slot so that no two coincide.
    """
    from .events import Job

    rng = rng or np.random.default_rng(0)
    a = np.asarray(a, dtype=np.int64)
    horizon = float(len(a) * slot_len)
    open_jobs: list[float] = []   # arrival times of currently open jobs (stack)
    jobs: list[Job] = []
    prev = 0
    for s, cur in enumerate(a):
        t0 = s * slot_len
        diff = int(cur) - prev
        if diff > 0:
            offs = np.sort(rng.uniform(0.005, 0.49, diff)) * slot_len
            for o in offs:
                open_jobs.append(t0 + float(o))
        elif diff < 0:
            offs = np.sort(rng.uniform(0.51, 0.995, -diff)) * slot_len
            for o in offs:
                arr = open_jobs.pop()
                jobs.append(Job(arr, t0 + float(o)))
        prev = int(cur)
    for arr in open_jobs:
        jobs.append(Job(arr, horizon))
    # ensure distinct epochs
    from .events import _deduplicate

    return _deduplicate(jobs, horizon, rng)
