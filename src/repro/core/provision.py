"""One declarative provisioning API: ``provision(ProvisionSpec(...))``.

The spec is three pytree-registered frozen dataclasses plus options:

  * :class:`~repro.core.costs.CostModel` — ``P``/``beta_on``/``beta_off`` as
    scalars or ``(n_levels,)`` arrays (heterogeneous fleets); the critical
    interval Δ is always *derived* per level (paper eq. 12), never passed;
    typed fleets come from ``CostModel.from_groups(ServerGroup(...), ...)``
    — d server types in routing-priority order, with ``group_cost`` on the
    result breaking every schedule's spend down per type;
  * :class:`Workload` — demand ``(T,)`` or ``(B, T)``, an optional
    ``predicted`` trace, or an optional :class:`PredictionNoise` model that
    synthesizes one (paper Sec. V-C);
  * :class:`PolicySpec` — policy name, a single ``window`` or a ``windows``
    sweep axis (α = (w+1)/Δ), and the PRNG ``key`` for A2/A3.

:func:`provision` runs the whole (noise-stds × windows × traces × levels)
grid as one jitted device program and returns a :class:`ProvisionResult`
carrying the schedule, total/energy/toggle costs, and the per-level cost
breakdown.  Passing ``mesh=`` shards the level axis over the mesh through
the fused Pallas grid scan (:mod:`repro.kernels.provision_scan`) — the
same sweep axes, one kernel program per (noise-std, window, trace) cell,
bit-exact against the unsharded path.

Shape convention: the result keeps a leading windows axis iff the spec used
``windows=``, a batch axis iff demand was ``(B, T)``, and an outermost
noise axis iff ``PredictionNoise.std_frac`` was a ``(S,)`` sweep — mirroring
the inputs, so ``result.x`` is ``(T,)``, ``(B, T)``, ``(W, T)``,
``(W, B, T)`` … up to ``(S, W, B, T)``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..deferral import DeferralSpec
from ..obs import provenance as _prov
from ..obs.telemetry import get_telemetry
from . import jax_provision as _engine
from .costs import CostModel


@dataclasses.dataclass(frozen=True, eq=False)
class PredictionNoise:
    """Zero-mean Gaussian prediction error, std = ``std_frac`` × actual load.

    The JAX-native form of :func:`repro.core.traces.with_prediction_error`
    (paper Sec. V-C): the peek step reads ``max(round(a + ε), 0)`` with
    ``ε ~ N(0, (std_frac · a)²)`` drawn from ``key``.

    ``std_frac`` is a float, or a ``(S,)`` array to sweep error levels as a
    leading axis of the result (like ``PolicySpec.windows``): the normal
    draw is shared across the sweep (common random numbers), only its scale
    varies, so ratio curves over S are variance-reduced and the ``S=1``
    sweep reduces to the scalar row exactly.
    """

    std_frac: float | jax.Array
    key: jax.Array

    def apply(self, demand: jax.Array) -> jax.Array:
        """(T,) draws from ``key`` directly; (B, T) splits it per trace —
        the same convention as ``PolicySpec.key``, so batched noise studies
        reduce to their unbatched rows exactly.  A ``(S,)`` ``std_frac``
        prepends an S axis to the result."""
        a = jnp.asarray(demand, jnp.float32)

        if a.ndim == 2:
            z = jax.vmap(lambda k, ai: jax.random.normal(k, ai.shape))(
                jax.random.split(self.key, a.shape[0]), a
            )
        else:
            z = jax.random.normal(self.key, a.shape)
        std = jnp.asarray(self.std_frac, jnp.float32)
        if std.ndim == 1:
            std = std.reshape((std.shape[0],) + (1,) * a.ndim)
        elif std.ndim > 1:
            raise ValueError(
                f"std_frac must be a scalar or a (S,) sweep, got shape {std.shape}"
            )
        return jnp.maximum(jnp.rint(a + std * z * a), 0.0).astype(jnp.int32)


jax.tree_util.register_dataclass(
    PredictionNoise, data_fields=["std_frac", "key"], meta_fields=[]
)


@dataclasses.dataclass(frozen=True, eq=False)
class Workload:
    """Demand trace(s) plus what the peek step is allowed to see.

    ``demand``: (T,) or (B, T) integer concurrency per slot.  ``predicted``:
    optional trace(s) of the same shape the prediction window reads (the
    dispatcher always sees the true current slot).  ``noise``: optional
    :class:`PredictionNoise` that synthesizes ``predicted`` from ``demand``
    (its ``std_frac`` may be a ``(S,)`` sweep axis); mutually exclusive with
    an explicit ``predicted``.  ``deferral``: optional
    :class:`~repro.deferral.DeferralSpec` marking the demand as *arrivals
    with slack* rather than rigid load — :func:`provision` then water-fills
    the arrivals into the deferred service profile before the engine sees
    them (defer-then-provision) and reports queue metrics on the result.
    A zero-slack spec is bit-exact with no spec at all.
    """

    demand: jax.Array
    predicted: jax.Array | None = None
    noise: PredictionNoise | None = None
    deferral: DeferralSpec | None = None

    def resolve_predicted(self, demand_i32: jax.Array) -> jax.Array | None:
        if self.predicted is not None and self.noise is not None:
            raise ValueError("pass either predicted= or noise=, not both")
        if self.noise is not None:
            return self.noise.apply(demand_i32)
        if self.predicted is not None:
            return jnp.asarray(self.predicted, jnp.int32)
        return None


jax.tree_util.register_dataclass(
    Workload,
    data_fields=["demand", "predicted", "noise", "deferral"],
    meta_fields=[],
)


@dataclasses.dataclass(frozen=True, eq=False)
class PolicySpec:
    """Which algorithm runs, with how much future, under which key.

    ``name``: one of ``repro.core.jax_provision.POLICIES``.  ``window``: the
    number of future slots the peek sees (α = (window+1)/Δ per level).
    ``windows``: optional (W,) sweep axis — evaluates every window in one
    program and puts a leading W axis on the result; overrides ``window``.
    ``key``: explicit PRNG key, required for the randomized A2/A3 and the
    typed-fleet AQ-rand (split per trace for batched demand).  The
    Albers–Quedenfeld pair ``AQ-det``/``AQ-rand`` never peeks, so both
    ignore ``window``/``windows`` (the sweep axis broadcasts).
    """

    name: str = "A1"
    window: int = 0
    windows: jax.Array | None = None
    key: jax.Array | None = None

    def validate(self) -> "PolicySpec":
        """Raise ValueError for unknown policy names or a missing key on the
        randomized policies; returns self (chainable)."""
        _engine._check_policy(self.name)
        if self.name in _engine.KEYED:
            _engine._require_key(self.name, self.key)
        return self


jax.tree_util.register_dataclass(
    PolicySpec, data_fields=["windows", "key"], meta_fields=["name", "window"]
)


@dataclasses.dataclass(frozen=True, eq=False)
class ProvisionSpec:
    """The complete declarative input of one provisioning computation.

    ``n_levels``: fleet size; defaults to the cost model's per-level length,
    else ``max(demand) + 1`` (concrete demand only — under jit/vmap pass it
    explicitly).  ``mesh``/``mesh_axis``: shard the level axis over a mesh
    axis through the fused Pallas grid scan — the full (noise-std × window
    × trace) sweep runs as one program per grid cell and level block, with
    results bit-exact against the unsharded path (online policies only;
    ``offline`` has no slot scan).  ``use_pallas=False`` keeps the lax.scan
    body per cell.
    """

    costs: CostModel
    workload: Workload
    policy: PolicySpec
    n_levels: int | None = None
    mesh: Mesh | None = None
    mesh_axis: str = "data"
    use_pallas: bool = True


jax.tree_util.register_dataclass(
    ProvisionSpec,
    data_fields=["costs", "workload", "policy"],
    meta_fields=["n_levels", "mesh", "mesh_axis", "use_pallas"],
)


@dataclasses.dataclass(frozen=True, eq=False)
class ProvisionResult:
    """What one :func:`provision` call produced (all device arrays).

    ``x``: powered-on servers per slot, (..., T) int32.  ``cost`` =
    ``energy`` + ``toggle_cost`` (paper eq. 5, forced x(T)=a(T) boundary).
    ``level_cost``: (..., N) per-level totals — the heterogeneous-fleet
    breakdown (which server types the money went to).  ``group_cost``:
    (..., d) per-type totals for typed fleets (``CostModel.from_groups``,
    one column per server type in routing-priority order); None for
    ungrouped models.

    Deferral-enabled workloads (``Workload(deferral=...)``) additionally
    carry the queue's view of the schedule, all None otherwise:
    ``backlog`` (..., T) work still queued after each slot;
    ``max_delay`` / ``p99_delay`` (...) worst and 99th-percentile queueing
    delay in slots over served units; ``deadline_misses`` (...) units that
    expired while queued; ``unserved`` (...) units left at the horizon
    (0 whenever the schedule covers the deferred profile).

    ``provision(spec, record_decisions=True)`` fills the provenance pair
    (both None by default): ``decisions`` (..., T, N) uint8 per-slot reason
    bitmask (:mod:`repro.obs.provenance` — demand-rise / wait-expired /
    peek-fired / toggle-off), and ``decision_counts``, a dict of the four
    aggregate per-level counters (..., N) int32 keyed by
    ``repro.obs.provenance.COUNT_ORDER`` names.  The sharded (mesh) route
    records the aggregate counters only — ``decisions`` stays None there
    (see docs/observability.md).
    """

    x: jax.Array
    cost: jax.Array
    energy: jax.Array
    toggle_cost: jax.Array
    level_cost: jax.Array
    group_cost: jax.Array | None = None
    backlog: jax.Array | None = None
    max_delay: jax.Array | None = None
    p99_delay: jax.Array | None = None
    deadline_misses: jax.Array | None = None
    unserved: jax.Array | None = None
    decisions: jax.Array | None = None
    decision_counts: dict | None = None


jax.tree_util.register_dataclass(
    ProvisionResult,
    data_fields=["x", "cost", "energy", "toggle_cost", "level_cost",
                 "group_cost", "backlog", "max_delay", "p99_delay",
                 "deadline_misses", "unserved", "decisions",
                 "decision_counts"],
    meta_fields=[],
)


def _prepare(spec: ProvisionSpec, pol: PolicySpec) -> dict:
    """Normalize a validated spec into engine-shaped inputs (shared by
    :func:`provision` and :func:`provision_stream`).

    Applies deferral water-filling, resolves the predicted trace / noise
    sweep, infers ``n_levels``, broadcasts the cost fields per level and
    derives the squeeze conventions.  Returns a dict of everything the
    engine bodies consume plus the true ``arrivals`` (queue metrics are
    always measured on those, not on the deferred profile).
    """
    a = jnp.asarray(spec.workload.demand, jnp.int32)
    if a.ndim not in (1, 2):
        raise ValueError(f"demand must be (T,) or (B, T), got shape {a.shape}")
    defer = spec.workload.deferral
    arrivals = a
    if defer is not None:
        # defer-then-provision: the engine (predictions, noise, n_levels
        # inference, the offline baseline) runs on the water-filled service
        # profile; queue metrics are measured on the true arrivals
        a = defer.validate().apply(a)
    squeeze_b = a.ndim == 1
    ab = a[None] if squeeze_b else a
    noise = spec.workload.noise
    squeeze_s = noise is None or jnp.ndim(noise.std_frac) == 0
    pred = spec.workload.resolve_predicted(a)
    if pred is None:
        predb = ab
    else:
        want = (
            a.shape
            if squeeze_s
            else (jnp.shape(noise.std_frac)[0],) + a.shape
        )
        if pred.shape != want:
            raise ValueError(
                f"predicted shape {pred.shape} must match demand shape "
                f"{a.shape}"
                + ("" if squeeze_s else
                   f" with a leading noise-sweep axis (expected {want})")
            )
        predb = jnp.expand_dims(pred, -2) if squeeze_b else pred

    spec.costs.validate_groups()
    n_levels = spec.n_levels
    if n_levels is None:
        n_levels = spec.costs.n_levels
    if n_levels is None:
        if isinstance(jnp.asarray(ab), jax.core.Tracer):
            # int(ab.max()) below would die with an opaque
            # ConcretizationTypeError when the caller traces provision()
            # under jit/vmap — name the actual fix instead
            raise ValueError(
                "n_levels cannot be derived from demand inside jit/vmap "
                "(the demand is a tracer, so max(demand) is not concrete): "
                "pass ProvisionSpec(n_levels=...) explicitly or use a "
                "CostModel with (n_levels,) per-level fields"
            )
        n_levels = int(ab.max()) + 1        # needs concrete demand
    P_lv, bon_lv, boff_lv = spec.costs.per_level(n_levels)
    delta_lv = jnp.broadcast_to(
        jnp.asarray(spec.costs.delta, jnp.float32), (n_levels,)
    )

    squeeze_w = pol.windows is None
    windows = (
        jnp.asarray([pol.window], jnp.int32)
        if squeeze_w
        else jnp.asarray(pol.windows, jnp.int32)
    )

    keys = None
    if pol.name in _engine.KEYED:
        keys = (
            pol.key[None] if squeeze_b else jax.random.split(pol.key, ab.shape[0])
        )
    return dict(
        arrivals=arrivals, defer=defer, ab=ab, predb=predb,
        squeeze_b=squeeze_b, squeeze_w=squeeze_w, squeeze_s=squeeze_s,
        windows=windows, keys=keys, n_levels=n_levels,
        P_lv=P_lv, bon_lv=bon_lv, boff_lv=boff_lv, delta_lv=delta_lv,
        max_h=spec.costs.delta_slots(),
    )


def provision(spec: ProvisionSpec, *, record_decisions: bool = False) -> ProvisionResult:
    """Run a :class:`ProvisionSpec` end-to-end as one jitted device program.

    Subsumes the deprecated ``provision_schedule`` / ``provision_sweep`` /
    ``provision_sweep_costs`` / ``provision_cost`` /
    ``provision_schedule_sharded`` surface: batching is the demand's leading
    axis, the α-sweep is ``PolicySpec.windows``, sharding is ``mesh=``.  The
    cost model's fields flow through jit as data, so re-pricing the fleet
    does not recompile; only (policy, shapes, Δ's static scan bound) do.

    ``record_decisions=True`` fills ``ProvisionResult.decisions`` /
    ``decision_counts`` with per-slot reason codes out of the slot scan
    (:mod:`repro.obs.provenance`); it is a *static* switch — the default-off
    path traces exactly today's program, bit-for-bit and compile-for-compile
    (gated in ``provision_bench.py --smoke``).  Rejected for ``offline``,
    which is a closed form with no slot scan to record.
    """
    pol = spec.policy.validate()
    if record_decisions and pol.name == "offline":
        raise ValueError(
            "record_decisions=True: 'offline' is the closed-form hindsight "
            "optimum — it has no slot scan, so there are no per-slot "
            "decisions to record"
        )
    pr = _prepare(spec, pol)
    arrivals, defer = pr["arrivals"], pr["defer"]
    ab, predb = pr["ab"], pr["predb"]
    squeeze_b, squeeze_w, squeeze_s = (
        pr["squeeze_b"], pr["squeeze_w"], pr["squeeze_s"]
    )
    windows, keys, n_levels, max_h = (
        pr["windows"], pr["keys"], pr["n_levels"], pr["max_h"]
    )
    P_lv, bon_lv, boff_lv, delta_lv = (
        pr["P_lv"], pr["bon_lv"], pr["boff_lv"], pr["delta_lv"]
    )

    tel = get_telemetry()
    route = "mesh" if spec.mesh is not None else "scan"
    with tel.span("provision", policy=pol.name, route=route,
                  n_levels=n_levels, record=record_decisions):
        if spec.mesh is not None:
            # the fleet path takes the same (S, W, B) grid as the lax.scan
            # programs: normalize predb to (S, B, T) and squeeze the result
            # back to the spec's axis convention below
            predb3 = predb[None] if predb.ndim == 2 else predb
            out = _engine._sharded_run(
                spec.mesh, spec.mesh_axis, ab, predb3, windows, delta_lv, P_lv,
                bon_lv, boff_lv, n_levels=n_levels, max_h=max_h,
                policy=pol.name, keys=keys, use_pallas=spec.use_pallas,
                group_sizes=spec.costs.group_sizes, record=record_decisions,
            )

            def _squeeze(o):
                if squeeze_b:
                    o = jnp.squeeze(o, axis=2)
                if squeeze_w:
                    o = jnp.squeeze(o, axis=1)
                if squeeze_s:
                    o = jnp.squeeze(o, axis=0)
                return o

            out = jax.tree.map(_squeeze, out)
        else:
            # noise sweep: the engine vmapped over the (S,) predicted axis
            # with the demand, windows and keys held fixed — common random
            # numbers across error levels, one compiled program for the
            # whole (S, W, B) grid
            body = _engine._run if squeeze_s else _engine._run_noise_sweep
            out = body(
                ab, predb, windows, delta_lv, P_lv, bon_lv, boff_lv, keys,
                n_levels=n_levels, max_h=max_h, policy=pol.name,
                record=record_decisions,
            )
            lead = 0 if squeeze_s else 1
            if squeeze_b:
                out = jax.tree.map(lambda o: jnp.squeeze(o, axis=lead + 1), out)
            if squeeze_w:
                out = jax.tree.map(lambda o: jnp.squeeze(o, axis=lead), out)

    decisions = out.pop("decisions", None)
    counts = None
    if record_decisions:
        if decisions is not None:
            # lax.scan route: full per-slot codes; the aggregate counters
            # are one reduction away (same rows the mesh route records)
            counts = {
                name: ((decisions & bit) != 0).sum(axis=-2).astype(jnp.int32)
                for name, bit in zip(_prov.COUNT_ORDER, _prov.COUNT_BITS)
            }
        else:
            rows = out.pop("decision_counts")       # (..., 4, N) int32
            counts = {
                name: rows[..., i, :]
                for i, name in enumerate(_prov.COUNT_ORDER)
            }
        offs = counts["toggle_off"]
        if tel.enabled and not isinstance(offs, jax.core.Tracer):
            tel.count("provision/decision_toggle_offs", float(offs.sum()))

    level_cost = out["energy"] + out["on_cost"] + out["off_cost"]
    queue = (
        {} if defer is None else defer.metrics(arrivals, out["x"])
    )
    return ProvisionResult(
        x=out["x"],
        cost=level_cost.sum(axis=-1),
        energy=out["energy"].sum(axis=-1),
        toggle_cost=(out["on_cost"] + out["off_cost"]).sum(axis=-1),
        level_cost=level_cost,
        group_cost=(
            None if spec.costs.group_sizes is None
            else spec.costs.group_reduce(level_cost)
        ),
        backlog=queue.get("backlog"),
        max_delay=queue.get("max_delay"),
        p99_delay=queue.get("p99_delay"),
        deadline_misses=queue.get("deadline_misses"),
        unserved=queue.get("unserved"),
        decisions=decisions,
        decision_counts=counts,
    )


def provision_stream(
    spec: ProvisionSpec,
    *,
    t_chunk: int | None = None,
    record_decisions: bool = False,
) -> ProvisionResult:
    """:func:`provision` for production-length traces: same spec, same
    result, O(t_chunk · levels) working set per cell instead of the
    monolithic scan's O(T · levels) on-matrix.

    Both engine routes stream the trace in ``t_chunk``-slot tiles with an
    explicit carry — the lax.scan route through the chunked
    ``_run_stream`` bodies, the ``mesh=`` route through the HBM-resident
    double-buffered Pallas kernel
    (:func:`repro.kernels.provision_scan.provision_scan_stream`).  Results
    are **bit-exact** against :func:`provision` on every field for every
    online policy: the carry preserves the engine state across tiles, the
    peek reads into the next tile so chunking never truncates the window,
    and the randomized policies consume the same absolute-slot wait draws
    (CRN parity; their (T, N) uniform tables are the one O(T) allocation
    the streaming path keeps — docs/provisioning_engine.md "Streaming &
    long traces").

    Two deliberate differences: ``offline`` is rejected (the hindsight
    optimum is a closed form over the whole trace — there is nothing to
    stream), and ``record_decisions=True`` fills ``decision_counts`` only
    (aggregate per-level counters, the fleet-path convention) — per-slot
    ``decisions`` codes are exactly the O(T · N) buffer streaming exists to
    avoid.  ``t_chunk`` defaults to
    :data:`repro.kernels.provision_scan.DEFAULT_T_CHUNK` and is clamped to
    the trace length; it is a compile key but never changes results.
    """
    from repro.kernels.provision_scan import DEFAULT_T_CHUNK

    pol = spec.policy.validate()
    if pol.name == "offline":
        raise ValueError(
            "provision_stream is online-only: 'offline' is the closed-form "
            "hindsight optimum over the whole trace — use provision()"
        )
    pr = _prepare(spec, pol)
    arrivals, defer = pr["arrivals"], pr["defer"]
    ab, predb = pr["ab"], pr["predb"]
    squeeze_b, squeeze_w, squeeze_s = (
        pr["squeeze_b"], pr["squeeze_w"], pr["squeeze_s"]
    )
    windows, keys, n_levels, max_h = (
        pr["windows"], pr["keys"], pr["n_levels"], pr["max_h"]
    )
    P_lv, bon_lv, boff_lv, delta_lv = (
        pr["P_lv"], pr["bon_lv"], pr["boff_lv"], pr["delta_lv"]
    )
    T = int(ab.shape[-1])
    if t_chunk is None:
        t_chunk = DEFAULT_T_CHUNK
    t_chunk = int(min(max(int(t_chunk), 1), max(T, 1)))

    tel = get_telemetry()
    route = "mesh" if spec.mesh is not None else "scan"
    with tel.span("provision_stream", policy=pol.name, route=route,
                  n_levels=n_levels, t_chunk=t_chunk,
                  record=record_decisions):
        if spec.mesh is not None:
            predb3 = predb[None] if predb.ndim == 2 else predb
            out = _engine._sharded_stream(
                spec.mesh, spec.mesh_axis, ab, predb3, windows, delta_lv, P_lv,
                bon_lv, boff_lv, n_levels=n_levels, max_h=max_h,
                policy=pol.name, keys=keys, use_pallas=spec.use_pallas,
                group_sizes=spec.costs.group_sizes, t_chunk=t_chunk,
                record=record_decisions,
            )

            def _squeeze(o):
                if squeeze_b:
                    o = jnp.squeeze(o, axis=2)
                if squeeze_w:
                    o = jnp.squeeze(o, axis=1)
                if squeeze_s:
                    o = jnp.squeeze(o, axis=0)
                return o

            out = jax.tree.map(_squeeze, out)
        else:
            body = (
                _engine._run_stream if squeeze_s else _engine._run_stream_noise
            )
            out = body(
                ab, predb, windows, delta_lv, P_lv, bon_lv, boff_lv, keys,
                n_levels=n_levels, max_h=max_h, policy=pol.name,
                t_chunk=t_chunk, record=record_decisions,
            )
            lead = 0 if squeeze_s else 1
            if squeeze_b:
                out = jax.tree.map(lambda o: jnp.squeeze(o, axis=lead + 1), out)
            if squeeze_w:
                out = jax.tree.map(lambda o: jnp.squeeze(o, axis=lead), out)

    counts = None
    if record_decisions:
        rows = out.pop("decision_counts")           # (..., 4, N) int32
        counts = {
            name: rows[..., i, :]
            for i, name in enumerate(_prov.COUNT_ORDER)
        }
        offs = counts["toggle_off"]
        if tel.enabled and not isinstance(offs, jax.core.Tracer):
            tel.count("provision/decision_toggle_offs", float(offs.sum()))

    level_cost = out["energy"] + out["on_cost"] + out["off_cost"]
    queue = (
        {} if defer is None else defer.metrics(arrivals, out["x"])
    )
    return ProvisionResult(
        x=out["x"],
        cost=level_cost.sum(axis=-1),
        energy=out["energy"].sum(axis=-1),
        toggle_cost=(out["on_cost"] + out["off_cost"]).sum(axis=-1),
        level_cost=level_cost,
        group_cost=(
            None if spec.costs.group_sizes is None
            else spec.costs.group_reduce(level_cost)
        ),
        backlog=queue.get("backlog"),
        max_delay=queue.get("max_delay"),
        p99_delay=queue.get("p99_delay"),
        deadline_misses=queue.get("deadline_misses"),
        unserved=queue.get("unserved"),
        decisions=None,
        decision_counts=counts,
    )
