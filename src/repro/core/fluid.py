"""Discrete-time fluid workload engine (paper Sections IV-C, V).

Level decomposition (see DESIGN.md §2): with the paper's slot-wise LIFO rule
and a fixed push order, server ``l`` (0-indexed) is busy in slot ``t`` iff
``a[t] > l``.  Provisioning therefore decomposes into independent per-level
ski-rental instances on the indicator traces, and every algorithm below is a
per-level gap computation.  Tests verify the decomposition against a
brute-force DP oracle and the critical-segment construction.

Two engines:
  * closed-form per-gap costs (exact predictions) — fast path;
  * slot-scan engine supporting erroneous predicted traces (Section V-C).

All times are in slot units; ``CostModel.P`` is energy per slot per server.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from .costs import CostModel

E = math.e


# host-side reference-model result, never crosses into jit
@dataclasses.dataclass
class FluidResult:  # repro-lint: disable=RPL005
    cost: float
    energy: float
    toggle_cost: float
    x: np.ndarray | None = None   # per-slot number of running servers


# ---------------------------------------------------------------------------
# Gap extraction
# ---------------------------------------------------------------------------

def level_gaps(a: np.ndarray, level: int) -> tuple[int, list[tuple[int, int]], int, int, int]:
    """Busy/gap structure of one level.

    Returns (busy_slots, interior_gaps[(start, length)], lead_len, trail_len,
    first_busy) where interior gaps lie strictly between busy runs.
    """
    busy = np.asarray(a) > level
    idx = np.flatnonzero(busy)
    if idx.size == 0:
        return 0, [], len(a), 0, -1
    gaps = []
    d = np.diff(idx)
    for k in np.flatnonzero(d > 1):
        gaps.append((int(idx[k]) + 1, int(d[k]) - 1))
    lead = int(idx[0])
    trail = int(len(a) - 1 - idx[-1])
    return int(idx.size), gaps, lead, trail, int(idx[0])


# ---------------------------------------------------------------------------
# Closed-form per-gap policy costs (exact predictions)
# ---------------------------------------------------------------------------

def _gap_cost_offline(g: float, b: float, P: float, beta: float) -> float:
    return min(g * P, beta)


def _make_gap_cost_a1(w: int, b: int) -> Callable[[float], tuple[float, float]]:
    """Returns fn(g) -> (interior cost, trailing idle slots before forced off).

    A1 waits m = max(0, b - w - 1) slots, then peeks the visible window
    (slots t+1 .. t+w, i.e. pops up to real time t + w + 1)."""

    def fn(g):
        m = max(0, b - w - 1)
        if g <= m + w + 1:   # pop happens during wait or is visible in window
            return g, None   # idle throughout (cost g*P), no toggle
        return m, "off"

    return fn


def sample_wait_a2(alpha: float, b: float, rng: np.random.Generator) -> float:
    span = (1.0 - alpha) * b
    if span <= 0:
        return 0.0
    return span * math.log1p(rng.uniform() * (E - 1.0))


def sample_wait_a3(alpha: float, b: float, rng: np.random.Generator) -> float:
    if rng.uniform() < alpha / (E - 1.0 + alpha):
        return 0.0
    return sample_wait_a2(alpha, b, rng)


def fluid_cost(
    a: np.ndarray,
    policy: str,
    costs: CostModel,
    window: int = 0,
    rng: np.random.Generator | None = None,
    t_wait_factor: float = 1.0,
) -> FluidResult:
    """Closed-form fluid cost for policy in
    {offline, A1, A2, A3, delayedoff, lcp, static}.

    ``window`` = number of *future* slots known (the current slot is always
    known — it drives the dispatcher).  Effective alpha = min(1, (window+1)/b)
    as derived in the paper's Section V-B discussion (window = Delta - 1
    already achieves the optimum).
    """
    rng = rng or np.random.default_rng(0)
    a = np.asarray(a, dtype=np.int64)
    P, beta = costs.P, costs.beta
    b = costs.delta  # in slots
    bi = int(round(b))
    w = int(window)
    alpha = min(1.0, (w + 1) / b)

    if policy == "static":
        peak = int(a.max())
        energy = P * peak * len(a)
        return FluidResult(cost=energy, energy=energy, toggle_cost=0.0)

    if policy == "lcp" and w < 1:
        raise ValueError("LCP(w) needs at least one future slot (paper Sec. V-B)")

    n_levels = int(a.max())
    energy = 0.0
    toggle = 0.0
    for level in range(n_levels):
        busy, gaps, lead, trail, first = level_gaps(a, level)
        if busy == 0:
            continue
        energy += P * busy
        # beta_on at first use if the level starts off (x(0) = a(0)).
        if level >= a[0]:
            toggle += costs.beta_on
        for _, g in gaps:
            e_idle, t_tog = _interior_gap(policy, g, b, bi, w, alpha, P, beta, rng,
                                          t_wait_factor)
            energy += e_idle
            toggle += t_tog
        # trailing gap: forced off by x(T) = a(T); offline turns off instantly.
        if trail > 0:
            e_idle, _ = _trailing_gap(policy, trail, b, bi, w, alpha, P, rng,
                                      t_wait_factor)
            energy += e_idle
            toggle += costs.beta_off
    return FluidResult(cost=energy + toggle, energy=energy, toggle_cost=toggle)


def _interior_gap(policy, g, b, bi, w, alpha, P, beta, rng, t_wait_factor):
    """(idle energy, toggle cost) for one interior gap of length g slots."""
    if policy == "offline":
        return (g * P, 0.0) if g * P <= beta else (0.0, beta)
    if policy == "A1":
        m = max(0.0, b - w - 1)
        # peek covers (m, m + alpha*b]; info beyond the critical window is
        # useless and A1 does not use it (paper Theorem 7 remark (i)).
        if g <= m + min(w + 1, b):
            return g * P, 0.0
        return m * P, beta
    if policy in ("A2", "A3"):
        z = sample_wait_a2(alpha, b, rng) if policy == "A2" else sample_wait_a3(alpha, b, rng)
        if g <= z:
            return g * P, 0.0
        # peek at decision time z with visibility through z + alpha*b
        if g <= z + alpha * b:
            return g * P, 0.0
        return z * P, beta
    if policy == "delayedoff":
        tw = t_wait_factor * b
        if g <= tw:
            return g * P, 0.0
        return tw * P, beta
    if policy == "lcp":
        # LCP's window must cover the *current* slot (x_t is set before slot t
        # is observed, Lin et al.), and its lazy upper envelope keeps a server
        # on through ties, so it turns off one slot later than the hindsight
        # threshold: m = b - w + 1.  Net effect: LCP(w) ~ A1 with
        # alpha = (w-1)/b, matching the paper's Fig. 4b placement.
        m = max(0.0, b - w + 1)
        if g <= b:
            return g * P, 0.0
        return m * P, beta
    raise KeyError(policy)


def _trailing_gap(policy, trail, b, bi, w, alpha, P, rng, t_wait_factor):
    """Idle energy before the forced turn-off at the horizon."""
    if trail <= 0 or policy == "offline":
        return 0.0, None
    if policy == "A1":
        m = max(0.0, b - w - 1)
        return min(trail, m) * P, None
    if policy in ("A2", "A3"):
        z = sample_wait_a2(alpha, b, rng) if policy == "A2" else sample_wait_a3(alpha, b, rng)
        return min(trail, z) * P, None
    if policy == "delayedoff":
        return min(trail, t_wait_factor * b) * P, None
    if policy == "lcp":
        return min(trail, max(0.0, b - w + 1)) * P, None
    raise KeyError(policy)


# ---------------------------------------------------------------------------
# Slot-scan engine (supports erroneous predictions; returns x per slot)
# ---------------------------------------------------------------------------

def fluid_scan(
    a: np.ndarray,
    policy: str,
    costs: CostModel,
    window: int = 0,
    predicted: np.ndarray | None = None,
    rng: np.random.Generator | None = None,
) -> FluidResult:
    """Slot-by-slot simulation.  ``predicted`` is the trace the peek step
    reads (defaults to ``a``); the dispatcher always sees the true current
    load.  Decisions happen at slot granularity.
    """
    rng = rng or np.random.default_rng(0)
    a = np.asarray(a, dtype=np.int64)
    pred = a if predicted is None else np.asarray(predicted, dtype=np.int64)
    P, beta = costs.P, costs.beta
    b = costs.delta
    w = int(window)
    alpha = min(1.0, (w + 1) / b)
    T = len(a)
    n_levels = int(max(a.max(), 1))

    # per-level state
    on = a[0] > np.arange(n_levels)          # x(0) = a(0)
    idle_run = np.zeros(n_levels)            # consecutive idle slots while on
    wait_target = np.full(n_levels, np.inf)  # sampled wait for randomized pols

    energy = 0.0
    toggle = 0.0
    x_hist = np.zeros(T, dtype=np.int64)

    for t in range(T):
        busy = a[t] > np.arange(n_levels)
        # dispatcher: busy levels must be on (turn on if off)
        turn_on = busy & ~on
        toggle += costs.beta_on * int(turn_on.sum())
        on = on | busy
        idle_run = np.where(busy, 0.0, idle_run)
        # idle levels that are on: advance idle time, decide
        idle = on & ~busy
        new_idle = idle & (idle_run == 0.0)
        if policy in ("A2", "A3"):
            for lv in np.flatnonzero(new_idle):
                wait_target[lv] = (
                    sample_wait_a2(alpha, b, rng)
                    if policy == "A2"
                    else sample_wait_a3(alpha, b, rng)
                )
        idle_run = np.where(idle, idle_run + 1.0, idle_run)

        # decision: turn off this slot? (before paying the slot's idle energy)
        off_now = np.zeros(n_levels, dtype=bool)
        for lv in np.flatnonzero(idle):
            r = idle_run[lv] - 1.0   # idle slots fully elapsed before slot t
            if policy == "offline":
                # hindsight: look at the true future
                fut = np.flatnonzero(a[t:] > lv)
                gap_total = r + (fut[0] if fut.size else np.inf)
                off_now[lv] = gap_total * P > beta or not fut.size
            elif policy in ("A1", "A2", "A3"):
                m = max(0.0, b - w - 1) if policy == "A1" else wait_target[lv]
                if r >= m:
                    # Window covers pops through real time t + min(w+1, b):
                    # the current slot is observed and the right edge of the
                    # continuous window [tau, tau + alpha*Delta] includes an
                    # arrival at the boundary instant; capped at alpha*Delta.
                    horizon_slots = int(min(w + 1, math.ceil(b)))
                    seen_future = pred[t + 1 : t + horizon_slots + 1] > lv
                    off_now[lv] = not seen_future.any()
            elif policy == "delayedoff":
                off_now[lv] = r >= b
            elif policy == "lcp":
                # knowledge = slots t .. t+w-1 (window includes current slot)
                seen_future = pred[t + 1 : t + w] > lv
                if seen_future.any():
                    nxt = t + 1 + int(np.flatnonzero(seen_future)[0])
                    gap_if_wait = r + (nxt - t)
                    off_now[lv] = gap_if_wait * P > beta
                else:
                    off_now[lv] = r >= max(0.0, b - w + 1)
            else:
                raise KeyError(policy)
        toggle += costs.beta_off * int(off_now.sum())
        on = on & ~off_now
        idle_run = np.where(off_now, 0.0, idle_run)
        energy += P * int(on.sum())
        x_hist[t] = int(on.sum())

    # horizon: force x(T) = a(T): all still-idle levels off
    still_idle = on & ~(a[-1] > np.arange(n_levels))
    toggle += costs.beta_off * int(still_idle.sum())
    return FluidResult(cost=energy + toggle, energy=energy, toggle_cost=toggle, x=x_hist)


def cost_reduction_vs_static(cost: float, a: np.ndarray, costs: CostModel) -> float:
    static = fluid_cost(a, "static", costs).cost
    return 1.0 - cost / static
