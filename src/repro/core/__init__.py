"""The paper's contribution: power-proportional dynamic provisioning.

Public API:
  * Declarative provisioning: ``provision(ProvisionSpec(...))`` with
    ``CostModel`` (scalar or per-level), ``Workload``, ``PolicySpec``,
    ``PredictionNoise`` — returns a ``ProvisionResult``.
  * Brick (continuous-time) model: ``BrickTrace``, ``simulate`` (online),
    ``a0_schedule``/``a0_cost``/``optimal_schedule_constructed`` (offline),
    ``critical_segments``.
  * Fluid (discrete-time) model: ``fluid_cost``, ``fluid_scan``.
  * Policies: ``A1Deterministic``, ``A2Randomized``, ``A3Randomized``.
  * Validation: ``dp_optimal_cost``.

The loose-kwargs ``provision_schedule``/``provision_sweep[_costs]``/
``provision_cost``/``provision_schedule_sharded`` functions are deprecated
wrappers around ``provision``.
"""
from ..deferral import DeferralSpec
from .costs import PAPER_COSTS, CostModel, ServerGroup, schedule_cost
from .dp_oracle import dp_optimal_cost
from .events import BrickTrace, Job, generate_brick_trace, trace_from_intervals
from .fluid import FluidResult, fluid_cost, fluid_scan
from .jax_provision import (
    POLICIES,
    RANDOMIZED as RANDOMIZED_POLICIES,
    on_matrix_cost,
    provision_cost,
    provision_schedule,
    provision_schedule_sharded,
    provision_sweep,
    provision_sweep_costs,
)
from .provision import (
    PolicySpec,
    PredictionNoise,
    ProvisionResult,
    ProvisionSpec,
    Workload,
    provision,
    provision_stream,
)
from .offline import a0_cost, a0_schedule, optimal_cost, optimal_schedule_constructed
from .online import SimResult, simulate
from .segments import CriticalSegment, SegmentType, critical_segments, critical_times
from .ski_rental import (
    A1Deterministic,
    A2Randomized,
    A3Randomized,
    BreakEven,
    DelayedOffPolicy,
    OfflinePolicy,
    theoretical_ratio,
)
from .traces import (
    brick_trace_from_fluid,
    msr_like_trace,
    pmr,
    scale_to_pmr,
    with_prediction_error,
)

__all__ = [
    "PAPER_COSTS",
    "CostModel",
    "DeferralSpec",
    "ServerGroup",
    "schedule_cost",
    "dp_optimal_cost",
    "BrickTrace",
    "Job",
    "generate_brick_trace",
    "trace_from_intervals",
    "FluidResult",
    "fluid_cost",
    "fluid_scan",
    "POLICIES",
    "RANDOMIZED_POLICIES",
    "PolicySpec",
    "PredictionNoise",
    "ProvisionResult",
    "ProvisionSpec",
    "Workload",
    "provision",
    "provision_stream",
    "on_matrix_cost",
    "provision_cost",
    "provision_schedule",
    "provision_schedule_sharded",
    "provision_sweep",
    "provision_sweep_costs",
    "a0_cost",
    "a0_schedule",
    "optimal_cost",
    "optimal_schedule_constructed",
    "SimResult",
    "simulate",
    "CriticalSegment",
    "SegmentType",
    "critical_segments",
    "critical_times",
    "A1Deterministic",
    "A2Randomized",
    "A3Randomized",
    "BreakEven",
    "DelayedOffPolicy",
    "OfflinePolicy",
    "theoretical_ratio",
    "brick_trace_from_fluid",
    "msr_like_trace",
    "pmr",
    "scale_to_pmr",
    "with_prediction_error",
]
