"""Server-operation cost model (paper Section II-B, problem SCP)."""
from __future__ import annotations

import dataclasses

from .stepfn import StepFn


@dataclasses.dataclass(frozen=True)
class CostModel:
    """P: energy per unit time per running server; beta_on/off: toggle costs."""

    P: float = 1.0
    beta_on: float = 3.0
    beta_off: float = 3.0

    @property
    def beta(self) -> float:
        return self.beta_on + self.beta_off

    @property
    def delta(self) -> float:
        """Critical interval Delta = (beta_on + beta_off) / P  (paper eq. 12)."""
        return self.beta / self.P


#: The paper's experimental setting: P = 1, beta_on + beta_off = 6 => Delta = 6.
PAPER_COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


def schedule_cost(x: StepFn, costs: CostModel, *, final_level: float | None = None) -> float:
    """Total cost of a schedule x(t): P * integral(x) + toggle costs.

    ``final_level``: if given, enforce the boundary x(T) = a(T) by charging the
    final forced turn-off/on at T (paper eq. 5).
    """
    energy = costs.P * x.integral()
    up, down = x.switching()
    cost = energy + costs.beta_on * up + costs.beta_off * down
    if final_level is not None:
        last = x.values[-1]
        if last > final_level:
            cost += costs.beta_off * (last - final_level)
        elif last < final_level:
            cost += costs.beta_on * (final_level - last)
    return cost
