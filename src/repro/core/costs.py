"""Server-operation cost model (paper Section II-B, problem SCP).

``CostModel`` is JAX-native: ``P``/``beta_on``/``beta_off`` accept python
scalars **or** ``(n_levels,)`` arrays, so one model describes either the
paper's homogeneous fleet or a heterogeneous one (per-level server types,
Albers & Quedenfeld, PAPERS.md).  The critical interval ``delta`` is always
*derived* — Δ = (β_on + β_off) / P per level (paper eq. 12) — never passed
separately.  The class is a registered pytree so specs built from it flow
through ``jax.jit``/``vmap`` as data, not as static compile keys.

Typed fleets (Albers & Quedenfeld, arXiv 2107.14672) are first-class:
:meth:`CostModel.from_groups` builds a model from :class:`ServerGroup`
declarations — one group per server *type*, each with its own power draw,
toggle costs and level count.  Groups are concatenated in routing-priority
order (ascending ``P`` by default, so the cheapest-to-run type takes base
load), which makes the greedy demand split implicit in the level stack:
level ``j`` of the flat model is busy iff demand exceeds ``j``, exactly the
homogeneous dispatcher compare.  The grouping itself (``group_sizes``,
``group_names``) rides along as *static* pytree metadata, so a typed model
hashes into jit compile keys while the cost values stay traced data.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

from .stepfn import StepFn

ArrayLike = "float | np.ndarray | jax.Array"


@dataclasses.dataclass(frozen=True)
class ServerGroup:
    """One server *type*: ``n_servers`` identical machines with shared costs.

    The building block of a typed fleet (Albers & Quedenfeld's *d* server
    types): ``P`` is the per-slot energy of one running server of this type,
    ``beta_on``/``beta_off`` its toggle costs, so the type's critical
    interval is Δ = (β_on + β_off) / P (paper eq. 12, per type).
    """

    name: str
    n_servers: int
    P: float = 1.0
    beta_on: float = 3.0
    beta_off: float = 3.0

    @property
    def delta(self) -> float:
        return (self.beta_on + self.beta_off) / self.P

    def validate(self) -> "ServerGroup":
        if self.n_servers < 1:
            raise ValueError(f"group {self.name!r}: n_servers must be >= 1")
        if self.P <= 0 or self.beta_on < 0 or self.beta_off < 0:
            raise ValueError(f"group {self.name!r}: need P > 0 and beta >= 0")
        return self


@dataclasses.dataclass(frozen=True, eq=False)
class CostModel:
    """P: energy per unit time per running server; beta_on/off: toggle costs.

    Each field is a scalar (homogeneous fleet) or an ``(n_levels,)`` array
    (per-level server types); scalars broadcast against array fields.

    ``group_sizes``/``group_names``: optional static metadata marking the
    level stack as a *typed* fleet of ``d = len(group_sizes)`` server types
    — levels ``[offset_g, offset_g + group_sizes[g])`` all belong to type
    ``g``.  Build typed models with :meth:`from_groups`; the metadata drives
    per-type cost aggregation (:meth:`group_reduce`) and the group-aligned
    kernel block packing in the sharded engine.
    """

    P: "ArrayLike" = 1.0
    beta_on: "ArrayLike" = 3.0
    beta_off: "ArrayLike" = 3.0
    group_sizes: tuple[int, ...] | None = None
    group_names: tuple[str, ...] | None = None

    @classmethod
    def from_groups(cls, *groups: ServerGroup, order: str | None = "energy") -> "CostModel":
        """Typed fleet from :class:`ServerGroup` declarations.

        ``order="energy"`` (default) sorts groups by ascending ``P`` (stable)
        so the cheapest-to-run type takes base load — the routing-priority
        convention that makes the greedy demand split implicit in the level
        stack.  ``order=None`` keeps the declared order (the caller asserts
        its own routing priority).
        """
        if not groups:
            raise ValueError("from_groups needs at least one ServerGroup")
        for g in groups:
            g.validate()
        names = [g.name for g in groups]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate group names: {names}")
        if order == "energy":
            groups = tuple(sorted(groups, key=lambda g: g.P))
        elif order is not None:
            raise ValueError(f"order must be 'energy' or None, got {order!r}")
        return cls(
            P=np.concatenate([np.full(g.n_servers, g.P, np.float32) for g in groups]),
            beta_on=np.concatenate(
                [np.full(g.n_servers, g.beta_on, np.float32) for g in groups]
            ),
            beta_off=np.concatenate(
                [np.full(g.n_servers, g.beta_off, np.float32) for g in groups]
            ),
            group_sizes=tuple(int(g.n_servers) for g in groups),
            group_names=tuple(g.name for g in groups),
        )

    @property
    def beta(self):
        return self.beta_on + self.beta_off

    @property
    def delta(self):
        """Critical interval Delta = (beta_on + beta_off) / P  (paper eq. 12).

        Scalar for homogeneous models, ``(n_levels,)`` for heterogeneous.
        """
        return self.beta / self.P

    @property
    def is_heterogeneous(self) -> bool:
        return any(np.ndim(f) > 0 for f in (self.P, self.beta_on, self.beta_off))

    @property
    def n_levels(self) -> int | None:
        """Fleet size the model pins down, or None for scalar models."""
        sizes = {np.shape(f)[0] for f in (self.P, self.beta_on, self.beta_off)
                 if np.ndim(f) > 0}
        if not sizes:
            return None
        if len(sizes) > 1:
            raise ValueError(f"inconsistent per-level field lengths: {sorted(sizes)}")
        return int(sizes.pop())

    @property
    def n_groups(self) -> int:
        """Number of server types d (1 for ungrouped models)."""
        return 1 if self.group_sizes is None else len(self.group_sizes)

    @property
    def group_offsets(self) -> tuple[int, ...]:
        """First level id of each group (``group_sizes`` prefix sums)."""
        if self.group_sizes is None:
            return (0,)
        return tuple(int(o) for o in np.cumsum((0,) + self.group_sizes)[:-1])

    @property
    def groups(self) -> tuple[ServerGroup, ...] | None:
        """Reconstructed :class:`ServerGroup` tuple (None when ungrouped)."""
        if self.group_sizes is None:
            return None
        self.validate_groups()
        out = []
        for name, size, off in zip(self.group_names, self.group_sizes, self.group_offsets):
            P, bon, boff = (np.asarray(f).reshape(-1) for f in
                            (self.P, self.beta_on, self.beta_off))
            out.append(ServerGroup(
                name=name, n_servers=size, P=float(P[off]),
                beta_on=float(bon[off]), beta_off=float(boff[off]),
            ))
        return tuple(out)

    def validate_groups(self) -> "CostModel":
        """Check the group metadata is consistent with the per-level arrays."""
        if self.group_sizes is None:
            return self
        if self.group_names is None or len(self.group_names) != len(self.group_sizes):
            raise ValueError(
                f"group_names {self.group_names} must name every group in "
                f"group_sizes {self.group_sizes}"
            )
        if any(int(s) < 1 for s in self.group_sizes):
            raise ValueError(f"group_sizes must all be >= 1, got {self.group_sizes}")
        n = self.n_levels
        total = int(sum(self.group_sizes))
        if n is None or n != total:
            raise ValueError(
                f"group_sizes sum to {total} but the per-level cost arrays "
                f"pin {n} levels"
            )
        return self

    def group_reduce(self, level_values):
        """Sum a trailing ``(..., n_levels)`` axis per group -> ``(..., d)``.

        The per-type aggregation behind ``ProvisionResult.group_cost`` and
        the eval grid's per-type CR columns.  Works on an ungrouped model
        too (one group spanning the whole stack).
        """
        import jax.numpy as jnp

        v = jnp.asarray(level_values)
        if self.group_sizes is None:
            return v.sum(axis=-1, keepdims=True)
        self.validate_groups()
        return jnp.stack(
            [v[..., o:o + s].sum(axis=-1)
             for o, s in zip(self.group_offsets, self.group_sizes)],
            axis=-1,
        )

    def delta_slots(self) -> int:
        """Static scan bound: ceil of the largest per-level Delta (slots)."""
        return int(math.ceil(float(np.max(np.asarray(self.delta)))))

    def per_level(self, n_levels: int):
        """(P, beta_on, beta_off) broadcast to ``(n_levels,)`` float32 arrays."""
        import jax.numpy as jnp

        own = self.n_levels
        if own is not None and own != n_levels:
            raise ValueError(
                f"cost model is pinned to {own} levels, asked for {n_levels}"
            )
        return tuple(
            jnp.broadcast_to(jnp.asarray(f, jnp.float32), (n_levels,))
            for f in (self.P, self.beta_on, self.beta_off)
        )


jax.tree_util.register_dataclass(
    CostModel,
    data_fields=["P", "beta_on", "beta_off"],
    meta_fields=["group_sizes", "group_names"],
)


#: The paper's experimental setting: P = 1, beta_on + beta_off = 6 => Delta = 6.
PAPER_COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


def schedule_cost(x: StepFn, costs: CostModel, *, final_level: float | None = None) -> float:
    """Total cost of a schedule x(t): P * integral(x) + toggle costs.

    ``final_level``: if given, enforce the boundary x(T) = a(T) by charging the
    final forced turn-off/on at T (paper eq. 5).  Homogeneous models only —
    a StepFn carries no per-level identity.
    """
    if costs.is_heterogeneous:
        raise ValueError("schedule_cost needs a homogeneous (scalar) CostModel")
    energy = costs.P * x.integral()
    up, down = x.switching()
    cost = energy + costs.beta_on * up + costs.beta_off * down
    if final_level is not None:
        last = x.values[-1]
        if last > final_level:
            cost += costs.beta_off * (last - final_level)
        elif last < final_level:
            cost += costs.beta_on * (final_level - last)
    return cost
