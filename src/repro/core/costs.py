"""Server-operation cost model (paper Section II-B, problem SCP).

``CostModel`` is JAX-native: ``P``/``beta_on``/``beta_off`` accept python
scalars **or** ``(n_levels,)`` arrays, so one model describes either the
paper's homogeneous fleet or a heterogeneous one (per-level server types,
Albers & Quedenfeld, PAPERS.md).  The critical interval ``delta`` is always
*derived* — Δ = (β_on + β_off) / P per level (paper eq. 12) — never passed
separately.  The class is a registered pytree so specs built from it flow
through ``jax.jit``/``vmap`` as data, not as static compile keys.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

from .stepfn import StepFn

ArrayLike = "float | np.ndarray | jax.Array"


@dataclasses.dataclass(frozen=True, eq=False)
class CostModel:
    """P: energy per unit time per running server; beta_on/off: toggle costs.

    Each field is a scalar (homogeneous fleet) or an ``(n_levels,)`` array
    (per-level server types); scalars broadcast against array fields.
    """

    P: "ArrayLike" = 1.0
    beta_on: "ArrayLike" = 3.0
    beta_off: "ArrayLike" = 3.0

    @property
    def beta(self):
        return self.beta_on + self.beta_off

    @property
    def delta(self):
        """Critical interval Delta = (beta_on + beta_off) / P  (paper eq. 12).

        Scalar for homogeneous models, ``(n_levels,)`` for heterogeneous.
        """
        return self.beta / self.P

    @property
    def is_heterogeneous(self) -> bool:
        return any(np.ndim(f) > 0 for f in (self.P, self.beta_on, self.beta_off))

    @property
    def n_levels(self) -> int | None:
        """Fleet size the model pins down, or None for scalar models."""
        sizes = {np.shape(f)[0] for f in (self.P, self.beta_on, self.beta_off)
                 if np.ndim(f) > 0}
        if not sizes:
            return None
        if len(sizes) > 1:
            raise ValueError(f"inconsistent per-level field lengths: {sorted(sizes)}")
        return int(sizes.pop())

    def delta_slots(self) -> int:
        """Static scan bound: ceil of the largest per-level Delta (slots)."""
        return int(math.ceil(float(np.max(np.asarray(self.delta)))))

    def per_level(self, n_levels: int):
        """(P, beta_on, beta_off) broadcast to ``(n_levels,)`` float32 arrays."""
        import jax.numpy as jnp

        own = self.n_levels
        if own is not None and own != n_levels:
            raise ValueError(
                f"cost model is pinned to {own} levels, asked for {n_levels}"
            )
        return tuple(
            jnp.broadcast_to(jnp.asarray(f, jnp.float32), (n_levels,))
            for f in (self.P, self.beta_on, self.beta_off)
        )


jax.tree_util.register_dataclass(
    CostModel, data_fields=["P", "beta_on", "beta_off"], meta_fields=[]
)


#: The paper's experimental setting: P = 1, beta_on + beta_off = 6 => Delta = 6.
PAPER_COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


def schedule_cost(x: StepFn, costs: CostModel, *, final_level: float | None = None) -> float:
    """Total cost of a schedule x(t): P * integral(x) + toggle costs.

    ``final_level``: if given, enforce the boundary x(T) = a(T) by charging the
    final forced turn-off/on at T (paper eq. 5).  Homogeneous models only —
    a StepFn carries no per-level identity.
    """
    if costs.is_heterogeneous:
        raise ValueError("schedule_cost needs a homogeneous (scalar) CostModel")
    energy = costs.P * x.integral()
    up, down = x.switching()
    cost = energy + costs.beta_on * up + costs.beta_off * down
    if final_level is not None:
        last = x.values[-1]
        if last > final_level:
            cost += costs.beta_off * (last - final_level)
        elif last < final_level:
            cost += costs.beta_on * (final_level - last)
    return cost
