"""The paper's provisioning algorithms as a batched, jit-able JAX engine.

The fluid-model level decomposition (DESIGN.md §2) makes every algorithm an
independent per-level computation, so the whole fleet is one vectorized
``lax.scan`` over slots.  On top of that single scan this module layers

  * all the policies — ``A1`` (deterministic, ratio ``2 - α``), ``A2``
    (randomized, ``(e-α)/(e-1)``), ``A3`` (randomized, ``e/(e-1+α)``),
    ``offline`` (hindsight optimum, closed form), ``delayedoff``, and the
    typed-fleet pair from the Albers–Quedenfeld line (arXiv 2107.14672):
    ``AQ-det`` (per-type break-even timers, 2d-competitive over d server
    types) and ``AQ-rand`` (randomized per-type waits, d·e/(e−1)) — with
    the randomized waits sampled per level via an explicit PRNG key,
    matching :mod:`repro.core.ski_rental` semantics;
  * heterogeneous per-level cost models: ``Δ``, ``P`` and the toggle costs
    may all be ``(n_levels,)`` arrays (one server type per level), with the
    per-level critical interval driving waits, peek horizons and costs;
    typed fleets (``CostModel.from_groups``) ride the same arrays, with the
    group metadata driving routed level ids and the group-aligned kernel
    block layout in the sharded path;
  * a leading batch axis over demand traces (``(B, T)`` demand, one subkey
    per trace) via ``vmap``;
  * a vectorized sweep axis over prediction windows (``α = (w+1)/Δ``) via
    ``vmap`` with common random numbers across the sweep, so a whole
    (traces × α × policies) competitive-ratio table is one device program;
  * a fused Pallas grid scan (:mod:`repro.kernels.provision_scan`,
    interpret-mode fallback off-TPU) used by the ``shard_map`` fleet path:
    the full (noise-std x window x trace) sweep runs as one kernel program
    per grid cell and level block, with separate scalar-prefetched demand
    and prediction traces indexed per cell — bit-exact against the
    ``lax.scan`` programs above (common random numbers on every axis).

The public entrypoint is :func:`repro.core.provision.provision`, driven by a
declarative :class:`~repro.core.provision.ProvisionSpec`.  The loose-kwargs
functions that predate it (``provision_schedule``, ``provision_sweep``,
``provision_sweep_costs``, ``provision_cost``,
``provision_schedule_sharded``) remain as thin deprecated wrappers that
forward to the same engine.

Semantics mirror :func:`repro.core.fluid.fluid_scan` exactly (tested).

PRNG contract: ``A2``/``A3`` require ``key``.  The engine draws two
``(T, n_levels)`` uniform tables per trace; the draw at ``[t, l]`` is
consumed iff level ``l`` becomes newly idle in slot ``t`` — a pattern that
depends only on the trace (a level enters idle exactly when it stops being
busy), so schedules are reproducible given (trace, key) and independent
draws are never reused across idle periods.  Batched calls split the key
per trace; the α-sweep reuses the same tables across windows (common
random numbers, variance reduction for ratio curves).
"""
from __future__ import annotations

import functools
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..obs import provenance as _prov

E = math.e

POLICIES = ("A1", "A2", "A3", "offline", "delayedoff", "AQ-det", "AQ-rand")
RANDOMIZED = ("A2", "A3")
#: policies that consume a PRNG key (RANDOMIZED plus the typed AQ-rand)
KEYED = RANDOMIZED + ("AQ-rand",)
#: policies with no prediction peek (ski-rental timers only)
NO_PEEK = ("delayedoff", "AQ-det", "AQ-rand")
#: policies whose schedule ignores the window sweep entirely
WINDOW_FREE = ("offline",) + NO_PEEK


def _check_policy(policy: str) -> None:
    if policy not in POLICIES:
        raise ValueError(
            f"unknown policy {policy!r}: valid policies are {POLICIES}"
        )


def _require_key(policy: str, key) -> None:
    if key is None:
        raise ValueError(f"policy {policy!r} is randomized: pass an explicit key")


# ---------------------------------------------------------------------------
# Randomized-wait sampling (ski-rental thresholds)
# ---------------------------------------------------------------------------

def _uniforms(key: jax.Array, T: int, n_levels: int) -> tuple[jax.Array, jax.Array]:
    """Two (T, n_levels) U(0,1) tables: atom draw (A3) and value draw."""
    k0, k1 = jax.random.split(key)
    return (
        jax.random.uniform(k0, (T, n_levels)),
        jax.random.uniform(k1, (T, n_levels)),
    )


def _waits_from_uniforms(policy, u0, u, window, delta):
    """Transform uniform tables into wait thresholds for a given window.

    A2: Z ~ e^{z/((1-α)Δ)} / ((e-1)(1-α)Δ) on [0, (1-α)Δ]  (inverse CDF).
    A3: atom at 0 w.p. α/(e-1+α), else A2's density (corrected atom, see
    ski_rental.py).  AQ-rand: the no-peek α = 0 case — the full-span
    e/(e−1) ski-rental distribution per level, which on a typed fleet is
    the Albers–Quedenfeld randomized per-type wait (d·e/(e−1) overall).
    ``delta`` is a scalar or a per-level ``(N,)`` array — heterogeneous
    fleets get a distinct α and span per level.  Keeping the transform
    separate from the draws lets the α-sweep share draws across windows.
    """
    b = jnp.asarray(delta, jnp.float32)
    alpha = jnp.clip((jnp.asarray(window, jnp.float32) + 1.0) / b, 0.0, 1.0)
    if policy == "AQ-rand":             # no peek: the window never enters
        alpha = jnp.zeros_like(alpha)
    span = (1.0 - alpha) * b
    waits = span * jnp.log1p(u * (E - 1.0))
    if policy == "A3":
        p0 = alpha / (E - 1.0 + alpha)
        waits = jnp.where(u0 < p0, 0.0, waits)
    return waits


# ---------------------------------------------------------------------------
# The per-level slot scan (all online policies)
# ---------------------------------------------------------------------------

def _slot_update(r, on, wait, busy, seen, wait_draw):
    """One slot of the per-level ski-rental engine (shared by the monolithic
    and the chunked scan bodies — byte-identical op order, so the streaming
    path is bit-exact against :func:`_on_matrix_scan` by construction).

    ``r``/``on``/``wait``: (N,) idle run length, on bit, wait threshold;
    ``busy``: dispatcher compare for this slot; ``seen``: peek verdict;
    ``wait_draw``: this slot's sampled thresholds (None for deterministic
    policies, whose ``wait`` is the static threshold).  Returns the updated
    state plus the ``expired``/``off_now`` decision bits (provenance).
    """
    on = on | busy                                 # dispatcher turn-on
    r = jnp.where(busy, 0.0, r)
    idle = on & ~busy
    if wait_draw is not None:
        wait = jnp.where(idle & (r == 0.0), wait_draw, wait)
    r = jnp.where(idle, r + 1.0, r)
    expired = idle & (r - 1.0 >= wait)
    off_now = expired & ~seen
    on = on & ~off_now
    r = jnp.where(off_now, 0.0, r)
    return (r, on, wait), expired, off_now


def _on_matrix_scan(a, pred, levels, *, delta, max_h, window, policy, waits=None,
                    record=False):
    """(T, N) bool on-matrix via one lax.scan over slots.

    ``delta`` is a scalar or per-level ``(N,)`` array of critical intervals;
    ``max_h`` is the static peek bound (``ceil(max Δ)`` — the peek never
    exceeds the largest critical interval).  ``window`` may be a python int
    or a traced scalar (the α-sweep vmaps over it).  ``waits``: (T, N)
    sampled thresholds for A2/A3; the entry at ``[t, l]`` is consumed iff
    level ``l`` becomes newly idle in slot ``t``.

    ``record=True`` (a python-time switch: the default trace is unchanged)
    additionally emits per-slot decision provenance and returns
    ``(ons, codes)`` with ``codes`` (T, N) uint8 — the
    :mod:`repro.obs.provenance` reason bitmask (demand-rise / wait-expired /
    peek-fired / toggle-off) for every (slot, level).
    """
    T = a.shape[0]
    n = levels.shape[0]
    b = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n,))
    pad = jnp.concatenate([pred, jnp.zeros((max_h,), pred.dtype)])
    w = jnp.asarray(window, jnp.float32)
    if policy in NO_PEEK:           # timer Δ_l (the per-type break-even
        horizon = jnp.zeros((n,), jnp.float32)   # timer for AQ-det), no peek
        m_static = b
    else:
        horizon = jnp.minimum(w + 1.0, b)
        m_static = jnp.maximum(0.0, b - w - 1.0)
    hslots = jnp.arange(max_h, dtype=jnp.float32)

    def step(carry, t):
        r, on, wait = carry                            # (N,) f32, bool, f32
        busy = a[t] > levels
        if record:
            rise = busy & ~on                          # dispatcher turn-on edge
        fut = jax.lax.dynamic_slice(pad, (t + 1,), (max_h,))
        seen = (
            (fut[None, :] > levels[:, None]) & (hslots[None, :] < horizon[:, None])
        ).any(axis=1)
        (r, on, wait), expired, off_now = _slot_update(
            r, on, wait, busy, seen, None if waits is None else waits[t]
        )
        if record:
            codes = (
                rise.astype(jnp.uint8) * _prov.DEMAND_RISE
                + expired.astype(jnp.uint8) * _prov.WAIT_EXPIRED
                + (expired & seen).astype(jnp.uint8) * _prov.PEEK_FIRED
                + off_now.astype(jnp.uint8) * _prov.TOGGLE_OFF
            )
            return (r, on, wait), (on, codes)
        return (r, on, wait), on

    init = (
        jnp.zeros((n,), jnp.float32),
        a[0] > levels,                                  # x(0) = a(0)
        m_static if waits is None else jnp.zeros((n,), jnp.float32),
    )
    (_, _, _), out = jax.lax.scan(step, init, jnp.arange(T))
    return out


def _stream_cell(a, pred, levels, *, delta, max_h, window, policy, waits=None,
                 t_chunk, record=False, lane_ok=None):
    """Chunked slot scan over one (trace, window) cell with explicit carry.

    The streaming twin of :func:`_on_matrix_scan`: instead of materializing
    the (T, N) on-matrix, slots run in ``t_chunk`` tiles under an outer
    ``lax.scan`` whose carry is the O(N) engine state (idle run, on bits,
    wait thresholds) plus int32 accumulators — so only x(t) and per-level
    totals ever leave the scan and the working set is O(t_chunk · N)
    regardless of T.  The slot body is the shared :func:`_slot_update`, so
    the state trajectory is bit-identical to the monolithic scan.

    Toggle accounting uses the virtual-boundary convention: the "previous"
    state at t = 0 is the busy mask itself, which makes ``up`` absorb
    ``_cost_terms``' ``first_on`` and makes the t = 0 ``down`` vanish; the
    forced x(T) = a(T) final off is added here from the end-of-trace carry.
    The resulting integer totals equal :func:`_cost_terms` of the monolithic
    on-matrix exactly.

    Returns ``(x, terms, on_final)``: ``x`` (T,) int32, ``terms`` a dict of
    (N,) int32 totals ``run``/``up``/``down`` (plus the four
    :data:`repro.obs.provenance.COUNT_ORDER` counters when ``record``), and
    ``on_final`` the (N,) end-of-trace on bits (the sharded path recomputes
    its own routed final-off from these).  ``lane_ok``: optional (N,) bool
    storage-lane mask (the sharded layout's pad lanes) applied to x and
    every accumulator, mirroring the Pallas kernels' lane masking.
    """
    T = a.shape[0]
    n = levels.shape[0]
    b = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n,))
    w = jnp.asarray(window, jnp.float32)
    if policy in NO_PEEK:
        horizon = jnp.zeros((n,), jnp.float32)
        m_static = b
    else:
        horizon = jnp.minimum(w + 1.0, b)
        m_static = jnp.maximum(0.0, b - w - 1.0)
    hslots = jnp.arange(max_h, dtype=jnp.float32)
    ok = jnp.ones((n,), bool) if lane_ok is None else lane_ok

    n_chunks = -(-T // t_chunk)
    T_pad = n_chunks * t_chunk
    a_pad = jnp.concatenate([a, jnp.zeros((T_pad - T,), a.dtype)])
    p_pad = jnp.concatenate([pred, jnp.zeros((T_pad - T + max_h,), pred.dtype)])
    w_pad = (
        None if waits is None
        else jnp.concatenate([waits, jnp.zeros((T_pad - T, n), waits.dtype)])
    )
    n_acc = 7 if record else 3
    init = (
        (
            jnp.zeros((n,), jnp.float32),                       # idle run r
            jnp.zeros((n,), bool),          # on (slot 0's |busy seeds x(0)=a(0))
            m_static if waits is None else jnp.zeros((n,), jnp.float32),
        ),
        jnp.zeros((n_acc, n), jnp.int32),
    )

    def chunk(carry, c):
        state, accs = carry
        t0 = c * t_chunk
        a_c = jax.lax.dynamic_slice(a_pad, (t0,), (t_chunk,))
        p_c = jax.lax.dynamic_slice(p_pad, (t0,), (t_chunk + max_h,))
        w_c = (
            None if w_pad is None
            else jax.lax.dynamic_slice(w_pad, (t0, 0), (t_chunk, n))
        )

        def slot(carry2, tl):
            (r, on, wait), accs = carry2
            t = t0 + tl
            valid = t < T                       # pad tail freezes everything
            busy = a_c[tl] > levels
            prev_eff = jnp.where(t == 0, busy, on)    # virtual x(0)=a(0) edge
            rise = busy & ~prev_eff
            fut = jax.lax.dynamic_slice(p_c, (tl + 1,), (max_h,))
            seen = (
                (fut[None, :] > levels[:, None])
                & (hslots[None, :] < horizon[:, None])
            ).any(axis=1)
            (r2, on2, wait2), expired, off_now = _slot_update(
                r, on, wait, busy, seen, None if w_c is None else w_c[tl]
            )
            x_t = jnp.where(valid, (on2 & ok).sum().astype(jnp.int32), 0)
            rows = [on2 & ok, (on2 & ~prev_eff) & ok, (prev_eff & ~on2) & ok]
            if record:
                rows += [
                    rise & ok, expired & ok, (expired & seen) & ok, off_now & ok,
                ]
            inc = jnp.stack([x.astype(jnp.int32) for x in rows])
            accs = jnp.where(valid, accs + inc, accs)
            r2 = jnp.where(valid, r2, r)
            on2 = jnp.where(valid, on2, on)
            wait2 = jnp.where(valid, wait2, wait)
            return ((r2, on2, wait2), accs), x_t

        (state, accs), x_c = jax.lax.scan(slot, (state, accs),
                                          jnp.arange(t_chunk))
        return (state, accs), x_c

    ((_, on_f, _), accs), xs = jax.lax.scan(chunk, init, jnp.arange(n_chunks))
    x = xs.reshape(T_pad)[:T]
    final_off = ((on_f & ok) & ~(a[T - 1] > levels)).astype(jnp.int32)
    terms = {"run": accs[0], "up": accs[1], "down": accs[2] + final_off}
    if record:
        for k, name in enumerate(_prov.COUNT_ORDER):
            terms[name] = accs[3 + k]
    return x, terms, on_f


def _offline_levels(a, n_levels, delta):
    """Hindsight-optimal per-level schedule, closed form (no scan).

    Level on at slot t iff busy, or inside an interior idle gap of length
    <= Delta_l (prev and next busy exist and next - prev - 1 <= b_l); the
    per-level Delta makes this heterogeneous-ready.
    """
    T = a.shape[0]
    b = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n_levels,))
    levels = jnp.arange(n_levels)
    busy = a[:, None] > levels[None, :]                    # (T, N)
    idx = jnp.arange(T)[:, None]
    prev_busy = jax.lax.associative_scan(
        jnp.maximum, jnp.where(busy, idx, -1), axis=0
    )                                                      # last busy <= t
    next_busy = jax.lax.associative_scan(
        jnp.minimum, jnp.where(busy, idx, T + b + 1), axis=0, reverse=True
    )                                                      # first busy >= t
    gap = next_busy - prev_busy - 1
    keep_idle = (prev_busy >= 0) & (next_busy <= T - 1) & (gap * 1.0 <= b)
    return busy | (~busy & keep_idle)


def _level_schedule(a, n_levels, delta, window, policy, predicted=None, key=None):
    """(T, n_levels) bool on-matrix for one trace (any policy).

    ``delta`` must be concrete (a python number or per-level array) — this
    convenience wrapper derives the static peek bound from it.
    """
    _check_policy(policy)
    max_h = int(math.ceil(float(jnp.max(jnp.asarray(delta)))))
    pred = a if predicted is None else predicted
    if policy == "offline":
        return _offline_levels(a, n_levels, delta)
    waits = None
    if policy in KEYED:
        _require_key(policy, key)
        u0, u = _uniforms(key, a.shape[0], n_levels)
        waits = _waits_from_uniforms(policy, u0, u, window, delta)
    levels = jnp.arange(n_levels)
    return _on_matrix_scan(
        a, pred, levels, delta=delta, max_h=max_h, window=window, policy=policy,
        waits=waits,
    )


# ---------------------------------------------------------------------------
# Per-level cost reduction (heterogeneous-ready)
# ---------------------------------------------------------------------------

def _cost_terms(a, on_matrix, P_lv, beta_on_lv, beta_off_lv, levels=None):
    """Per-level cost components of a schedule, each ``(..., N)``.

    ``a`` (..., T) demand, ``on_matrix`` (..., T, N); the cost fields are
    scalars or ``(N,)`` arrays.  ``levels``: the level ids the on-matrix
    columns correspond to (defaults to 0..N-1; the sharded path passes its
    block's offset ids).  Initial state x(0)=a(0) is free; the final slot is
    forced to x(T)=a(T) (paper eq. 5).
    """
    ob = on_matrix.astype(bool)
    on = ob.astype(jnp.int32)
    if levels is None:
        levels = jnp.arange(on_matrix.shape[-1])
    run_slots = on.sum(axis=-2)                                   # (..., N)
    up = jnp.clip(on[..., 1:, :] - on[..., :-1, :], 0).sum(axis=-2)
    down = jnp.clip(on[..., :-1, :] - on[..., 1:, :], 0).sum(axis=-2)
    first_on = (ob[..., 0, :] & ~(a[..., 0, None] > levels)).astype(jnp.int32)
    final_off = (ob[..., -1, :] & ~(a[..., -1, None] > levels)).astype(jnp.int32)
    return {
        "energy": P_lv * run_slots,
        "on_cost": beta_on_lv * (up + first_on),
        "off_cost": beta_off_lv * (down + final_off),
    }


def on_matrix_cost(a, on_matrix, costs):
    """Total cost of a per-level schedule under a (possibly per-level) model.

    ``costs`` is a :class:`repro.core.costs.CostModel`; supports leading
    batch axes: ``a`` (..., T), ``on_matrix`` (..., T, N).
    """
    P_lv, bon_lv, boff_lv = costs.per_level(on_matrix.shape[-1])
    terms = _cost_terms(jnp.asarray(a), on_matrix, P_lv, bon_lv, boff_lv)
    return (terms["energy"] + terms["on_cost"] + terms["off_cost"]).sum(axis=-1)


# ---------------------------------------------------------------------------
# The one engine body: (windows × traces × levels) in a single program
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_levels", "max_h", "policy",
                                             "record"))
def _run(ab, predb, windows, delta, P_lv, beta_on_lv, beta_off_lv, keys, *,
         n_levels, max_h, policy, record=False):
    """Shared engine body behind :func:`repro.core.provision.provision`.

    ``ab``/``predb``: (B, T) int32; ``windows``: (W,); ``delta``/cost
    fields: (N,) float32; ``keys``: (B,) typed keys or None.  Returns a dict
    of ``x`` (W, B, T) int32 and per-level cost terms (W, B, N) float32.
    The cost model enters as pytree *data*, so re-pricing a fleet reuses
    the compiled program — only (policy, shapes) are compile keys.

    ``record=True`` (static) adds ``decisions`` (W, B, T, N) uint8 — the
    per-slot :mod:`repro.obs.provenance` reason bitmask — to the dict; the
    default trace is byte-for-byte today's program.  ``offline`` has no slot
    scan, hence nothing to record (rejected in ``provision``).
    """
    if record and policy == "offline":
        raise ValueError("record=True: offline has no slot scan to record")
    B, T = ab.shape
    levels = jnp.arange(n_levels)

    def reduce(ai, ons, codes=None):
        out = _cost_terms(ai, ons, P_lv, beta_on_lv, beta_off_lv)
        out["x"] = ons.sum(axis=1).astype(jnp.int32)
        if record:
            out["decisions"] = codes
        return out

    def scan(ai, pi, w, waits):
        res = _on_matrix_scan(ai, pi, levels, delta=delta, max_h=max_h,
                              window=w, policy=policy, waits=waits,
                              record=record)
        return res if record else (res, None)

    if policy in WINDOW_FREE:
        # window-independent policies: compute once, broadcast over the sweep
        # (AQ-rand draws its per-level waits from the key but never peeks,
        # so one sample serves the whole sweep too)
        if policy == "AQ-rand":
            u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)
        else:
            u0 = u = jnp.zeros((B, 0, 0))

        def one(ai, pi, u0i, ui):
            if policy == "offline":
                return reduce(ai, _offline_levels(ai, n_levels, delta))
            waits = (
                _waits_from_uniforms(policy, u0i, ui, 0, delta)
                if policy == "AQ-rand"
                else None
            )
            ons, codes = scan(ai, pi, 0, waits)
            return reduce(ai, ons, codes)

        out = jax.vmap(one)(ab, predb, u0, u)
        return jax.tree.map(
            lambda o: jnp.broadcast_to(o[None], (windows.shape[0],) + o.shape), out
        )

    if policy in RANDOMIZED:
        u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)   # (B, T, N)
    else:
        u0 = u = jnp.zeros((B, 0, 0))

    def per_window(w):
        def per_trace(ai, pi, u0i, ui):
            waits = (
                _waits_from_uniforms(policy, u0i, ui, w, delta)
                if policy in RANDOMIZED
                else None
            )
            ons, codes = scan(ai, pi, w, waits)
            return reduce(ai, ons, codes)

        return jax.vmap(per_trace)(ab, predb, u0, u)

    return jax.vmap(per_window)(windows)                 # each leaf (W, B, ...)


@functools.partial(jax.jit, static_argnames=("n_levels", "max_h", "policy",
                                             "record"))
def _run_noise_sweep(ab, predb, windows, delta, P_lv, beta_on_lv, beta_off_lv,
                     keys, *, n_levels, max_h, policy, record=False):
    """:func:`_run` vmapped over a leading (S,) predicted-trace axis — the
    ``PredictionNoise.std_frac`` sweep.  Demand, windows and keys are held
    fixed across the sweep (common random numbers).  A separate jitted
    entrypoint (rather than an inline ``vmap`` in ``provision``) so the
    sweep path's compiles land in a countable cache — the eval harness's
    no-recompile guard watches ``_cache_size`` here and on :func:`_run`."""

    def one(predb_s):
        return _run(
            ab, predb_s, windows, delta, P_lv, beta_on_lv, beta_off_lv, keys,
            n_levels=n_levels, max_h=max_h, policy=policy, record=record,
        )

    return jax.vmap(one)(predb)


@functools.partial(jax.jit, static_argnames=("n_levels", "max_h", "policy",
                                             "t_chunk", "record"))
def _run_stream(ab, predb, windows, delta, P_lv, beta_on_lv, beta_off_lv, keys,
                *, n_levels, max_h, policy, t_chunk, record=False):
    """Streaming twin of :func:`_run`: same (W, B) sweep structure, same CRN
    wait tables, but every cell runs through the chunked
    :func:`_stream_cell` — O(B · t_chunk · N) working set instead of the
    monolithic scan's O(B · T · N) on-matrix, so the scan route accepts
    production-length traces.  Bit-exact against :func:`_run` on x and every
    cost leaf (shared :func:`_slot_update` body, shared wait-draw
    transformation).

    Differences from :func:`_run`, by design: ``offline`` is rejected (it is
    closed-form over the whole trace — use :func:`provision`), and
    ``record=True`` yields ``decision_counts`` (W, B, 4, N) aggregates — the
    fleet-path convention — because per-slot (T, N) codes are exactly the
    O(T · N) buffer the streaming layout exists to avoid.  The randomized
    policies still draw their (T, N) uniform tables up front (the CRN
    contract pins draws to absolute slots); deterministic policies carry
    O(N) only.
    """
    if policy == "offline":
        raise ValueError(
            "offline is closed-form over the full trace; the streaming engine "
            "is online-only — use provision() for offline"
        )
    B, T = ab.shape
    levels = jnp.arange(n_levels)

    def one_cell(ai, pi, w, waits):
        x, t_, _ = _stream_cell(
            ai, pi, levels, delta=delta, max_h=max_h, window=w, policy=policy,
            waits=waits, t_chunk=t_chunk, record=record,
        )
        out = {
            "energy": P_lv * t_["run"],
            "on_cost": beta_on_lv * t_["up"],
            "off_cost": beta_off_lv * t_["down"],
            "x": x,
        }
        if record:
            out["decision_counts"] = jnp.stack(
                [t_[name] for name in _prov.COUNT_ORDER]
            )                                                    # (4, N)
        return out

    if policy in WINDOW_FREE:
        if policy == "AQ-rand":
            u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)
        else:
            u0 = u = jnp.zeros((B, 0, 0))

        def one(ai, pi, u0i, ui):
            waits = (
                _waits_from_uniforms(policy, u0i, ui, 0, delta)
                if policy == "AQ-rand"
                else None
            )
            return one_cell(ai, pi, 0, waits)

        out = jax.vmap(one)(ab, predb, u0, u)
        return jax.tree.map(
            lambda o: jnp.broadcast_to(o[None], (windows.shape[0],) + o.shape), out
        )

    if policy in RANDOMIZED:
        u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)   # (B, T, N)
    else:
        u0 = u = jnp.zeros((B, 0, 0))

    def per_window(w):
        def per_trace(ai, pi, u0i, ui):
            waits = (
                _waits_from_uniforms(policy, u0i, ui, w, delta)
                if policy in RANDOMIZED
                else None
            )
            return one_cell(ai, pi, w, waits)

        return jax.vmap(per_trace)(ab, predb, u0, u)

    return jax.vmap(per_window)(windows)                 # each leaf (W, B, ...)


@functools.partial(jax.jit, static_argnames=("n_levels", "max_h", "policy",
                                             "t_chunk", "record"))
def _run_stream_noise(ab, predb, windows, delta, P_lv, beta_on_lv, beta_off_lv,
                      keys, *, n_levels, max_h, policy, t_chunk, record=False):
    """:func:`_run_stream` vmapped over a leading (S,) predicted-trace axis
    (the noise sweep), mirroring :func:`_run_noise_sweep` — a separate
    jitted entrypoint so the streaming sweep path's compiles land in a
    countable cache too."""

    def one(predb_s):
        return _run_stream(
            ab, predb_s, windows, delta, P_lv, beta_on_lv, beta_off_lv, keys,
            n_levels=n_levels, max_h=max_h, policy=policy, t_chunk=t_chunk,
            record=record,
        )

    return jax.vmap(one)(predb)


# ---------------------------------------------------------------------------
# Fleet-scale engine body: shard the level axis over the mesh (Pallas scan)
# ---------------------------------------------------------------------------

def _sharded_run(mesh, axis, ab, predb, windows, delta, P_lv, beta_on_lv,
                 beta_off_lv, *, n_levels, max_h, policy, keys=None,
                 use_pallas=True, group_sizes=None, record=False):
    """Level-sharded engine over the full (S, W, B) sweep grid.

    ``ab``: (B, T) demand; ``predb``: (S, B, T) predicted traces (S = 1
    without a noise sweep); ``windows``: (W,) concrete window values;
    ``keys``: (B,) per-trace keys for the randomized policies.  Returns the
    same dict as :func:`_run_noise_sweep` — leaves shaped (S, W, B, ...) —
    computed through the fused Pallas grid scan
    (:func:`repro.kernels.provision_scan.provision_scan_grid`): one program
    per ((s, w, b) cell, level block), levels sharded over ``axis``.

    Bit-exact against the lax.scan programs: the wait tables are the same
    per-trace uniform draws transformed per window (common random numbers
    across both sweep axes — noise cells share draws outright).  The thin
    python wrapper only concretizes the static unroll bound; the body is
    :func:`_sharded_grid`, a separate jitted entrypoint so the fleet path's
    compiles land in a countable cache (watched by the eval harness and the
    benchmark smoke gates alongside ``_run``/``_run_noise_sweep``).
    """
    _check_policy(policy)
    if policy == "offline":
        raise ValueError(
            "sharded path supports online policies (offline has no slot scan); "
            f"valid policies are {tuple(p for p in POLICIES if p != 'offline')}"
        )
    if policy in KEYED and keys is None:
        _require_key(policy, None)
    windows = jnp.asarray(windows, jnp.int32)
    if policy in NO_PEEK:
        h_unroll = 0
    else:
        try:
            w_max = int(windows.max())                       # static peek bound
        except jax.errors.ConcretizationTypeError:
            # provision(mesh=...) traced under an outer jit/vmap: the sweep
            # values aren't concrete, so unroll to the Δ bound — the
            # per-cell horizon rows mask the peek to min(w+1, Δ_l) anyway,
            # a wider unroll only costs a few masked compares
            w_max = max_h
        h_unroll = int(min(w_max + 1, max_h))
    return _sharded_grid(
        jnp.asarray(ab), jnp.asarray(predb), windows, delta, P_lv,
        beta_on_lv, beta_off_lv, keys,
        mesh=mesh, axis=axis, n_levels=n_levels, max_h=max_h,
        h_unroll=h_unroll, policy=policy, use_pallas=use_pallas,
        group_sizes=group_sizes, record=record,
    )


#: routing id for pad lanes in the sharded level layout: compares false
#: against any int32 demand, so a pad lane can never turn on
ROUTE_SENTINEL = 2**30


def _group_layout(n_levels, group_sizes, size):
    """Static (route, sel, n_layout) storage layout for the sharded level axis.

    ``route[j]`` is the *routing id* of storage lane ``j`` — the global
    level the busy compare ``a(t) > route[j]`` dispatches against — or
    ``ROUTE_SENTINEL`` for pad lanes.  ``sel[l]`` is the storage lane of
    real level ``l`` (compacts gathered per-lane outputs back to level
    order).  Ungrouped fleets lay levels out contiguously (identical to the
    pre-typed engine).  Typed fleets pad each group to an 8-sublane
    multiple — capped at the kernel's 128-lane block — so no
    threshold/horizon block straddles two server types: each Pallas block
    is group-pure, which is what lets a block carry one type's Δ/waits.
    The tail is padded to a mesh-size multiple either way.
    """
    if group_sizes is None:
        sizes = padded = [int(n_levels)]
    else:
        sizes = [int(s) for s in group_sizes]
        align = min(128, -(-max(sizes) // 8) * 8)
        padded = [-(-s // align) * align for s in sizes]
    n_layout = -(-sum(padded) // size) * size
    route = np.full(n_layout, ROUTE_SENTINEL, np.int32)
    sel = np.empty(n_levels, np.int64)
    off_route = off_lane = 0
    for s, p in zip(sizes, padded):
        route[off_lane:off_lane + s] = np.arange(off_route, off_route + s)
        sel[off_route:off_route + s] = np.arange(off_lane, off_lane + s)
        off_route += s
        off_lane += p
    return route, sel, n_layout


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axis", "n_levels", "max_h", "h_unroll", "policy", "use_pallas",
    "group_sizes", "record"))
def _sharded_grid(ab, predb, windows, delta, P_lv, beta_on_lv, beta_off_lv,
                  keys, *, mesh, axis, n_levels, max_h, h_unroll, policy,
                  use_pallas, group_sizes=None, record=False):
    """One device program for the sharded (S, W, B) grid.

    The demand/predicted traces and the per-cell wait tables are replicated
    only along the sweep axes; the *level* axis — thresholds, peek
    horizons, Δ, cost fields — is sharded over the mesh.  Each shard runs
    every grid cell over its level block through the Pallas grid scan
    (interpret mode off-TPU); x(t) is a psum and the per-level cost terms a
    tiled all_gather, so the caller sees (S, W, B, ...) leaves identical to
    the unsharded engine.  Scales to fleets far past one host's memory
    (1000+ node deployments decide locally, paper Sec. IV).

    Typed fleets (``group_sizes``): levels are stored in the group-aligned
    layout of :func:`_group_layout` and every lane carries its *routing id*
    explicitly — the kernel's dispatcher compares demand against the routed
    id, not the storage position — so group padding never shifts the demand
    split and gathered outputs compact back to level order via ``sel``.

    ``record=True`` (static) adds ``decision_counts`` (S, W, B, 4, N) int32
    to the dict: aggregate per-level reason counters in
    :data:`repro.obs.provenance.COUNT_ORDER` row order.  The fleet path
    records *aggregates only* — streaming (G, T, N) uint8 codes out of the
    kernel would dwarf the on-matrix itself; docs/observability.md spells
    out the asymmetry with the lax.scan path's full per-slot codes.
    """
    from repro.kernels.provision_scan import provision_scan_grid

    S, B, T = predb.shape
    W = windows.shape[0]
    size = mesh.shape[axis]
    route_np, sel_np, n_layout = _group_layout(n_levels, group_sizes, size)
    per_shard = n_layout // size
    route = jnp.asarray(route_np)
    sel = jnp.asarray(sel_np)

    def pad_lv(v, fill):
        # scatter a real (n_levels,) row into the storage layout; pad lanes
        # take ``fill`` (they are masked out of every output anyway)
        v = jnp.broadcast_to(jnp.asarray(v, jnp.float32), (n_levels,))
        return jnp.full((n_layout,), fill, jnp.float32).at[sel].set(v)

    b_real = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n_levels,))
    b = pad_lv(delta, 1.0)          # padded lanes are masked out; Δ irrelevant
    wf = windows.astype(jnp.float32)
    if policy in RANDOMIZED:
        # draw at n_levels (NOT n_layout) so the (trace, key) -> schedule
        # contract holds regardless of mesh size or group padding, then
        # scatter the table into the layout; the same per-trace draws serve
        # every window (common random numbers)
        u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)  # (B, T, N)
        waits = jax.vmap(lambda w: jax.vmap(
            lambda u0i, ui: _waits_from_uniforms(policy, u0i, ui, w, b_real)
        )(u0, u))(wf)                                        # (W, B, T, N)
        thresholds = (
            jnp.zeros((W, B, T, n_layout), jnp.float32)
            .at[..., sel].set(waits)
            .reshape(W * B, T, n_layout)
        )
    elif policy == "AQ-rand":
        # window-free randomized waits: one (T, N) table per trace serves
        # the whole sweep (the AQ transform pins α = 0)
        u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)
        waits = jax.vmap(
            lambda u0i, ui: _waits_from_uniforms(policy, u0i, ui, 0, b_real)
        )(u0, u)                                             # (B, T, N)
        thresholds = (
            jnp.zeros((B, T, n_layout), jnp.float32).at[..., sel].set(waits)
        )
    elif policy in ("delayedoff", "AQ-det"):
        thresholds = jnp.broadcast_to(b, (W, n_layout))[:, None, :]  # timer Δ_l
    else:                                                    # A1 per window
        thresholds = jnp.maximum(0.0, b[None, :] - wf[:, None] - 1.0)[:, None, :]
    if policy in NO_PEEK:
        horizon_wl = jnp.zeros((W, n_layout), jnp.float32)   # no peek
    else:
        horizon_wl = jnp.minimum(wf[:, None] + 1.0, b[None, :])
    P_pad = pad_lv(P_lv, 0.0)
    bon_pad = pad_lv(beta_on_lv, 0.0)
    boff_pad = pad_lv(beta_off_lv, 0.0)

    # cell maps: cell g = (s, w, b) in row-major order, matching the
    # (S, W, B) axis convention of _run_noise_sweep
    s_ix, w_ix, b_ix = jnp.meshgrid(
        jnp.arange(S), jnp.arange(W), jnp.arange(B), indexing="ij"
    )
    cell_trace = b_ix.reshape(-1).astype(jnp.int32)
    cell_pred = (s_ix * B + b_ix).reshape(-1).astype(jnp.int32)
    if policy in RANDOMIZED:
        cell_thr = (w_ix * B + b_ix).reshape(-1).astype(jnp.int32)
    elif policy == "AQ-rand":
        cell_thr = b_ix.reshape(-1).astype(jnp.int32)        # per-trace tables
    else:
        cell_thr = w_ix.reshape(-1).astype(jnp.int32)
    cell_hor = w_ix.reshape(-1).astype(jnp.int32)
    cell_w = windows[w_ix.reshape(-1)]
    pred_rows = predb.reshape(S * B, T)

    def local(a_rows, p_rows, ct, cp, cthr, chor, cw, thr_l, hor_l, b_l,
              Pp, bon, boff, route_l):
        counts = None
        if use_pallas:
            out = provision_scan_grid(
                a_rows, p_rows, thr_l, ct, cp, cthr, chor,
                delta=max_h, horizon=h_unroll, routes=route_l,
                level_horizon=hor_l, record=record,
            )                                          # (G, T, per_shard)
            ons, counts = out if record else (out, None)
        else:
            def per_cell(bi, pi, ti, w):
                waits = thr_l[ti] if policy in KEYED else None
                return _on_matrix_scan(
                    a_rows[bi], p_rows[pi], route_l, delta=b_l, max_h=max_h,
                    window=w, policy=policy, waits=waits, record=record,
                )
            if record:
                ons, codes = jax.vmap(per_cell)(ct, cp, cthr, cw)
                counts = jnp.stack(
                    [((codes & bit) != 0).sum(axis=1) for bit in _prov.COUNT_BITS],
                    axis=1,
                ).astype(jnp.int32)                    # (G, 4, per_shard)
            else:
                ons = jax.vmap(per_cell)(ct, cp, cthr, cw)
        # pad lanes carry ROUTE_SENTINEL and can never turn on; the mask
        # keeps x(t) robust to any lane whose routed id fell off the fleet
        lane_ok = route_l < n_levels
        ons = ons & lane_ok[None, None, :]
        x = jax.lax.psum(ons.sum(axis=-1).astype(jnp.int32), axis)
        ons = ons.reshape(S, W, B, T, per_shard)
        a_swb = jnp.broadcast_to(a_rows[None, None], (S, W, B, T))
        terms = _cost_terms(a_swb, ons, Pp, bon, boff, levels=route_l)
        terms = {
            k: jax.lax.all_gather(v, axis, axis=3, tiled=True)
            for k, v in terms.items()
        }
        terms["x"] = x.reshape(S, W, B, T)
        if record:
            counts = counts * lane_ok[None, None, :].astype(jnp.int32)
            counts = counts.reshape(S, W, B, 4, per_shard)
            terms["decision_counts"] = jax.lax.all_gather(
                counts, axis, axis=4, tiled=True
            )
        return terms

    out_spec = {"x": P(), "energy": P(), "on_cost": P(), "off_cost": P()}
    if record:
        out_spec["decision_counts"] = P()
    cell_spec = (P(),) * 5
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P()) + cell_spec
        + (P(None, None, axis), P(None, axis), P(axis), P(axis), P(axis),
           P(axis), P(axis)),
        out_specs=out_spec,
        check_rep=False,    # no replication rule for pallas_call yet
    )
    out = fn(ab, pred_rows, cell_trace, cell_pred, cell_thr, cell_hor, cell_w,
             thresholds, horizon_wl, b, P_pad, bon_pad, boff_pad, route)
    # compact the gathered storage layout back to level order (a no-op
    # slice for ungrouped fleets, where sel is contiguous)
    return {
        k: (v if k == "x" else v[..., sel]) for k, v in out.items()
    }


def _sharded_stream(mesh, axis, ab, predb, windows, delta, P_lv, beta_on_lv,
                    beta_off_lv, *, n_levels, max_h, policy, keys=None,
                    use_pallas=True, group_sizes=None, t_chunk=None,
                    record=False):
    """Streaming twin of :func:`_sharded_run`: the level-sharded (S, W, B)
    grid evaluated through the chunked kernels — the HBM-resident
    double-buffered :func:`repro.kernels.provision_scan.provision_scan_stream`
    on the Pallas route, :func:`_stream_cell` on the lax.scan route — so the
    fleet path accepts production-length traces with an O(t_chunk + levels)
    per-cell working set.  Same wait tables, cell maps and layout as the
    monolithic grid (bit-exact on x and every cost leaf); per-slot decision
    codes are never materialized (``record`` yields aggregate counters, the
    existing fleet-path convention).
    """
    from repro.kernels.provision_scan import DEFAULT_T_CHUNK

    _check_policy(policy)
    if policy == "offline":
        raise ValueError(
            "sharded path supports online policies (offline has no slot scan); "
            f"valid policies are {tuple(p for p in POLICIES if p != 'offline')}"
        )
    if policy in KEYED and keys is None:
        _require_key(policy, None)
    windows = jnp.asarray(windows, jnp.int32)
    if policy in NO_PEEK:
        h_unroll = 0
    else:
        try:
            w_max = int(windows.max())
        except jax.errors.ConcretizationTypeError:
            w_max = max_h                       # masked peek bound (see above)
        h_unroll = int(min(w_max + 1, max_h))
    if t_chunk is None:
        t_chunk = DEFAULT_T_CHUNK
    t_chunk = int(min(t_chunk, max(int(ab.shape[-1]), 1)))
    return _sharded_stream_grid(
        jnp.asarray(ab), jnp.asarray(predb), windows, delta, P_lv,
        beta_on_lv, beta_off_lv, keys,
        mesh=mesh, axis=axis, n_levels=n_levels, max_h=max_h,
        h_unroll=h_unroll, policy=policy, use_pallas=use_pallas,
        group_sizes=group_sizes, t_chunk=t_chunk, record=record,
    )


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axis", "n_levels", "max_h", "h_unroll", "policy", "use_pallas",
    "group_sizes", "t_chunk", "record"))
def _sharded_stream_grid(ab, predb, windows, delta, P_lv, beta_on_lv,
                         beta_off_lv, keys, *, mesh, axis, n_levels, max_h,
                         h_unroll, policy, use_pallas, group_sizes=None,
                         t_chunk, record=False):
    """One device program for the streaming sharded grid.

    Identical sweep/layout/threshold construction to :func:`_sharded_grid`
    — same CRN draws, same group-aligned routed lanes — but each shard
    reduces its level block through the streaming kernels, which return
    x(t), per-lane accumulators and the end-of-trace carry instead of the
    (G, T, per_shard) on-matrix.  The forced x(T) = a(T) final off is
    applied here from the carry (the kernel contract leaves it to the
    caller, who alone knows the trace really ends at T).
    """
    from repro.kernels.provision_scan import provision_scan_stream

    S, B, T = predb.shape
    W = windows.shape[0]
    size = mesh.shape[axis]
    route_np, sel_np, n_layout = _group_layout(n_levels, group_sizes, size)
    per_shard = n_layout // size
    route = jnp.asarray(route_np)
    sel = jnp.asarray(sel_np)

    def pad_lv(v, fill):
        v = jnp.broadcast_to(jnp.asarray(v, jnp.float32), (n_levels,))
        return jnp.full((n_layout,), fill, jnp.float32).at[sel].set(v)

    b_real = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n_levels,))
    b = pad_lv(delta, 1.0)
    wf = windows.astype(jnp.float32)
    if policy in RANDOMIZED:
        u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)  # (B, T, N)
        waits = jax.vmap(lambda w: jax.vmap(
            lambda u0i, ui: _waits_from_uniforms(policy, u0i, ui, w, b_real)
        )(u0, u))(wf)                                        # (W, B, T, N)
        thresholds = (
            jnp.zeros((W, B, T, n_layout), jnp.float32)
            .at[..., sel].set(waits)
            .reshape(W * B, T, n_layout)
        )
    elif policy == "AQ-rand":
        u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)
        waits = jax.vmap(
            lambda u0i, ui: _waits_from_uniforms(policy, u0i, ui, 0, b_real)
        )(u0, u)                                             # (B, T, N)
        thresholds = (
            jnp.zeros((B, T, n_layout), jnp.float32).at[..., sel].set(waits)
        )
    elif policy in ("delayedoff", "AQ-det"):
        thresholds = jnp.broadcast_to(b, (W, n_layout))[:, None, :]  # timer Δ_l
    else:                                                    # A1 per window
        thresholds = jnp.maximum(0.0, b[None, :] - wf[:, None] - 1.0)[:, None, :]
    if policy in NO_PEEK:
        horizon_wl = jnp.zeros((W, n_layout), jnp.float32)
    else:
        horizon_wl = jnp.minimum(wf[:, None] + 1.0, b[None, :])
    P_pad = pad_lv(P_lv, 0.0)
    bon_pad = pad_lv(beta_on_lv, 0.0)
    boff_pad = pad_lv(beta_off_lv, 0.0)

    s_ix, w_ix, b_ix = jnp.meshgrid(
        jnp.arange(S), jnp.arange(W), jnp.arange(B), indexing="ij"
    )
    cell_trace = b_ix.reshape(-1).astype(jnp.int32)
    cell_pred = (s_ix * B + b_ix).reshape(-1).astype(jnp.int32)
    if policy in RANDOMIZED:
        cell_thr = (w_ix * B + b_ix).reshape(-1).astype(jnp.int32)
    elif policy == "AQ-rand":
        cell_thr = b_ix.reshape(-1).astype(jnp.int32)
    else:
        cell_thr = w_ix.reshape(-1).astype(jnp.int32)
    cell_hor = w_ix.reshape(-1).astype(jnp.int32)
    cell_w = windows[w_ix.reshape(-1)]
    pred_rows = predb.reshape(S * B, T)

    def local(a_rows, p_rows, ct, cp, cthr, chor, cw, thr_l, hor_l, b_l,
              Pp, bon, boff, route_l):
        lane_ok = route_l < n_levels
        if use_pallas:
            x_g, accs, carry = provision_scan_stream(
                a_rows, p_rows, thr_l, ct, cp, cthr, chor,
                horizon=h_unroll, t_chunk=t_chunk, n_levels=n_levels,
                routes=route_l, level_horizon=hor_l, record=record,
            )                            # x (G, T); accs/carry lanes (G, per_shard)
            # forced final off: the kernel's down stops at the virtual
            # boundary; close the trace against the routed busy compare
            a_last = a_rows[ct, T - 1]                               # (G,)
            final_off = (
                carry["on"] & lane_ok[None, :]
                & ~(a_last[:, None] > route_l[None, :])
            ).astype(jnp.int32)
            accs = dict(accs)
            accs["down"] = accs["down"] + final_off
        else:
            def per_cell(bi, pi, ti, w):
                waits = thr_l[ti] if policy in KEYED else None
                x, t_, _ = _stream_cell(
                    a_rows[bi], p_rows[pi], route_l, delta=b_l, max_h=max_h,
                    window=w, policy=policy, waits=waits, t_chunk=t_chunk,
                    record=record, lane_ok=lane_ok,
                )
                return x, t_
            x_g, accs = jax.vmap(per_cell)(ct, cp, cthr, cw)
        x = jax.lax.psum(x_g, axis)                              # (G, T)
        terms = {
            "energy": Pp * accs["run"],
            "on_cost": bon * accs["up"],
            "off_cost": boff * accs["down"],
        }
        terms = {
            k: jax.lax.all_gather(
                v.reshape(S, W, B, per_shard), axis, axis=3, tiled=True
            )
            for k, v in terms.items()
        }
        terms["x"] = x.reshape(S, W, B, T)
        if record:
            counts = jnp.stack(
                [accs[name] for name in _prov.COUNT_ORDER], axis=1
            )                                                # (G, 4, per_shard)
            terms["decision_counts"] = jax.lax.all_gather(
                counts.reshape(S, W, B, 4, per_shard), axis, axis=4, tiled=True
            )
        return terms

    out_spec = {"x": P(), "energy": P(), "on_cost": P(), "off_cost": P()}
    if record:
        out_spec["decision_counts"] = P()
    cell_spec = (P(),) * 5
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P()) + cell_spec
        + (P(None, None, axis), P(None, axis), P(axis), P(axis), P(axis),
           P(axis), P(axis)),
        out_specs=out_spec,
        check_rep=False,
    )
    out = fn(ab, pred_rows, cell_trace, cell_pred, cell_thr, cell_hor, cell_w,
             thresholds, horizon_wl, b, P_pad, bon_pad, boff_pad, route)
    return {
        k: (v if k == "x" else v[..., sel]) for k, v in out.items()
    }


# ---------------------------------------------------------------------------
# Deprecated loose-kwargs API (forwards to the spec engine)
# ---------------------------------------------------------------------------

def _warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"deprecated: {old} — build a ProvisionSpec and call "
        f"repro.core.provision ({new})",
        DeprecationWarning,
        stacklevel=3,
    )


def _dynamics_costs(delta):
    """A CostModel whose derived Δ equals the wrapper's free-floating delta."""
    from .costs import CostModel

    d = jnp.asarray(delta, jnp.float32)
    half = d / 2.0 if d.ndim else float(delta) / 2.0
    return CostModel(P=1.0, beta_on=half, beta_off=half)


def provision_schedule(
    a: jax.Array,          # (T,) or (B, T) int32 demand per slot
    *,
    n_levels: int,
    delta: int,            # critical interval in slots (beta/P)
    window: int = 0,       # future slots visible (current slot always known)
    policy: str = "A1",    # A1 | A2 | A3 | offline | delayedoff
    predicted: jax.Array | None = None,
    key: jax.Array | None = None,   # required for A2/A3; split per trace if batched
) -> jax.Array:
    """Deprecated: use ``provision(ProvisionSpec(...))``.

    Returns x: (T,) or (B, T) int32 — number of powered-on servers per slot.
    """
    from .provision import PolicySpec, ProvisionSpec, Workload, provision

    _warn_deprecated("provision_schedule(...)", "result.x")
    spec = ProvisionSpec(
        costs=_dynamics_costs(delta),
        workload=Workload(demand=a, predicted=predicted),
        policy=PolicySpec(name=policy, window=window, key=key),
        n_levels=n_levels,
    )
    return provision(spec).x


def provision_sweep(
    a: jax.Array,
    *,
    n_levels: int,
    delta: int,
    windows: jax.Array,    # (W,) prediction windows in slots; α = (w+1)/Δ
    policy: str = "A1",
    key: jax.Array | None = None,
    predicted: jax.Array | None = None,
) -> jax.Array:
    """Deprecated: use ``provision(ProvisionSpec(...))`` with ``windows=``.

    x over the whole sweep: (W, T) for a (T,) trace, (W, B, T) batched.
    """
    from .provision import PolicySpec, ProvisionSpec, Workload, provision

    _warn_deprecated("provision_sweep(...)", "result.x with a windows axis")
    spec = ProvisionSpec(
        costs=_dynamics_costs(delta),
        workload=Workload(demand=a, predicted=predicted),
        policy=PolicySpec(name=policy, windows=windows, key=key),
        n_levels=n_levels,
    )
    return provision(spec).x


def provision_sweep_costs(
    a: jax.Array,
    *,
    n_levels: int,
    delta: int,
    windows: jax.Array,
    policy: str = "A1",
    key: jax.Array | None = None,
    predicted: jax.Array | None = None,
    P: float = 1.0,
    beta_on: float = 3.0,
    beta_off: float = 3.0,
) -> jax.Array:
    """Deprecated: use ``provision(ProvisionSpec(...))`` and ``result.cost``.

    Schedule costs over the sweep: (W,) or (W, B) — one device program.
    The redundant ``delta`` kwarg must equal the derived
    ``(beta_on + beta_off) / P`` (the spec API removes it entirely).
    """
    from .costs import CostModel
    from .provision import PolicySpec, ProvisionSpec, Workload, provision

    _warn_deprecated("provision_sweep_costs(...)", "result.cost with a windows axis")
    derived = (beta_on + beta_off) / P
    if abs(derived - float(delta)) > 1e-6:
        raise ValueError(
            f"delta={delta} disagrees with (beta_on+beta_off)/P={derived}; "
            "the spec API derives delta from CostModel — drop the delta kwarg"
        )
    spec = ProvisionSpec(
        costs=CostModel(P=P, beta_on=beta_on, beta_off=beta_off),
        workload=Workload(demand=a, predicted=predicted),
        policy=PolicySpec(name=policy, windows=windows, key=key),
        n_levels=n_levels,
    )
    return provision(spec).cost


def provision_cost(
    a: jax.Array, on_matrix: jax.Array, P: float, beta_on: float, beta_off: float
) -> jax.Array:
    """Deprecated: use ``on_matrix_cost(a, on_matrix, CostModel(...))`` or the
    ``cost``/``level_cost`` fields of a :func:`provision` result.

    Total cost of a per-level schedule (energy + toggles + forced final off).
    Supports leading batch axes: ``a`` (..., T), ``on_matrix`` (..., T, N).
    """
    from .costs import CostModel

    _warn_deprecated("provision_cost(...)", "result.cost / on_matrix_cost")
    return on_matrix_cost(a, on_matrix, CostModel(P=P, beta_on=beta_on, beta_off=beta_off))


def provision_schedule_sharded(
    mesh: Mesh,
    a: jax.Array,
    *,
    n_levels: int,
    delta: int,
    window: int = 0,
    axis: str = "data",
    policy: str = "A1",
    key: jax.Array | None = None,
    predicted: jax.Array | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """Deprecated: use ``provision(ProvisionSpec(..., mesh=mesh))``.

    Same as provision_schedule, levels sharded over ``axis`` via shard_map.
    """
    from .provision import PolicySpec, ProvisionSpec, Workload, provision

    _warn_deprecated("provision_schedule_sharded(...)", "mesh= on the spec")
    spec = ProvisionSpec(
        costs=_dynamics_costs(delta),
        workload=Workload(demand=a, predicted=predicted),
        policy=PolicySpec(name=policy, window=window, key=key),
        n_levels=n_levels,
        mesh=mesh,
        mesh_axis=axis,
        use_pallas=use_pallas,
    )
    return provision(spec).x
