"""The paper's provisioning algorithms as a batched, jit-able JAX engine.

The fluid-model level decomposition (DESIGN.md §2) makes every algorithm an
independent per-level computation, so the whole fleet is one vectorized
``lax.scan`` over slots.  On top of that single scan this module layers

  * all five policies — ``A1`` (deterministic, ratio ``2 - α``), ``A2``
    (randomized, ``(e-α)/(e-1)``), ``A3`` (randomized, ``e/(e-1+α)``),
    ``offline`` (hindsight optimum, closed form) and ``delayedoff`` — with
    the randomized waits sampled per level via an explicit PRNG key,
    matching :mod:`repro.core.ski_rental` semantics;
  * a leading batch axis over demand traces (``(B, T)`` demand, one subkey
    per trace) via ``vmap``;
  * a vectorized sweep axis over prediction windows (``α = (w+1)/Δ``) via
    ``vmap`` with common random numbers across the sweep, so a whole
    (traces × α × policies) competitive-ratio table is one device program;
  * a fused Pallas per-level scan (:mod:`repro.kernels.provision_scan`,
    interpret-mode fallback off-TPU) used by the ``shard_map`` fleet path.

Semantics mirror :func:`repro.core.fluid.fluid_scan` exactly (tested).

PRNG contract: ``A2``/``A3`` require ``key``.  The engine draws two
``(T, n_levels)`` uniform tables per trace; the draw at ``[t, l]`` is
consumed iff level ``l`` becomes newly idle in slot ``t`` — a pattern that
depends only on the trace (a level enters idle exactly when it stops being
busy), so schedules are reproducible given (trace, key) and independent
draws are never reused across idle periods.  Batched calls split the key
per trace; the α-sweep reuses the same tables across windows (common
random numbers, variance reduction for ratio curves).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

E = math.e

POLICIES = ("A1", "A2", "A3", "offline", "delayedoff")
RANDOMIZED = ("A2", "A3")


# ---------------------------------------------------------------------------
# Randomized-wait sampling (ski-rental thresholds)
# ---------------------------------------------------------------------------

def _uniforms(key: jax.Array, T: int, n_levels: int) -> tuple[jax.Array, jax.Array]:
    """Two (T, n_levels) U(0,1) tables: atom draw (A3) and value draw."""
    k0, k1 = jax.random.split(key)
    return (
        jax.random.uniform(k0, (T, n_levels)),
        jax.random.uniform(k1, (T, n_levels)),
    )


def _waits_from_uniforms(policy, u0, u, window, delta):
    """Transform uniform tables into wait thresholds for a given window.

    A2: Z ~ e^{z/((1-α)Δ)} / ((e-1)(1-α)Δ) on [0, (1-α)Δ]  (inverse CDF).
    A3: atom at 0 w.p. α/(e-1+α), else A2's density (corrected atom, see
    ski_rental.py).  Keeping the transform separate from the draws lets the
    α-sweep share draws across windows.
    """
    b = float(delta)
    alpha = jnp.clip((jnp.asarray(window, jnp.float32) + 1.0) / b, 0.0, 1.0)
    span = (1.0 - alpha) * b
    waits = span * jnp.log1p(u * (E - 1.0))
    if policy == "A3":
        p0 = alpha / (E - 1.0 + alpha)
        waits = jnp.where(u0 < p0, 0.0, waits)
    return waits


# ---------------------------------------------------------------------------
# The per-level slot scan (all online policies)
# ---------------------------------------------------------------------------

def _on_matrix_scan(a, pred, levels, *, delta, window, policy, waits=None):
    """(T, N) bool on-matrix via one lax.scan over slots.

    ``window`` may be a python int or a traced scalar (the α-sweep vmaps
    over it).  ``waits``: (T, N) sampled thresholds for A2/A3; the entry at
    ``[t, l]`` is consumed iff level ``l`` becomes newly idle in slot ``t``.
    """
    T = a.shape[0]
    b = float(delta)
    max_h = int(delta)              # the peek never exceeds the critical interval
    pad = jnp.concatenate([pred, jnp.zeros((max_h,), pred.dtype)])
    w = jnp.asarray(window, jnp.float32)
    if policy == "delayedoff":      # timer Δ, no peek
        horizon = jnp.float32(0.0)
        m_static = jnp.float32(b)
    else:
        horizon = jnp.minimum(w + 1.0, b)
        m_static = jnp.maximum(0.0, b - w - 1.0)
    hslots = jnp.arange(max_h, dtype=jnp.float32)

    def step(carry, t):
        r, on, wait = carry                            # (N,) f32, bool, f32
        busy = a[t] > levels
        on = on | busy                                 # dispatcher turn-on
        r = jnp.where(busy, 0.0, r)
        idle = on & ~busy
        if waits is not None:
            wait = jnp.where(idle & (r == 0.0), waits[t], wait)
        r = jnp.where(idle, r + 1.0, r)
        fut = jax.lax.dynamic_slice(pad, (t + 1,), (max_h,))
        seen = ((fut[None, :] > levels[:, None]) & (hslots[None, :] < horizon)).any(axis=1)
        off_now = idle & (r - 1.0 >= wait) & ~seen
        on = on & ~off_now
        r = jnp.where(off_now, 0.0, r)
        return (r, on, wait), on

    n = levels.shape[0]
    init = (
        jnp.zeros((n,), jnp.float32),
        a[0] > levels,                                  # x(0) = a(0)
        jnp.full((n,), m_static) if waits is None else jnp.zeros((n,), jnp.float32),
    )
    (_, _, _), ons = jax.lax.scan(step, init, jnp.arange(T))
    return ons


def _offline_levels(a, n_levels, b):
    """Hindsight-optimal per-level schedule, closed form (no scan).

    Level on at slot t iff busy, or inside an interior idle gap of length
    <= Delta (prev and next busy exist and next - prev - 1 <= b).
    """
    T = a.shape[0]
    levels = jnp.arange(n_levels)
    busy = a[:, None] > levels[None, :]                    # (T, N)
    idx = jnp.arange(T)[:, None]
    prev_busy = jax.lax.associative_scan(
        jnp.maximum, jnp.where(busy, idx, -1), axis=0
    )                                                      # last busy <= t
    next_busy = jax.lax.associative_scan(
        jnp.minimum, jnp.where(busy, idx, T + b + 1), axis=0, reverse=True
    )                                                      # first busy >= t
    gap = next_busy - prev_busy - 1
    keep_idle = (prev_busy >= 0) & (next_busy <= T - 1) & (gap * 1.0 <= b)
    return busy | (~busy & keep_idle)


def _level_schedule(a, n_levels, delta, window, policy, predicted=None, key=None):
    """(T, n_levels) bool on-matrix for one trace (any policy)."""
    if policy not in POLICIES:
        raise KeyError(policy)
    pred = a if predicted is None else predicted
    if policy == "offline":
        return _offline_levels(a, n_levels, delta)
    waits = None
    if policy in RANDOMIZED:
        if key is None:
            raise ValueError(f"policy {policy!r} is randomized: pass an explicit key")
        u0, u = _uniforms(key, a.shape[0], n_levels)
        waits = _waits_from_uniforms(policy, u0, u, window, delta)
    levels = jnp.arange(n_levels)
    return _on_matrix_scan(
        a, pred, levels, delta=delta, window=window, policy=policy, waits=waits
    )


# ---------------------------------------------------------------------------
# Public engine: single trace or batched, plus the α-sweep
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_levels", "delta", "window", "policy"))
def provision_schedule(
    a: jax.Array,          # (T,) or (B, T) int32 demand per slot
    *,
    n_levels: int,
    delta: int,            # critical interval in slots (beta/P)
    window: int = 0,       # future slots visible (current slot always known)
    policy: str = "A1",    # A1 | A2 | A3 | offline | delayedoff
    predicted: jax.Array | None = None,
    key: jax.Array | None = None,   # required for A2/A3; split per trace if batched
) -> jax.Array:
    """Returns x: (T,) or (B, T) int32 — number of powered-on servers per slot."""
    a = jnp.asarray(a)
    pred = a if predicted is None else jnp.asarray(predicted)
    if a.ndim == 1:
        ons = _level_schedule(a, n_levels, delta, window, policy, pred, key)
        return ons.sum(axis=1).astype(jnp.int32)

    def one(ai, pi, ki):
        ons = _level_schedule(ai, n_levels, delta, window, policy, pi, ki)
        return ons.sum(axis=1).astype(jnp.int32)

    if policy in RANDOMIZED:
        if key is None:
            raise ValueError(f"policy {policy!r} is randomized: pass an explicit key")
        keys = jax.random.split(key, a.shape[0])
        return jax.vmap(one)(a, pred, keys)
    return jax.vmap(lambda ai, pi: one(ai, pi, None))(a, pred)


def _sweep(a, n_levels, delta, windows, policy, key, predicted, reduce_fn):
    """Shared body of the α-sweep: vmap windows × vmap traces, CRN draws."""
    a = jnp.asarray(a)
    squeeze = a.ndim == 1
    ab = a[None] if squeeze else a
    pred = ab if predicted is None else jnp.asarray(predicted).reshape(ab.shape)
    windows = jnp.asarray(windows)
    B, T = ab.shape

    if policy == "offline":        # window-independent: compute once, broadcast
        def off_one(ai, pi):
            return reduce_fn(ai, _offline_levels(ai, n_levels, delta))
        out = jax.vmap(off_one)(ab, pred)
        out = jnp.broadcast_to(out[None], (windows.shape[0],) + out.shape)
        return out[:, 0] if squeeze else out

    if policy in RANDOMIZED:
        if key is None:
            raise ValueError(f"policy {policy!r} is randomized: pass an explicit key")
        # a (T,) trace consumes the key directly (same stream as
        # provision_schedule); a (B, T) batch splits it per trace.
        keys = key[None] if squeeze else jax.random.split(key, B)
        u0, u = jax.vmap(lambda k: _uniforms(k, T, n_levels))(keys)  # (B, T, N)
    else:
        u0 = u = jnp.zeros((B, 0, 0))

    levels = jnp.arange(n_levels)

    def per_window(w):
        def per_trace(ai, pi, u0i, ui):
            waits = (
                _waits_from_uniforms(policy, u0i, ui, w, delta)
                if policy in RANDOMIZED
                else None
            )
            ons = _on_matrix_scan(
                ai, pi, levels, delta=delta, window=w, policy=policy, waits=waits
            )
            return reduce_fn(ai, ons)

        return jax.vmap(per_trace)(ab, pred, u0, u)

    out = jax.vmap(per_window)(windows)                 # (W, B, ...)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("n_levels", "delta", "policy"))
def provision_sweep(
    a: jax.Array,
    *,
    n_levels: int,
    delta: int,
    windows: jax.Array,    # (W,) prediction windows in slots; α = (w+1)/Δ
    policy: str = "A1",
    key: jax.Array | None = None,
    predicted: jax.Array | None = None,
) -> jax.Array:
    """x over the whole sweep: (W, T) for a (T,) trace, (W, B, T) batched."""
    reduce_fn = lambda ai, ons: ons.sum(axis=1).astype(jnp.int32)
    return _sweep(a, n_levels, delta, windows, policy, key, predicted, reduce_fn)


@functools.partial(jax.jit, static_argnames=("n_levels", "delta", "policy"))
def provision_sweep_costs(
    a: jax.Array,
    *,
    n_levels: int,
    delta: int,
    windows: jax.Array,
    policy: str = "A1",
    key: jax.Array | None = None,
    predicted: jax.Array | None = None,
    P: float = 1.0,
    beta_on: float = 3.0,
    beta_off: float = 3.0,
) -> jax.Array:
    """Schedule costs over the sweep: (W,) or (W, B) — one device program.

    The on-matrices are reduced to costs inside the vmap lanes, so the sweep
    never materializes the full (W, B, T, N) tensor.
    """
    reduce_fn = lambda ai, ons: provision_cost(ai, ons, P, beta_on, beta_off)
    return _sweep(a, n_levels, delta, windows, policy, key, predicted, reduce_fn)


def provision_cost(
    a: jax.Array, on_matrix: jax.Array, P: float, beta_on: float, beta_off: float
) -> jax.Array:
    """Total cost of a per-level schedule (energy + toggles + forced final off).

    Supports leading batch axes: ``a`` (..., T), ``on_matrix`` (..., T, N).
    """
    ob = on_matrix.astype(bool)
    on = ob.astype(jnp.int32)
    energy = P * on.sum(axis=(-2, -1))
    up = jnp.clip(on[..., 1:, :] - on[..., :-1, :], 0).sum(axis=(-2, -1))
    down = jnp.clip(on[..., :-1, :] - on[..., 1:, :], 0).sum(axis=(-2, -1))
    # initial state x(0)=a(0) is free; final forced off to a(T)
    levels = jnp.arange(on_matrix.shape[-1])
    first_turn_on = (ob[..., 0, :] & ~(a[..., 0, None] > levels)).sum(axis=-1)
    final_off = (ob[..., -1, :] & ~(a[..., -1, None] > levels)).sum(axis=-1)
    return (
        energy
        + beta_on * (up + first_turn_on)
        + beta_off * (down + final_off)
    )


# ---------------------------------------------------------------------------
# Fleet-scale: shard the level axis over the mesh (fused Pallas scan)
# ---------------------------------------------------------------------------

def provision_schedule_sharded(
    mesh: Mesh,
    a: jax.Array,
    *,
    n_levels: int,
    delta: int,
    window: int = 0,
    axis: str = "data",
    policy: str = "A1",
    key: jax.Array | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """Same as provision_schedule, levels sharded over ``axis`` via shard_map.

    The demand trace is replicated (tiny); each shard runs its own level
    block through the fused Pallas scan kernel (interpret mode off-TPU);
    the final x(t) is a psum over shards.  Scales to fleets far past one
    host's memory (1000+ node deployments decide locally, paper Sec. IV).
    """
    from repro.kernels.provision_scan import provision_scan

    if policy not in POLICIES or policy == "offline":
        raise KeyError(f"sharded path supports online policies, got {policy!r}")
    a = jnp.asarray(a)
    T = a.shape[0]
    size = mesh.shape[axis]
    n_padded = -(-n_levels // size) * size
    per_shard = n_padded // size

    b = float(delta)
    if policy in RANDOMIZED:
        if key is None:
            raise ValueError(f"policy {policy!r} is randomized: pass an explicit key")
        u0, u = _uniforms(key, T, n_padded)
        thresholds = _waits_from_uniforms(policy, u0, u, window, delta)  # (T, Np)
        thr_spec = P(None, axis)
    else:
        m = b if policy == "delayedoff" else max(0.0, b - window - 1.0)
        thresholds = jnp.full((n_padded,), m, jnp.float32)
        thr_spec = P(axis)
    horizon = 0 if policy == "delayedoff" else int(min(window + 1, delta))

    def local(a_local, thr_local):
        i = jax.lax.axis_index(axis)
        base = i * per_shard
        if use_pallas:
            ons = provision_scan(
                a_local, thr_local, delta=delta, horizon=horizon, base_level=base
            )
        else:
            levels = base + jnp.arange(per_shard)
            waits = thr_local if thr_local.ndim == 2 else None
            ons = _on_matrix_scan(
                a_local, a_local, levels,
                delta=delta, window=window, policy=policy, waits=waits,
            )
        x_local = ons.sum(axis=1).astype(jnp.int32)
        return jax.lax.psum(x_local, axis)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), thr_spec),
        out_specs=P(),
        check_rep=False,    # no replication rule for pallas_call yet
    )
    return fn(a, thresholds)
