"""The paper's provisioning algorithms as composable, jit-able JAX modules.

The fluid-model level decomposition (DESIGN.md §2) makes every algorithm an
independent per-level computation, so the whole fleet is one vectorized
``lax.scan`` over slots — and for very large fleets the *level* axis shards
over the mesh with ``shard_map`` (per-level instances are embarrassingly
parallel).  This is the form the serving autoscaler and the elastic trainer
consume on-device.

Semantics mirror :func:`repro.core.fluid.fluid_scan` exactly (tested).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

E = math.e


@functools.partial(jax.jit, static_argnames=("n_levels", "delta", "window", "policy"))
def provision_schedule(
    a: jax.Array,          # (T,) int32 demand per slot
    *,
    n_levels: int,
    delta: int,            # critical interval in slots (beta/P)
    window: int = 0,       # future slots visible (current slot always known)
    policy: str = "A1",    # A1 | offline | delayedoff
    predicted: jax.Array | None = None,
) -> jax.Array:
    """Returns x: (T,) int32 — number of powered-on servers per slot."""
    on_matrix = _level_schedule(a, n_levels, delta, window, policy, predicted)
    return on_matrix.sum(axis=1).astype(jnp.int32)


def _level_schedule(a, n_levels, delta, window, policy, predicted=None):
    """(T, n_levels) bool on-matrix."""
    T = a.shape[0]
    pred = a if predicted is None else predicted
    b = delta
    w = window
    m = max(0.0, b - w - 1) if policy == "A1" else float(b)   # delayedoff: m=b
    horizon = int(min(w + 1, b)) if policy == "A1" else 0
    levels = jnp.arange(n_levels)

    if policy == "offline":
        return _offline_levels(a, n_levels, b)

    pad = jnp.concatenate([pred, jnp.zeros((max(horizon, 1),), pred.dtype)])

    def step(carry, t):
        r, on = carry                                  # (N,) f32, (N,) bool
        busy = a[t] > levels
        on = on | busy                                 # dispatcher turn-on
        r = jnp.where(busy, 0.0, r)
        idle = on & ~busy
        r = jnp.where(idle, r + 1.0, r)
        if horizon > 0:
            fut = jax.lax.dynamic_slice(pad, (t + 1,), (horizon,))
            seen = (fut[None, :] > levels[:, None]).any(axis=1)
        else:
            seen = jnp.zeros_like(idle)
        off_now = idle & (r - 1.0 >= m) & ~seen
        on = on & ~off_now
        r = jnp.where(off_now, 0.0, r)
        return (r, on), on

    init = (levels * 0.0, a[0] > levels)   # derived from `levels` so the
    (_, _), ons = jax.lax.scan(step, init, jnp.arange(T))  # carry stays varying
    return ons


def _offline_levels(a, n_levels, b):
    """Hindsight-optimal per-level schedule, closed form (no scan).

    Level on at slot t iff busy, or inside an interior idle gap of length
    <= Delta (prev and next busy exist and next - prev - 1 <= b).
    """
    T = a.shape[0]
    levels = jnp.arange(n_levels)
    busy = a[:, None] > levels[None, :]                    # (T, N)
    idx = jnp.arange(T)[:, None]
    prev_busy = jax.lax.associative_scan(
        jnp.maximum, jnp.where(busy, idx, -1), axis=0
    )                                                      # last busy <= t
    next_busy = jax.lax.associative_scan(
        jnp.minimum, jnp.where(busy, idx, T + b + 1), axis=0, reverse=True
    )                                                      # first busy >= t
    gap = next_busy - prev_busy - 1
    keep_idle = (prev_busy >= 0) & (next_busy <= T - 1) & (gap * 1.0 <= b)
    return busy | (~busy & keep_idle)


def provision_cost(
    a: jax.Array, on_matrix: jax.Array, P: float, beta_on: float, beta_off: float
) -> jax.Array:
    """Total cost of a per-level schedule (energy + toggles + forced final off)."""
    energy = P * on_matrix.sum()
    up = jnp.clip(on_matrix[1:].astype(jnp.int32) - on_matrix[:-1].astype(jnp.int32), 0)
    down = jnp.clip(on_matrix[:-1].astype(jnp.int32) - on_matrix[1:].astype(jnp.int32), 0)
    # initial state x(0)=a(0) is free; final forced off to a(T)
    levels = jnp.arange(on_matrix.shape[1])
    init_on = a[0] > levels
    first_turn_on = (on_matrix[0] & ~init_on).sum()
    final_off = (on_matrix[-1] & ~(a[-1] > levels)).sum()
    return (
        energy
        + beta_on * (up.sum() + first_turn_on)
        + beta_off * (down.sum() + final_off)
    )


# ---------------------------------------------------------------------------
# Fleet-scale: shard the level axis over the mesh
# ---------------------------------------------------------------------------

def provision_schedule_sharded(
    mesh: Mesh,
    a: jax.Array,
    *,
    n_levels: int,
    delta: int,
    window: int = 0,
    axis: str = "data",
) -> jax.Array:
    """Same as provision_schedule, levels sharded over ``axis`` via shard_map.

    The demand trace is replicated (tiny); each shard runs its own level
    block; the final x(t) is a psum over shards.  Scales to fleets far past
    one host's memory (1000+ node deployments decide locally, paper Sec. IV).
    """
    size = mesh.shape[axis]
    n_padded = -(-n_levels // size) * size
    per_shard = n_padded // size

    def local(a_local):
        i = jax.lax.axis_index(axis)
        base = i * per_shard
        ons = _level_schedule_offset(a_local, per_shard, base, delta, window)
        x_local = ons.sum(axis=1).astype(jnp.int32)
        return jax.lax.psum(x_local, axis)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=P(),
        out_specs=P(),
    )
    return fn(a)


def _level_schedule_offset(a, n_levels, base, delta, window):
    """A1 level schedule for levels [base, base + n_levels)."""
    T = a.shape[0]
    b = delta
    w = window
    m = max(0.0, b - w - 1)
    horizon = int(min(w + 1, b))
    levels = base + jnp.arange(n_levels)
    pad = jnp.concatenate([a, jnp.zeros((max(horizon, 1),), a.dtype)])

    def step(carry, t):
        r, on = carry
        busy = a[t] > levels
        on = on | busy
        r = jnp.where(busy, 0.0, r)
        idle = on & ~busy
        r = jnp.where(idle, r + 1.0, r)
        fut = jax.lax.dynamic_slice(pad, (t + 1,), (horizon,))
        seen = (fut[None, :] > levels[:, None]).any(axis=1)
        off_now = idle & (r - 1.0 >= m) & ~seen
        on = on & ~off_now
        r = jnp.where(off_now, 0.0, r)
        return (r, on), on

    init = (levels * 0.0, a[0] > levels)
    (_, _), ons = jax.lax.scan(step, init, jnp.arange(T))
    return ons
