"""Training loop substrate."""
