"""Fault-tolerant training loop.

Wires together: step builders (pjit train step with FSDP x TP shardings),
deterministic step-indexed data, async atomic checkpoints with auto-resume,
preemption handling, straggler detection, and optional int8 gradient
compression with error feedback.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.checkpoint import Checkpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.data.tokens import TokenPipeline
from repro.distributed.compression import (
    compress_grads,
    init_error_feedback,
)
from repro.distributed.fault_tolerance import PreemptionGuard, StragglerDetector
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo as zoo
from repro.optim import AdamWConfig, adamw_update, init_adamw
from repro.utils import get_logger

log = get_logger("trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 200
    batch: int = 8
    seq: int = 128
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    grad_compression: bool = False
    model_parallel: int = 1
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, model_cfg: ModelConfig, tcfg: TrainerConfig,
                 hooks: dict[str, Callable] | None = None):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.hooks = hooks or {}
        self.mesh = make_host_mesh(tcfg.model_parallel)
        self.pipeline = TokenPipeline(model_cfg, tcfg.batch, tcfg.seq, tcfg.seed)
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep)
        self.guard = PreemptionGuard()
        self.straggler = StragglerDetector()
        self._build()

    def _build(self) -> None:
        cfg, tcfg = self.model_cfg, self.tcfg

        def train_step(params, opt_state, ef_state, batch):
            def lf(p):
                return zoo.loss_fn(p, cfg, batch)

            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
            cmetrics = {}
            if tcfg.grad_compression:
                grads, ef_state, cmetrics = compress_grads(grads, ef_state)
            params, opt_state, omet = adamw_update(grads, opt_state, params, tcfg.opt)
            return params, opt_state, ef_state, dict(
                metrics, loss=loss, **omet, **cmetrics
            )

        self._step = jax.jit(train_step, donate_argnums=(0, 1, 2))

    def init_state(self):
        params = zoo.init_params(self.model_cfg, jax.random.key(self.tcfg.seed))
        return params, init_adamw(params), init_error_feedback(params)

    def run(self, fail_at_step: int | None = None) -> dict:
        """Train; auto-resumes from the newest checkpoint in ckpt_dir.

        ``fail_at_step`` injects a crash (tests the restart path).
        """
        tcfg = self.tcfg
        self.guard.install()
        params, opt_state, ef_state = self.init_state()
        start = 0
        last = latest_step(tcfg.ckpt_dir)
        if last is not None:
            log.info("resuming from checkpoint step %d", last)
            params, opt_state, ef_state = restore(
                tcfg.ckpt_dir, last, (params, opt_state, ef_state)
            )
            start = last

        history = []
        for step in range(start, tcfg.total_steps):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.monotonic()
            batch = self.pipeline.batch_at(step)
            params, opt_state, ef_state, metrics = self._step(
                params, opt_state, ef_state, batch
            )
            dt = time.monotonic() - t0
            self.straggler.observe(0, dt)
            if (step + 1) % tcfg.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                history.append((step + 1, loss))
                log.info("step %d loss %.4f (%.2fs)", step + 1, loss, dt)
                if "on_log" in self.hooks:
                    self.hooks["on_log"](step + 1, metrics)
            if (step + 1) % tcfg.ckpt_every == 0:
                self.ckpt.save_async(step + 1, (params, opt_state, ef_state),
                                     extra={"loss": float(metrics["loss"])})
            if self.guard.should_stop():
                log.info("preemption requested: checkpointing at step %d", step + 1)
                self.ckpt.wait()
                self.ckpt.save_async(step + 1, (params, opt_state, ef_state))
                break
        self.ckpt.wait()
        final = {
            "params": params,
            "opt_state": opt_state,
            "history": history,
            "final_step": step + 1 if tcfg.total_steps > start else start,
        }
        return final
