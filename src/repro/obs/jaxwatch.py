"""JAX runtime health: compile accounting and profiler hooks.

:class:`CompileWatcher` is the one implementation of the jit-cache-delta
pattern that used to be hand-rolled in three places (the eval harness's
``_engine_cache_size``, ``benchmarks/provision_bench.py``'s cache gates,
and ``benchmarks/cr_eval.py``'s mesh smoke): snapshot the compiled-program
count of a set of jitted functions, run something, and report how many
programs the run added.  The engine's three entrypoints (``_run``,
``_run_noise_sweep``, ``_sharded_grid``) are separate jitted functions
*precisely so* their compiles are observable here.

The count rides JAX's private ``_cache_size`` API; when that API is gone
the watcher degrades exactly like the code it replaced: ``snapshot()``
returns -1 and ``added`` is -1 (callers treat negative as "unobservable",
never as a failure).

Where available, :func:`install_monitoring` additionally forwards JAX's own
``jax.monitoring`` event stream (backend compile durations, tracing events)
into a :class:`~repro.obs.telemetry.Telemetry` registry, and
:func:`profile_to` wraps a region in ``jax.profiler.trace`` — the hook the
benchmark CLIs expose as ``--profile DIR``.
"""
from __future__ import annotations

import contextlib

from .telemetry import Telemetry, get_telemetry


def engine_fns() -> tuple:
    """The provisioning engine's countable jitted entrypoints."""
    from repro.core.jax_provision import _run, _run_noise_sweep, _sharded_grid

    return (_run, _run_noise_sweep, _sharded_grid)


class CompileWatcher:
    """Count compiled-program cache growth across a region.

    ``fns``: the jitted functions to watch (default: the engine's three
    entrypoints).  Use as a context manager::

        with CompileWatcher() as w:
            provision(spec)
        assert w.added == 1          # cold compile; 0 on a warmed re-run

    or imperatively via :meth:`snapshot` deltas.  ``added`` is -1 whenever
    the private ``_cache_size`` API is unavailable on any watched function
    (same contract as the three helpers this class replaced).  On context
    exit the delta is also counted into the active telemetry registry
    (counter ``jax/compiles``) when one is installed.
    """

    def __init__(self, fns=None, telemetry: Telemetry | None = None):
        self.fns = tuple(fns) if fns is not None else engine_fns()
        self.telemetry = telemetry
        self._start: int | None = None
        self.added: int = -1

    @property
    def available(self) -> bool:
        return all(hasattr(f, "_cache_size") for f in self.fns)

    def snapshot(self) -> int:
        """Total compiled-program count over the watched functions, or -1
        if the private JAX cache API is gone."""
        if not self.available:
            return -1
        return sum(f._cache_size() for f in self.fns)

    def __enter__(self) -> "CompileWatcher":
        self._start = self.snapshot()
        return self

    def __exit__(self, *exc) -> bool:
        now = self.snapshot()
        self.added = -1 if (self._start is None or self._start < 0 or now < 0) \
            else now - self._start
        tel = self.telemetry if self.telemetry is not None else get_telemetry()
        if self.added > 0:
            tel.count("jax/compiles", self.added)
        return False


def engine_cache_size() -> int:
    """Compiled-program count across the engine entrypoints (-1 if the
    private JAX cache API is gone) — the drop-in form of the old
    ``repro.eval.harness._engine_cache_size``."""
    return CompileWatcher().snapshot()


_MONITORING_INSTALLED = False


def install_monitoring(telemetry: Telemetry | None = None) -> bool:
    """Forward ``jax.monitoring`` events into telemetry, where available.

    Registers one event listener (→ counter ``jax_event/<name>``) and one
    duration listener (→ histogram ``jax_duration/<name>``, seconds).  The
    listeners read the *active* registry at event time (or the explicit
    ``telemetry``), so a NullTelemetry default keeps them free.  Installs at
    most once per process; returns False when the API is missing.
    """
    global _MONITORING_INSTALLED
    if _MONITORING_INSTALLED:
        return True
    try:
        import jax.monitoring as monitoring

        def _tel() -> Telemetry:
            return telemetry if telemetry is not None else get_telemetry()

        def on_event(name: str, **kw) -> None:
            _tel().count(f"jax_event{name if name.startswith('/') else '/' + name}")

        def on_duration(name: str, secs: float, **kw) -> None:
            _tel().observe(
                f"jax_duration{name if name.startswith('/') else '/' + name}",
                secs,
            )

        monitoring.register_event_listener(on_event)
        monitoring.register_event_duration_secs_listener(on_duration)
    except Exception:
        return False
    _MONITORING_INSTALLED = True
    return True


@contextlib.contextmanager
def profile_to(directory=None):
    """``jax.profiler.trace`` over a region when ``directory`` is set, a
    no-op otherwise — the implementation behind the benchmark CLIs'
    ``--profile DIR`` flag (view the result with TensorBoard's profile
    plugin or Perfetto)."""
    if directory is None:
        yield
        return
    import jax

    with jax.profiler.trace(str(directory)):
        yield
