"""Host-side telemetry: counters, gauges, histograms, and span timers.

One :class:`Telemetry` instance is a process-local registry of metrics plus
a buffer of timing events, exportable two ways:

  * **Chrome trace-event JSON** (:meth:`Telemetry.chrome_trace`) — every
    ``span()`` becomes a complete ("ph": "X") event, loadable in Perfetto
    (https://ui.perfetto.dev) or ``chrome://tracing`` for a flame view of
    where a benchmark's wall time went;
  * **JSON-lines metrics** (:meth:`Telemetry.metrics_records`) — one JSON
    object per counter/gauge/histogram, machine-diffable next to
    ``BENCH_provision.json``.

The process-global default is a :class:`NullTelemetry`: every instrumented
call site reads ``get_telemetry()`` and gets an object whose methods do
nothing, so instrumentation left in library code costs one attribute lookup
and one no-op call when nobody is collecting.  That is the **zero-overhead
contract** (docs/observability.md): telemetry never allocates, never times,
and — crucially — never crosses the jit boundary when disabled.  Spans wrap
*host-side* work (a ``provision`` call, a benchmark cell); in-graph
provenance is :mod:`repro.obs.provenance`'s job.

Enable collection for a region with::

    from repro.obs import Telemetry, telemetry_session

    with telemetry_session() as tel:          # or telemetry_session(Telemetry())
        run_benchmark()
    tel.write_chrome_trace("bench.trace.json")
    tel.write_metrics_jsonl("bench.metrics.jsonl")

Labels: every metric accepts keyword labels (``tel.count("cells", policy="A1")``);
a (name, labels) pair is one series.  All methods are thread-safe.
"""
from __future__ import annotations

import contextlib
import json
import os
import pathlib
import threading
import time


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Telemetry:
    """A live metric registry + trace-event buffer (see module docstring)."""

    enabled: bool = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, list[float]] = {}
        self._events: list[dict] = []
        self._t0_ns = time.perf_counter_ns()

    # ------------------------------------------------------------- metrics
    def count(self, name: str, value: float = 1.0, **labels) -> None:
        """Increment counter ``name`` (monotone; value may be fractional)."""
        k = (name, _label_key(labels))
        with self._lock:
            self._counters[k] = self._counters.get(k, 0.0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        """Set gauge ``name`` to its latest value."""
        with self._lock:
            self._gauges[(name, _label_key(labels))] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one sample into histogram ``name``."""
        k = (name, _label_key(labels))
        with self._lock:
            self._hists.setdefault(k, []).append(float(value))

    def counter_value(self, name: str, **labels) -> float:
        return self._counters.get((name, _label_key(labels)), 0.0)

    def gauge_value(self, name: str, **labels) -> float | None:
        return self._gauges.get((name, _label_key(labels)))

    def samples(self, name: str, **labels) -> list[float]:
        return list(self._hists.get((name, _label_key(labels)), ()))

    def quantile(self, name: str, q: float, **labels) -> float | None:
        """The q-quantile (0..1, nearest-rank) of histogram ``name``."""
        vals = self._hists.get((name, _label_key(labels)))
        if not vals:
            return None
        s = sorted(vals)
        i = min(len(s) - 1, max(0, round(q * (len(s) - 1))))
        return s[i]

    # --------------------------------------------------------------- spans
    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Time a host-side region: a Chrome "X" event + a duration sample.

        The duration (ms) also lands in histogram ``span/<name>``, so p50/
        p99 of a repeated span are one :meth:`quantile` call away.
        """
        ts = self._now_us()
        try:
            yield self
        finally:
            dur = self._now_us() - ts
            ev = {
                "name": name, "ph": "X", "ts": ts, "dur": dur,
                "pid": os.getpid(), "tid": threading.get_ident(),
                "cat": "repro",
            }
            if args:
                ev["args"] = {k: str(v) for k, v in args.items()}
            with self._lock:
                self._events.append(ev)
            self.observe(f"span/{name}", dur / 1e3)

    def instant(self, name: str, **args) -> None:
        """Mark a point in time (Chrome "i" instant event)."""
        ev = {
            "name": name, "ph": "i", "ts": self._now_us(), "s": "p",
            "pid": os.getpid(), "tid": threading.get_ident(), "cat": "repro",
        }
        if args:
            ev["args"] = {k: str(v) for k, v in args.items()}
        with self._lock:
            self._events.append(ev)

    # ------------------------------------------------------------- exports
    def chrome_trace(self) -> dict:
        """The buffered spans as a Chrome trace-event JSON object.

        Loadable as-is in Perfetto / ``chrome://tracing`` (the
        ``traceEvents`` envelope with microsecond timestamps).
        """
        with self._lock:
            events = list(self._events)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.chrome_trace(), indent=1) + "\n")
        return path

    def metrics_records(self) -> list[dict]:
        """One JSON-able record per metric series (counters, gauges, and
        histograms with count/sum/min/max/p50/p99)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: list(v) for k, v in self._hists.items()}
        out: list[dict] = []
        for (name, labels), v in sorted(counters.items()):
            out.append({"type": "counter", "name": name,
                        "labels": dict(labels), "value": v})
        for (name, labels), v in sorted(gauges.items()):
            out.append({"type": "gauge", "name": name,
                        "labels": dict(labels), "value": v})
        for (name, labels), vals in sorted(hists.items()):
            s = sorted(vals)
            out.append({
                "type": "histogram", "name": name, "labels": dict(labels),
                "count": len(s), "sum": sum(s), "min": s[0], "max": s[-1],
                "p50": s[round(0.5 * (len(s) - 1))],
                "p99": s[min(len(s) - 1, round(0.99 * (len(s) - 1)))],
            })
        return out

    def write_metrics_jsonl(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        lines = [json.dumps(r) for r in self.metrics_records()]
        path.write_text("\n".join(lines) + ("\n" if lines else ""))
        return path


@contextlib.contextmanager
def _noop_span(tel):
    yield tel


class NullTelemetry(Telemetry):
    """The disabled default: every method is a no-op and ``span`` neither
    times nor allocates.  Instrumented library code runs against this unless
    a caller installs a live :class:`Telemetry` (``telemetry_session``)."""

    enabled = False

    def __init__(self) -> None:  # no buffers, no lock traffic
        pass

    def count(self, name, value=1.0, **labels):
        pass

    def gauge(self, name, value, **labels):
        pass

    def observe(self, name, value, **labels):
        pass

    def counter_value(self, name, **labels):
        return 0.0

    def gauge_value(self, name, **labels):
        return None

    def samples(self, name, **labels):
        return []

    def quantile(self, name, q, **labels):
        return None

    def span(self, name, **args):
        return _noop_span(self)

    def instant(self, name, **args):
        pass

    def chrome_trace(self):
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def metrics_records(self):
        return []


#: the process-global registry every instrumented call site reads
_ACTIVE: Telemetry = NullTelemetry()


def get_telemetry() -> Telemetry:
    """The active registry (a no-op :class:`NullTelemetry` by default)."""
    return _ACTIVE


def set_telemetry(tel: Telemetry) -> Telemetry:
    """Install ``tel`` as the process-global registry; returns the old one."""
    global _ACTIVE
    old, _ACTIVE = _ACTIVE, tel
    return old


@contextlib.contextmanager
def telemetry_session(tel: Telemetry | None = None):
    """Install a live registry for a ``with`` region, restoring the previous
    one on exit.  ``telemetry_session()`` creates a fresh :class:`Telemetry`."""
    tel = Telemetry() if tel is None else tel
    old = set_telemetry(tel)
    try:
        yield tel
    finally:
        set_telemetry(old)
