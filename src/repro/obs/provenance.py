"""Decision provenance: why each level toggled, as per-slot reason codes.

The paper's algorithms are explainable by construction — every on/off
decision has one local cause — and ``provision(spec,
record_decisions=True)`` carries that cause out of the jitted slot scan as
a per-slot, per-level **bitmask** on ``ProvisionResult.decisions``
(shape ``(..., T, N)``, uint8).  The bits:

======================  =====  =================================================
constant                value  meaning at slot ``t``, level ``l``
======================  =====  =================================================
``DEMAND_RISE``         1      the dispatcher turned the level on: ``a(t) > l``
                               and the level was off entering the slot
``WAIT_EXPIRED``        2      the level is idle and its ski-rental clock has
                               reached its wait (deterministic ``(1−α)Δ_l``
                               timer, or the sampled A2/A3/AQ-rand draw)
``PEEK_FIRED``          4      the clock had expired but the prediction peek
                               saw demand above the level inside
                               ``min(w+1, Δ_l)`` slots, vetoing the power-off
``TOGGLE_OFF``          8      the level powered off this slot (clock expired,
                               nothing seen in the window)
======================  =====  =================================================

``WAIT_EXPIRED`` stays set on every idle slot past the wait, so
``WAIT_EXPIRED & ~(PEEK_FIRED | TOGGLE_OFF)`` never occurs: an expired
clock either fires the peek or fires the toggle.  A slot with code 0 is a
hold (busy-and-on, idle-within-wait, or off).

The codes *reconstruct the schedule exactly* (property-tested): with
``x(0) = min(a(0), N)``,

    ``x(t) = x(0) + Σ_{u<=t} (#DEMAND_RISE(u) − #TOGGLE_OFF(u))``

which is what :func:`reconstruct_schedule` computes and
:func:`toggles_from_decisions` exposes per slot.  The sharded Pallas grid
path records aggregate per-level counters only
(``ProvisionResult.decision_counts``) — see docs/observability.md.

Everything here is plain numpy over host arrays; nothing imports the
engine, so the engine can import these constants without a cycle.
"""
from __future__ import annotations

import numpy as np

#: dispatcher turn-on: demand exceeded the level while it was off
DEMAND_RISE = 1
#: the level's ski-rental clock is at or past its (sampled) wait
WAIT_EXPIRED = 2
#: the prediction peek saw demand inside the window and vetoed the off
PEEK_FIRED = 4
#: the level powered off this slot
TOGGLE_OFF = 8

#: bit value -> human-readable reason name, in priority order
REASON_NAMES = {
    DEMAND_RISE: "demand-rise",
    WAIT_EXPIRED: "wait-expired",
    PEEK_FIRED: "peek-fired",
    TOGGLE_OFF: "toggle-off",
}

#: the order ``decision_counts`` rows are stored in (engine + kernel)
COUNT_ORDER = ("demand_rise", "wait_expired", "peek_fired", "toggle_off")
#: the bit each :data:`COUNT_ORDER` row counts, same order
COUNT_BITS = (DEMAND_RISE, WAIT_EXPIRED, PEEK_FIRED, TOGGLE_OFF)


def toggles_from_decisions(decisions) -> tuple[np.ndarray, np.ndarray]:
    """Per-slot (rises, offs) counts, each ``(..., T)`` int64.

    ``rises[t]`` = number of levels the dispatcher turned on in slot ``t``;
    ``offs[t]`` = number that powered off.  Their running difference is the
    schedule's derivative (see :func:`reconstruct_schedule`).
    """
    d = np.asarray(decisions)
    rises = ((d & DEMAND_RISE) != 0).sum(axis=-1).astype(np.int64)
    offs = ((d & TOGGLE_OFF) != 0).sum(axis=-1).astype(np.int64)
    return rises, offs


def reconstruct_schedule(decisions, x0) -> np.ndarray:
    """Rebuild ``x`` ``(..., T)`` from reason codes and the initial count.

    ``x0`` is the slot-0 *entry* state ``min(a(0), N)`` (broadcastable to
    the leading axes).  Exactness against ``ProvisionResult.x`` is the
    provenance contract: the codes are sufficient statistics for the
    schedule, property-tested in ``tests/test_obs.py``.
    """
    rises, offs = toggles_from_decisions(decisions)
    return np.asarray(x0)[..., None] + np.cumsum(rises - offs, axis=-1)


def decision_counts(decisions) -> dict[str, np.ndarray]:
    """Aggregate per-level reason counters ``{name: (..., N) int32}`` —
    the same four rows, in :data:`COUNT_ORDER`, that the sharded Pallas
    path records natively in ``ProvisionResult.decision_counts``."""
    d = np.asarray(decisions)
    return {
        name: ((d & bit) != 0).sum(axis=-2).astype(np.int32)
        for name, bit in zip(COUNT_ORDER, COUNT_BITS)
    }


def explain_slot(decisions, t: int) -> list[str]:
    """Human-readable event lines for slot ``t`` of a single-trace
    ``(T, N)`` decision matrix — the debugging view of one scheduling step."""
    d = np.asarray(decisions)
    if d.ndim != 2:
        raise ValueError(
            f"explain_slot wants a single-trace (T, N) matrix, got {d.shape}"
        )
    lines = []
    for level in np.flatnonzero(d[t]):
        bits = [name for bit, name in REASON_NAMES.items() if d[t, level] & bit]
        lines.append(f"t={t} level={int(level)}: " + " + ".join(bits))
    return lines
