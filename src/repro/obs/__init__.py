"""repro.obs — telemetry, compile accounting, and decision provenance.

Three small modules, no engine imports at package load:

* :mod:`repro.obs.telemetry` — counters/gauges/histograms + ``span()``
  timers with Chrome-trace and JSON-lines exports; no-op by default.
* :mod:`repro.obs.jaxwatch` — :class:`CompileWatcher` (the one jit-cache
  delta implementation), ``jax.monitoring`` forwarding, ``--profile`` hook.
* :mod:`repro.obs.provenance` — per-slot decision reason-code bitmask
  (demand-rise / wait-expired / peek-fired / toggle-off) and the
  schedule-reconstruction helpers that make the codes checkable.

See docs/observability.md for the full tour and the zero-overhead contract.
"""
from .provenance import (
    COUNT_BITS,
    COUNT_ORDER,
    DEMAND_RISE,
    PEEK_FIRED,
    REASON_NAMES,
    TOGGLE_OFF,
    WAIT_EXPIRED,
    decision_counts,
    explain_slot,
    reconstruct_schedule,
    toggles_from_decisions,
)
from .jaxwatch import (
    CompileWatcher,
    engine_cache_size,
    install_monitoring,
    profile_to,
)
from .telemetry import (
    NullTelemetry,
    Telemetry,
    get_telemetry,
    set_telemetry,
    telemetry_session,
)

__all__ = [
    "COUNT_BITS",
    "COUNT_ORDER",
    "CompileWatcher",
    "DEMAND_RISE",
    "NullTelemetry",
    "PEEK_FIRED",
    "REASON_NAMES",
    "TOGGLE_OFF",
    "Telemetry",
    "WAIT_EXPIRED",
    "decision_counts",
    "engine_cache_size",
    "explain_slot",
    "get_telemetry",
    "install_monitoring",
    "profile_to",
    "reconstruct_schedule",
    "set_telemetry",
    "telemetry_session",
    "toggles_from_decisions",
]
