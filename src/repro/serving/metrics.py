"""Serving-side plan metrics with a Prometheus text exporter.

:class:`PlanMetrics` is the operational counterpart of the eval report's
runtime columns: every :meth:`FleetProvisioner.advance()
<repro.serving.autoscaler.FleetProvisioner.advance>` step records how long
the stepper took, how many replica toggles the new plan carries over the
chunk, and the queue backlog depth — the three signals an operator
watches on a streaming capacity planner (plan latency must stay inside
the slot, toggle churn is the paper's cost being spent, backlog depth is
the deferral queue's health).

Exports: Python-side accessors (``latency_quantile(0.99)``, ``.toggles``,
``.backlog_depth``) plus :meth:`PlanMetrics.prometheus_text` — the
Prometheus `text exposition format
<https://prometheus.io/docs/instrumenting/exposition_formats/>`__, ready to
serve from a ``/metrics`` endpoint (summary with p50/p99 quantile labels
for latency, counters for plans/toggles, a gauge for backlog).  Metrics
also mirror into the active :mod:`repro.obs.telemetry` registry when one is
installed, so a benchmark's Chrome trace and a serving loop's Prometheus
scrape come from the same instrumentation.
"""
from __future__ import annotations

import dataclasses

from repro.obs.telemetry import get_telemetry

#: latency quantiles the Prometheus summary exports
_QUANTILES = (0.5, 0.99)


@dataclasses.dataclass
class PlanMetrics:
    """Rolling metrics of one :class:`FleetProvisioner`'s advance() loop.

    ``plans``: advance() calls observed.  ``plan_latencies_ms``: one wall
    sample per call (device compute + host dispatch).  ``toggles``:
    cumulative replica on/off transitions the returned chunk plans
    (``sum(|Δx|)`` within the chunk plus the seam from the previous
    chunk's last slot).  ``backlog_depth``: the queue depth after the last
    planned slot (0 without a deferral spec); ``peak_backlog`` its high
    water mark.
    """

    plans: int = 0
    toggles: int = 0
    backlog_depth: int = 0
    peak_backlog: int = 0
    plan_latencies_ms: list[float] = dataclasses.field(default_factory=list)

    def observe_plan(self, latency_ms: float, toggles: int, backlog: int) -> None:
        """Record one advance() step (called by the planner)."""
        self.plans += 1
        self.plan_latencies_ms.append(float(latency_ms))
        self.toggles += int(toggles)
        self.backlog_depth = int(backlog)
        self.peak_backlog = max(self.peak_backlog, int(backlog))
        tel = get_telemetry()
        tel.observe("serving/plan_latency_ms", float(latency_ms))
        tel.count("serving/toggles", int(toggles))
        tel.gauge("serving/backlog_depth", int(backlog))

    def latency_quantile(self, q: float) -> float | None:
        """Nearest-rank q-quantile (0..1) of the plan latencies, ms."""
        if not self.plan_latencies_ms:
            return None
        s = sorted(self.plan_latencies_ms)
        return s[min(len(s) - 1, max(0, round(q * (len(s) - 1))))]

    def prometheus_text(self, prefix: str = "repro_serving") -> str:
        """The metrics in Prometheus text exposition format.

        A summary (``<prefix>_plan_latency_ms`` with p50/p99 quantile
        labels, ``_sum``/``_count``), counters for plans and toggles, and
        gauges for the current and peak backlog depth.
        """
        lat = self.plan_latencies_ms
        lines = [
            f"# HELP {prefix}_plan_latency_ms Wall time of one advance() step.",
            f"# TYPE {prefix}_plan_latency_ms summary",
        ]
        for q in _QUANTILES:
            v = self.latency_quantile(q)
            if v is not None:
                lines.append(
                    f'{prefix}_plan_latency_ms{{quantile="{q}"}} {v:.6f}'
                )
        lines += [
            f"{prefix}_plan_latency_ms_sum {sum(lat):.6f}",
            f"{prefix}_plan_latency_ms_count {len(lat)}",
            f"# HELP {prefix}_plans_total advance() calls observed.",
            f"# TYPE {prefix}_plans_total counter",
            f"{prefix}_plans_total {self.plans}",
            f"# HELP {prefix}_toggles_total Replica on/off transitions planned.",
            f"# TYPE {prefix}_toggles_total counter",
            f"{prefix}_toggles_total {self.toggles}",
            f"# HELP {prefix}_backlog_depth Queued work after the last planned slot.",
            f"# TYPE {prefix}_backlog_depth gauge",
            f"{prefix}_backlog_depth {self.backlog_depth}",
            f"# HELP {prefix}_backlog_peak High-water mark of the backlog depth.",
            f"# TYPE {prefix}_backlog_peak gauge",
            f"{prefix}_backlog_peak {self.peak_backlog}",
        ]
        return "\n".join(lines) + "\n"
