"""Serving: per-replica engines + the paper's autoscaler + cluster simulation.

``FleetProvisioner.advance()`` streams: the O(1)-state incremental stepper
behind it (engine carry, pow2 chunk buckets, slot-indexed PRNG) lives in
:mod:`repro.serving.stepper` and is exported here for direct use.
"""
from .autoscaler import (
    FleetProvisioner,
    ReplicaAutoscaler,
    ScalerReport,
    replica_cost_model,
)
from .cluster import ClusterReport, make_window_max_predictor, run_cluster
from .engine import GenerationResult, InferenceEngine
from .metrics import PlanMetrics
from .stepper import StepperState, pow2_bucket, stepper_chunk, stepper_init

__all__ = [
    "FleetProvisioner",
    "PlanMetrics",
    "ReplicaAutoscaler",
    "ScalerReport",
    "StepperState",
    "pow2_bucket",
    "replica_cost_model",
    "stepper_chunk",
    "stepper_init",
    "ClusterReport",
    "make_window_max_predictor",
    "run_cluster",
    "GenerationResult",
    "InferenceEngine",
]
