"""Serving: per-replica engines + the paper's autoscaler + cluster simulation."""
from .autoscaler import (
    FleetProvisioner,
    ReplicaAutoscaler,
    ScalerReport,
    replica_cost_model,
)
from .cluster import ClusterReport, make_window_max_predictor, run_cluster
from .engine import GenerationResult, InferenceEngine
from .metrics import PlanMetrics

__all__ = [
    "FleetProvisioner",
    "PlanMetrics",
    "ReplicaAutoscaler",
    "ScalerReport",
    "replica_cost_model",
    "ClusterReport",
    "make_window_max_predictor",
    "run_cluster",
    "GenerationResult",
    "InferenceEngine",
]
