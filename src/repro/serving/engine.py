"""Per-replica inference engine: prefill + decode with a slot-based cache.

One engine == one replica (a mesh slice in production; the whole host mesh in
local runs).  Sessions are admitted in rolling batches and decoded in
lockstep; the cluster layer (and the paper's autoscaler) handles everything
across replicas.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_fn, init_cache, prefill_fn


# host-side decode output, never crosses into jit
@dataclasses.dataclass
class GenerationResult:  # repro-lint: disable=RPL005
    tokens: np.ndarray          # (B, n_new)
    prefill_len: int


class InferenceEngine:
    """Greedy-decoding engine for a (reduced) model on the local backend."""

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b, c: prefill_fn(p, cfg, b, c)
        )
        self._decode = jax.jit(
            lambda p, t, n, c: decode_fn(p, cfg, t, n, c)
        )

    def generate(self, tokens: np.ndarray, n_new: int) -> GenerationResult:
        """tokens: (B, S_prompt) int32. Greedy-decodes n_new tokens."""
        B, S = tokens.shape
        assert B <= self.max_batch and S + n_new <= self.max_seq
        cache = init_cache(self.cfg, B, self.max_seq, src_len=S)
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if self.cfg.frontend == "vision_stub":
            nf = self.cfg.n_frontend_tokens
            batch["frontend"] = jnp.zeros((B, nf, self.cfg.d_model), jnp.bfloat16)
        elif self.cfg.frontend == "audio_stub":
            batch["frontend"] = jnp.zeros((B, S, self.cfg.d_model), jnp.bfloat16)
        logits, cache = self._prefill(self.params, batch, cache)
        prefix = S + (
            self.cfg.n_frontend_tokens if self.cfg.frontend == "vision_stub" else 0
        )
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
        for i in range(n_new - 1):
            logits, cache = self._decode(
                self.params, tok, jnp.int32(prefix + i), cache
            )
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok))
        return GenerationResult(tokens=np.stack(out, axis=1), prefill_len=S)
