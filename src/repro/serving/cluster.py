"""Serving-cluster simulation: session trace -> autoscaler (+ real engines).

Replays a :class:`SessionTrace` against the paper-driven autoscaler and
reports energy vs the static-provisioning benchmark (paper Sec. V-A).  When
an ``engine_factory`` is supplied, arriving sessions run real prefill+decode
on their pinned replica, demonstrating the end-to-end path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.costs import CostModel
from repro.data.requests import SessionTrace
from .autoscaler import ReplicaAutoscaler, ScalerReport


@dataclasses.dataclass
class ClusterReport:
    scaler: ScalerReport
    total_cost: float
    static_cost: float
    reduction: float
    peak_concurrency: int
    sessions_served: int
    tokens_generated: int = 0


def make_window_max_predictor(trace: SessionTrace, noise_std_frac: float = 0.0,
                              rng: np.random.Generator | None = None):
    """Max concurrency over (t0, t1] from the (optionally noised) true trace."""
    brick = trace.to_brick()
    times, vals = brick.a_breakpoints()
    times = np.asarray(times)
    vals = np.asarray(vals, dtype=np.float64)
    rng = rng or np.random.default_rng(0)

    def predictor(t0: float, t1: float) -> float:
        lo = np.searchsorted(times, t0, side="right") - 1
        hi = np.searchsorted(times, t1, side="right")
        window = vals[max(lo, 0):hi]
        if window.size == 0:
            return 0.0
        m = float(window.max())
        if noise_std_frac > 0.0:
            m = max(0.0, m + rng.standard_normal() * noise_std_frac * m)
        return m

    return predictor


def run_cluster(
    trace: SessionTrace,
    costs: CostModel,
    policy: str = "A1",
    alpha: float = 0.0,
    predictor=None,
    engine_factory: Callable[[], object] | None = None,
    rng: np.random.Generator | None = None,
) -> ClusterReport:
    rng = rng or np.random.default_rng(0)
    brick = trace.to_brick()
    peak = brick.max_concurrency()
    n_replicas = peak + 2

    scaler = ReplicaAutoscaler(
        n_replicas, costs, policy=policy, alpha=alpha,
        predictor=predictor, rng=rng, initial_busy=brick.initial_count(),
    )

    # engines are created lazily per replica (weights load == beta_on)
    engines: dict[int, object] = {}
    tokens_generated = 0

    events = []
    for s in trace.sessions:
        events.append((s.arrival, 0, "arrive", s))
        events.append((s.departure, 1, "depart", s))
    events.sort(key=lambda e: (e[0], e[1]))

    session_replica: dict[int, int] = {}
    for t, _, kind, s in events:
        if kind == "arrive":
            rid = scaler.acquire(t)
            session_replica[s.session_id] = rid
            if engine_factory is not None:
                if rid not in engines:
                    engines[rid] = engine_factory()
                eng = engines[rid]
                prompt = np.asarray(
                    rng.integers(0, eng.cfg.vocab_size, (1, min(s.prompt_tokens, 32))),
                    np.int32,
                )
                res = eng.generate(prompt, n_new=min(s.max_new_tokens, 16))
                tokens_generated += res.tokens.size
        else:
            rid = session_replica.pop(s.session_id)
            scaler.release(t, rid)

    report = scaler.finalize(brick.horizon)
    total = report.total_cost(costs)
    static = costs.P * peak * brick.horizon
    return ClusterReport(
        scaler=report,
        total_cost=total,
        static_cost=static,
        reduction=1.0 - total / static,
        peak_concurrency=peak,
        sessions_served=len(trace.sessions),
        tokens_generated=tokens_generated,
    )
