"""Replica autoscaler — the paper's technique as a first-class serving feature.

Maps the paper's algorithms onto model-serving replicas:

  * last-empty-server-first  ->  last-empty-REPLICA-first (LIFO stack);
    a session is pinned to its replica for its whole lifetime, so the
    no-job-migration property becomes a no-KV-cache-migration property.
  * per-server ski-rental    ->  each idle replica independently decides
    off-vs-idle after (1-alpha)*Delta (A1) or a randomized wait (A2/A3),
    peeking an alpha*Delta prediction window.
  * the peek uses only the LIFO structure: a replica at stack depth p is
    popped iff predicted concurrency exceeds busy_now + p (paper Sec. IV-B).

Two front-ends share the math:

  * :class:`ReplicaAutoscaler` — event-driven, reacts live to session
    arrivals/departures (the serving cluster's control loop);
  * :class:`FleetProvisioner` — slot-based capacity planning on the batched
    jitted engine (:mod:`repro.core.jax_provision`): many fleets' demand
    traces, any policy, and a whole α-sweep evaluate as one device program.

Delta = (beta_on + beta_off)/P with beta_on the replica spin-up cost
(weight load + compile, amortized) — see ``replica_cost_model``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable

import numpy as np

from repro.core.costs import CostModel
from repro.core.ski_rental import (
    A1Deterministic,
    A2Randomized,
    A3Randomized,
    OfflinePolicy,
)

POLICIES = {
    "A1": A1Deterministic,
    "A2": A2Randomized,
    "A3": A3Randomized,
    "offline": OfflinePolicy,
}


def _policy_class(policy: str):
    if policy not in POLICIES:
        raise ValueError(
            f"unknown policy {policy!r}: valid policies are {tuple(POLICIES)}"
        )
    return POLICIES[policy]


@dataclasses.dataclass
class ReplicaState:
    replica_id: int
    state: str = "off"            # off | idle | busy
    since: float = 0.0            # time of last state change
    session: int | None = None


@dataclasses.dataclass
class ScalerReport:
    energy: float = 0.0
    n_turn_on: int = 0
    n_turn_off: int = 0
    busy_time: float = 0.0
    idle_time: float = 0.0

    def total_cost(self, costs: CostModel) -> float:
        return (
            self.energy
            + costs.beta_on * self.n_turn_on
            + costs.beta_off * self.n_turn_off
        )


class ReplicaAutoscaler:
    """Event-driven live autoscaler (no future knowledge beyond the window)."""

    def __init__(
        self,
        n_replicas: int,
        costs: CostModel,
        policy: str = "A1",
        alpha: float = 0.0,
        predictor: Callable[[float, float], float] | None = None,
        rng: np.random.Generator | None = None,
        initial_busy: int = 0,
    ):
        self.costs = costs
        self.policy = _policy_class(policy)(alpha=alpha)
        self.alpha = alpha
        self.predictor = predictor            # (t0, t1) -> max predicted load
        self.rng = rng or np.random.default_rng(0)
        self.replicas = [ReplicaState(i) for i in range(n_replicas)]
        # stack of replica ids (idle or off); bottom..top
        self.stack: list[int] = list(range(n_replicas - 1, initial_busy - 1, -1))
        for i in range(initial_busy):
            self.replicas[i].state = "busy"
        self.busy: set[int] = set(range(initial_busy))
        self.report = ScalerReport()
        self._timers: list[tuple[float, int, int]] = []   # (deadline, seq, rid)
        self._seq = 0
        self._timer_valid: dict[int, float] = {}

    # ------------------------------------------------------------------ events
    def acquire(self, t: float) -> int:
        """Session start: pop the last-empty replica (LIFO)."""
        self.advance(t)
        rid = self.stack.pop()
        r = self.replicas[rid]
        if r.state == "idle":
            self.report.energy += self.costs.P * (t - r.since)
            self.report.idle_time += t - r.since
        else:  # off -> on
            self.report.n_turn_on += 1
        r.state = "busy"
        r.since = t
        self.busy.add(rid)
        self._timer_valid.pop(rid, None)
        return rid

    def release(self, t: float, rid: int) -> None:
        """Session end: push the replica; start its ski-rental clock."""
        self.advance(t)
        r = self.replicas[rid]
        self.report.energy += self.costs.P * (t - r.since)
        self.report.busy_time += t - r.since
        self.busy.discard(rid)
        r.state = "idle"
        r.since = t
        self.stack.append(rid)
        wait = self.policy.wait_time(self.costs.delta, self.rng)
        if isinstance(self.policy, OfflinePolicy):
            wait = 0.0
        deadline = t + wait
        self._seq += 1
        self._timer_valid[rid] = deadline
        heapq.heappush(self._timers, (deadline, self._seq, rid))

    def advance(self, t: float) -> None:
        """Fire all ski-rental decisions due at or before time t."""
        while self._timers and self._timers[0][0] <= t:
            deadline, _, rid = heapq.heappop(self._timers)
            if self._timer_valid.get(rid) != deadline:
                continue
            del self._timer_valid[rid]
            r = self.replicas[rid]
            if r.state != "idle":
                continue
            if not self._predicted_pop(rid, deadline):
                # turn off
                self.report.energy += self.costs.P * (deadline - r.since)
                self.report.idle_time += deadline - r.since
                r.state = "off"
                r.since = deadline
                self.report.n_turn_off += 1
            # else: stay idle until popped

    def finalize(self, t_end: float) -> ScalerReport:
        """Horizon end: x(T) = a(T) — force idle replicas off."""
        self.advance(t_end)
        for r in self.replicas:
            if r.state == "idle":
                self.report.energy += self.costs.P * (t_end - r.since)
                self.report.idle_time += t_end - r.since
                r.state = "off"
                self.report.n_turn_off += 1
            elif r.state == "busy":
                self.report.energy += self.costs.P * (t_end - r.since)
                self.report.busy_time += t_end - r.since
                r.since = t_end
        return self.report

    # ------------------------------------------------------------------ peek
    def _stack_depth(self, rid: int) -> int:
        """0 = top of stack."""
        return len(self.stack) - 1 - self.stack.index(rid)

    def _predicted_pop(self, rid: int, t: float) -> bool:
        """Will this replica be popped within (t, t + alpha*Delta]?

        Under LIFO the replica at depth p is popped iff concurrency exceeds
        busy_now + p within the window.
        """
        if self.predictor is None or self.alpha <= 0.0:
            return False
        if rid not in self.stack:
            return False
        window_end = t + self.alpha * self.costs.delta
        predicted_max = self.predictor(t, window_end)
        threshold = len(self.busy) + self._stack_depth(rid) + 1
        return predicted_max >= threshold

    def n_on(self) -> int:
        return sum(1 for r in self.replicas if r.state != "off")


class FleetProvisioner:
    """Slot-based capacity planner on the declarative provisioning engine.

    Where :class:`ReplicaAutoscaler` reacts to one fleet's live events, this
    planner takes per-slot (predicted) session concurrency for B fleets at
    once — shape ``(T,)`` or ``(B, T)`` — and runs a
    :class:`repro.core.ProvisionSpec` over it, entirely on-device.  The
    ``policy`` argument is a :class:`repro.core.PolicySpec` (or a policy
    name, sugar for ``PolicySpec(name, window=window, key=key)``);
    heterogeneous per-replica cost models are plain ``(max_replicas,)``
    arrays on ``costs``.  ``plan_sweep``/``sweep_costs`` evaluate every
    prediction window in one program, which is how an operator picks α for
    a fleet (paper Fig. 4b as a planning tool).  ``mesh=`` shards the
    replica axis through the fused Pallas grid scan — batched demand and
    windows sweeps ride along (one kernel program per (window, trace) cell,
    bit-exact against the unsharded engine).  Randomized policies need an
    explicit PRNG ``key``.

    Typed fleets plug straight in: build ``costs`` with
    ``CostModel.from_groups(ServerGroup(...), ...)`` — e.g. one group per
    accelerator generation — and the fleet size defaults to the model's
    pinned capacity, ``plan(...).group_cost`` breaks the spend down per
    replica type, and the Albers–Quedenfeld ``AQ-det``/``AQ-rand`` policies
    become available alongside the paper's A1/A2/A3.

    ``deferral=`` (a :class:`repro.deferral.DeferralSpec`) marks the
    sessions as deferrable: the planner water-fills arrivals within their
    slack before provisioning, so bursts are absorbed by the queue instead
    of replica toggles, and every plan carries queue metrics
    (``plan(...).p99_delay`` etc.).  The spec's service cap defaults to the
    fleet size — demand above ``max_replicas`` re-enters the backlog
    rather than being rejected.
    """

    def __init__(
        self,
        costs: CostModel,
        policy="A1",
        window: int = 0,
        max_replicas: int | None = None,
        key=None,
        mesh=None,
        mesh_axis: str = "data",
        deferral=None,
    ):
        from repro.core import PolicySpec

        self.costs = costs
        if isinstance(policy, PolicySpec):
            if window != 0 or key is not None:
                raise ValueError(
                    "pass window/key inside the PolicySpec, not alongside it"
                )
            self.policy = policy
        else:
            self.policy = PolicySpec(name=policy, window=int(window), key=key)
        self.policy.validate()
        costs.validate_groups()
        pinned = costs.n_levels
        if max_replicas is None:
            # a level-pinned model (per-replica arrays or typed groups) IS
            # the fleet size; scalar models fall back to a planning cap
            max_replicas = 1024 if pinned is None else pinned
        elif pinned is not None and int(max_replicas) != pinned:
            raise ValueError(
                f"max_replicas={max_replicas} conflicts with the cost "
                f"model's pinned fleet size {pinned}; drop max_replicas "
                "(it defaults to the pinned size)"
            )
        self.max_replicas = int(max_replicas)
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        if deferral is not None:
            if deferral.cap is None:
                deferral = dataclasses.replace(deferral, cap=self.max_replicas)
            deferral.validate()
        self.deferral = deferral
        self._history = np.zeros(0, np.int64)
        self.last_plan = None
        #: the advance() stepper's carry (:class:`repro.serving.stepper.
        #: StepperState`); None until the first advance() call
        self.state = None
        self._prev_x = None
        from .metrics import PlanMetrics

        #: rolling advance() health: plan-latency p50/p99, toggle churn,
        #: backlog depth — export with ``self.metrics.prometheus_text()``
        self.metrics = PlanMetrics()

    def _spec(self, demand, predicted=None, windows=None):
        import dataclasses as _dc

        from repro.core import ProvisionSpec, Workload

        policy = self.policy
        if windows is not None:
            policy = _dc.replace(policy, windows=np.asarray(windows, np.int32))
        return ProvisionSpec(
            costs=self.costs,
            workload=Workload(
                demand=self._as_i32(demand),
                predicted=None if predicted is None else self._as_i32(predicted),
                deferral=self.deferral,
            ),
            policy=policy,
            n_levels=self.max_replicas,
            mesh=self.mesh,
            mesh_axis=self.mesh_axis,
        )

    def plan(self, demand, predicted=None):
        """Full ProvisionResult; ``.x`` is (T,) -> (T,) or (B, T) -> (B, T)."""
        from repro.core import provision

        if self.policy.windows is not None:
            raise ValueError(
                "the planner's PolicySpec carries a windows= sweep; "
                "plan() returns per-window-free shapes — use plan_sweep()/"
                "sweep_costs(), or drop windows from the PolicySpec"
            )
        return provision(self._spec(demand, predicted))

    def plan_sweep(self, demand, windows) -> np.ndarray:
        """x over an α-sweep: (W, T) or (W, B, T) for windows (W,)."""
        from repro.core import provision

        return np.asarray(provision(self._spec(demand, windows=windows)).x)

    def sweep_costs(self, demand, windows) -> np.ndarray:
        """Schedule costs over an α-sweep: (W,) or (W, B)."""
        from repro.core import provision

        return np.asarray(provision(self._spec(demand, windows=windows)).cost)

    def advance(self, demand_chunk) -> np.ndarray:
        """Commit the next chunk of per-slot demand; return its replica plan.

        A *true incremental stepper*: the per-level engine state
        (ski-rental clocks, on bits, residual waits), the causal deferral
        window and the queue's age buckets persist on ``self.state``
        (:class:`~repro.serving.stepper.StepperState`), so each call costs
        O(chunk · replicas) regardless of how long the fleet has been
        running — no history is re-planned, and every returned slot is
        final (*commit-as-returned*; the no-peek policies are exactly
        chunk-size invariant, the peeking ones read the window within the
        chunk only — docs/provisioning_engine.md "Streaming & long
        traces").  Chunks are padded to power-of-two buckets
        (:func:`~repro.serving.stepper.pow2_bucket`) with the tail masked
        as jit data, so steady-state serving does **zero** recompiles
        across any mix of chunk sizes inside a warmed bucket.

        Deferral follows the causal :func:`repro.deferral.defer_stream`
        rule (an honest online semantics — the batch planner's OA
        water-filling is anticipative; see docs/deferral.md) and requires
        scalar slack.  Randomized policies draw waits from the
        slot-indexed stream ``fold_in(key, global_slot)`` — reproducible
        and chunk-size invariant, but a different stream than ``plan()``'s
        per-trace tables.

        ``self.last_plan`` carries the chunk's view as a
        :class:`~repro.core.ProvisionResult`: ``x``/``backlog`` cover the
        chunk, the cost fields are chunk-local (toggle edges against the
        carried state; no forced final-off — the trace has not ended),
        and the queue scalars (``deadline_misses``/``unserved``/delay
        quantiles) are *cumulative since the first call*.  Every step
        records plan latency, toggles (including the seam from the
        previous chunk) and backlog depth into ``self.metrics``.
        """
        import time

        import jax.numpy as jnp

        from repro.core import ProvisionResult
        from repro.deferral import (
            defer_stream,
            queue_stream,
            queue_stream_finalize,
        )
        from repro.obs.telemetry import get_telemetry
        from .stepper import pow2_bucket, stepper_chunk, stepper_init

        chunk = np.asarray(demand_chunk, np.int64)
        if chunk.ndim != 1:
            raise ValueError(
                f"advance() steps one fleet: demand_chunk must be (T,), "
                f"got shape {chunk.shape}"
            )
        if chunk.size == 0:
            raise ValueError("advance() needs at least one demand slot")
        if self.policy.name == "offline":
            raise ValueError(
                "advance() steps online policies; 'offline' needs the whole "
                "trace in hindsight — use plan()"
            )
        if self.policy.windows is not None:
            raise ValueError(
                "the planner's PolicySpec carries a windows= sweep; advance() "
                "steps a single window — use plan_sweep()/sweep_costs(), or "
                "drop windows from the PolicySpec"
            )
        if self.deferral is not None and np.ndim(self.deferral.slack) != 0:
            raise ValueError(
                "advance() streams with scalar slack only (a per-slot slack "
                "vector is tied to one fixed horizon) — use plan()"
            )
        arrivals = self._as_i32(chunk)
        n = chunk.size
        max_h = self.costs.delta_slots()
        delta_lv = jnp.broadcast_to(
            jnp.asarray(self.costs.delta, jnp.float32), (self.max_replicas,)
        )
        if self.state is None:
            self.state = stepper_init(
                self.max_replicas, delta_lv, policy=self.policy.name,
                window=self.policy.window, deferral=self.deferral,
            )
        st = self.state
        t_pad = pow2_bucket(n)
        pad = np.zeros(t_pad, np.int32)
        valid = np.arange(t_pad) < n

        with get_telemetry().span("serving/advance", chunk=n, t_pad=t_pad,
                                  t0=st.t):
            t_wall = time.perf_counter()
            if self.deferral is None:
                served, defer_c = arrivals, None
            else:
                apad = jnp.asarray(
                    np.concatenate([np.asarray(arrivals), pad[n:]]))
                served_pad, defer_c = defer_stream(
                    apad, st.defer, slack=self.deferral.bound(),
                    cap=self.deferral.cap, valid=jnp.asarray(valid),
                )
                served = served_pad[:n]
            a_pad = jnp.asarray(
                np.concatenate([np.asarray(served, np.int32), pad[n:]]))
            x_pad, (r, on, wait), totals = stepper_chunk(
                a_pad, jnp.int32(n), jnp.int32(st.t), self.policy.key,
                st.r, st.on, st.wait, delta_lv,
                policy=self.policy.name, n_levels=self.max_replicas,
                max_h=max_h, window=self.policy.window, t_pad=t_pad,
            )
            x = np.asarray(x_pad)[:n]
            queue_c, backlog, qsnap = None, None, {}
            if self.deferral is not None:
                xq = jnp.asarray(np.concatenate([x.astype(np.int32), pad[n:]]))
                backlog_pad, queue_c = queue_stream(
                    apad, xq, st.queue, rule=self.deferral.rule,
                    max_slack=self.deferral.bound(), valid=jnp.asarray(valid),
                )
                backlog = jnp.asarray(backlog_pad)[:n]
                qsnap = queue_stream_finalize(
                    queue_c, max_slack=self.deferral.bound())
            latency_ms = (time.perf_counter() - t_wall) * 1e3

        self.state = dataclasses.replace(
            st, t=st.t + n, r=r, on=on, wait=wait,
            defer=defer_c, queue=queue_c,
        )
        self._history = np.concatenate([self._history, chunk])
        P_lv, bon_lv, boff_lv = self.costs.per_level(self.max_replicas)
        level_cost = (
            P_lv * totals["run"] + bon_lv * totals["up"]
            + boff_lv * totals["down"]
        )
        self.last_plan = ProvisionResult(
            x=jnp.asarray(x),
            cost=level_cost.sum(),
            energy=(P_lv * totals["run"]).sum(),
            toggle_cost=(
                bon_lv * totals["up"] + boff_lv * totals["down"]
            ).sum(),
            level_cost=level_cost,
            group_cost=(
                None if self.costs.group_sizes is None
                else self.costs.group_reduce(level_cost)
            ),
            backlog=backlog,
            max_delay=qsnap.get("max_delay"),
            p99_delay=qsnap.get("p99_delay"),
            deadline_misses=qsnap.get("deadline_misses"),
            unserved=qsnap.get("unserved"),
        )
        toggles = int(np.abs(np.diff(x)).sum())
        if self._prev_x is not None:
            toggles += abs(int(x[0]) - self._prev_x)    # seam between chunks
        self._prev_x = int(x[-1])
        self.metrics.observe_plan(
            latency_ms, toggles,
            0 if backlog is None else int(np.asarray(backlog)[-1]),
        )
        return x

    def reset(self) -> None:
        """Drop the advance() carry and history — the next call starts a
        fresh trace (compiled steps stay warm; state is data)."""
        self.state = None
        self._prev_x = None
        self._history = np.zeros(0, np.int64)
        self.last_plan = None

    def _as_i32(self, demand):
        import jax.numpy as jnp

        a = jnp.asarray(np.asarray(demand), jnp.int32)
        peak = int(np.asarray(demand).max())
        if peak > self.max_replicas and self.deferral is None:
            # with a deferral spec the service cap (== the fleet size by
            # default) absorbs the excess into the backlog instead
            raise ValueError(f"demand peak {peak} exceeds max_replicas {self.max_replicas}")
        return a


def replica_cost_model(
    weights_bytes_per_device: float,
    n_chips: int,
    idle_power_w: float = 120.0,
    peak_power_w: float = 250.0,
    hbm_bw: float = 819e9,
    compile_s: float = 30.0,
    slot_s: float = 600.0,
) -> CostModel:
    """Derive the paper's (P, beta) constants for one model replica.

    beta_on ~ energy of the spin-up: weight load (HBM-bandwidth bound) +
    compile/warmup at peak power; beta_off ~ drain at idle power.  P = idle
    power per slot (serving energy is charged to sessions either way).
    Units: energy per slot (slot_s seconds).
    """
    load_s = weights_bytes_per_device / hbm_bw + compile_s
    beta_on = n_chips * peak_power_w * load_s / (idle_power_w * slot_s)
    beta_off = n_chips * idle_power_w * 0.25 * compile_s / (idle_power_w * slot_s)
    # normalize so P = 1 per slot per replica
    return CostModel(P=1.0, beta_on=beta_on / n_chips, beta_off=max(beta_off / n_chips, 0.1))
