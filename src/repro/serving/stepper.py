"""The O(1)-state incremental stepper behind ``FleetProvisioner.advance()``.

The monolithic planner re-ran a trailing ``chunk + 3Δ + slack`` window of
history on every call — O(history) work per step and a fresh jit trace per
chunk shape.  This module replaces that with a *true* stepper: the
per-level ski-rental engine state (idle-run clocks, on bits, residual wait
thresholds), the causal deferral window and the queue's age buckets are
carried across calls as an explicit :class:`StepperState`, so each
``advance(chunk)`` costs O(chunk · levels) regardless of how long the
fleet has been running — the memoryless structure the paper's algorithms
have by construction (and what makes them practical at data-center scale,
arXiv 2108.09489 / 2107.14672).

Semantics — *commit-as-returned*:

* every slot's decision is final the moment ``advance`` returns it;
  nothing is replanned when more demand arrives.  The no-peek policies
  (``delayedoff``/``AQ-det``/``AQ-rand``) are therefore **chunk-size
  invariant** — any split of the demand stream yields the identical
  schedule.  Peeking policies read the prediction window *within* the
  chunk only (the future past the chunk boundary has not been observed
  yet, so the peek sees quiet) — at ``T_chunk = 1`` they degrade to their
  no-peek behaviour, which is the honest online semantics of a window the
  operator cannot actually see.
* randomized policies draw each level's wait from
  ``fold_in(key, global_slot)`` at the slot the level goes idle — a
  *slot-indexed* stream, so schedules are chunk-size invariant and
  reproducible from ``(key, demand stream)`` alone.  This is deliberately
  a different stream than the batch planner's per-trace uniform tables
  (those need ``T`` up front, which a stepper never has).
* deferral uses the **causal** :func:`repro.deferral.defer_stream` rule,
  not the batch path's anticipative OA water-filling (docs/deferral.md);
  queue metrics accumulate across calls through
  :func:`repro.deferral.queue_stream`.

Zero steady-state recompiles: chunks are padded to power-of-two buckets
(:func:`pow2_bucket`, tail masked by an ``n_valid`` operand that is jit
*data*), so any mix of chunk sizes within a warmed bucket reuses the
compiled step — gated by a compile-count test in tests/test_streaming.py.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.jax_provision import (
    KEYED,
    NO_PEEK,
    _slot_update,
    _waits_from_uniforms,
)
from repro.deferral import defer_stream_init, queue_stream_init

#: smallest chunk bucket — sub-8-slot chunks share one compiled step
MIN_BUCKET = 8


def pow2_bucket(n: int) -> int:
    """Smallest power-of-two ≥ ``n`` (floored at :data:`MIN_BUCKET`): the
    padded slot count one compiled step serves.  Steady-state serving with
    any chunk-size mix inside a bucket costs zero recompiles."""
    return max(MIN_BUCKET, 1 << (int(n) - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class StepperState:
    """Everything ``advance()`` carries between calls — O(levels + slack).

    ``t``: global slot counter (how many slots have been committed).
    ``r``/``on``/``wait``: the per-level engine carry — idle-run clocks,
    on bits, residual wait thresholds — exactly the state the streaming
    kernel chains on.  ``defer``/``queue``: the causal-deferral and
    age-bucket queue carries (None when the planner has no deferral spec).
    """

    t: int
    r: jax.Array
    on: jax.Array
    wait: jax.Array
    defer: dict | None = None
    queue: dict | None = None


jax.tree_util.register_dataclass(
    StepperState,
    data_fields=["t", "r", "on", "wait", "defer", "queue"],
    meta_fields=[],
)


def stepper_init(n_levels: int, delta_lv, *, policy: str, window: int = 0,
                 deferral=None) -> StepperState:
    """Fresh carry: clocks at zero, everything off, deterministic waits
    pre-loaded with the static threshold — the full break-even timer Δ_l
    for the no-peek policies, ``max(0, Δ_l − w − 1)`` for the peeking A1
    (the batch engine's ``m_static``); randomized policies start at zero
    and draw theirs at first idle from the slot-indexed stream."""
    b = jnp.broadcast_to(jnp.asarray(delta_lv, jnp.float32), (n_levels,))
    if policy in KEYED:
        wait0 = jnp.zeros((n_levels,), jnp.float32)
    elif policy in NO_PEEK:
        wait0 = b
    else:
        wait0 = jnp.maximum(0.0, b - jnp.float32(window) - 1.0)
    return StepperState(
        t=0,
        r=jnp.zeros((n_levels,), jnp.float32),
        on=jnp.zeros((n_levels,), bool),
        wait=wait0,
        defer=None if deferral is None else defer_stream_init(deferral.bound()),
        queue=None if deferral is None else queue_stream_init(deferral.bound()),
    )


@functools.partial(jax.jit, static_argnames=("policy", "n_levels", "max_h",
                                             "window", "t_pad"))
def stepper_chunk(a_pad, n_valid, t0, key, r, on, wait, delta_lv, *,
                  policy, n_levels, max_h, window, t_pad):
    """One committed chunk of the per-level engine, jitted.

    ``a_pad``: (t_pad,) int32 demand, zero-padded past ``n_valid`` (jit
    *data* — the pad mask freezes state, so bucket padding never changes
    results); ``t0``: global slot of ``a_pad[0]``; ``key``: the planner's
    PRNG key (ignored for deterministic policies); ``r``/``on``/``wait``:
    the (N,) engine carry in.  Static keys are (policy, n_levels, max_h,
    window, t_pad) — none change across a serving loop, so the steady
    state replays one compiled program.

    Returns ``(x, (r, on, wait), totals)``: the (t_pad,) replica counts
    (zeros past ``n_valid``), the carry out, and the chunk's per-level
    ``run``/``up``/``down`` int32 totals (toggle edges against the carried
    state; the virtual x(0)=a(0) boundary applies only at ``t0 = 0``).
    The peek reads the chunk itself (the stepper's demand is already the
    best per-slot prediction) and sees quiet past the chunk end.
    """
    levels = jnp.arange(n_levels)
    b = jnp.broadcast_to(jnp.asarray(delta_lv, jnp.float32), (n_levels,))
    wf = jnp.float32(window)
    if policy in NO_PEEK:
        horizon = jnp.zeros((n_levels,), jnp.float32)
    else:
        horizon = jnp.minimum(wf + 1.0, b)
    hslots = jnp.arange(max_h, dtype=jnp.float32)
    a_pad = jnp.asarray(a_pad, jnp.int32)
    p_pad = jnp.concatenate([a_pad, jnp.zeros((max_h,), jnp.int32)])

    if policy in KEYED:
        def draw(tg):
            k0, k1 = jax.random.split(jax.random.fold_in(key, tg))
            return (jax.random.uniform(k0, (n_levels,)),
                    jax.random.uniform(k1, (n_levels,)))

        u0, u = jax.vmap(draw)(t0 + jnp.arange(t_pad))
        waits_tab = _waits_from_uniforms(policy, u0, u, window, b)
    else:
        waits_tab = None

    def slot(carry, tl):
        r, on, wait, run, up, down = carry
        valid = tl < n_valid
        busy = a_pad[tl] > levels
        prev_eff = jnp.where(t0 + tl == 0, busy, on)   # virtual x(0)=a(0)
        fut = jax.lax.dynamic_slice(p_pad, (tl + 1,), (max_h,))
        seen = (
            (fut[None, :] > levels[:, None]) & (hslots[None, :] < horizon[:, None])
        ).any(axis=1)
        (r2, on2, wait2), _, _ = _slot_update(
            r, on, wait, busy, seen,
            None if waits_tab is None else waits_tab[tl],
        )
        x_t = jnp.where(valid, on2.sum().astype(jnp.int32), 0)
        run = jnp.where(valid, run + on2.astype(jnp.int32), run)
        up = jnp.where(valid, up + (on2 & ~prev_eff).astype(jnp.int32), up)
        down = jnp.where(valid, down + (prev_eff & ~on2).astype(jnp.int32), down)
        r2 = jnp.where(valid, r2, r)
        on2 = jnp.where(valid, on2, on)
        wait2 = jnp.where(valid, wait2, wait)
        return (r2, on2, wait2, run, up, down), x_t

    z = jnp.zeros((n_levels,), jnp.int32)
    (r, on, wait, run, up, down), x = jax.lax.scan(
        slot, (r, on, wait, z, z, z), jnp.arange(t_pad)
    )
    return x, (r, on, wait), {"run": run, "up": up, "down": down}
