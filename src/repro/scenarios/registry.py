"""The scenario registry: parametric workload generators behind one dataclass.

A :class:`Scenario` is a *name* into the registry plus the knobs that make a
concrete workload population from it: generator ``params``, the ``seed`` of
the trace stream, and the two scale knobs every scenario shares —
``target_pmr`` (enforced per trace via :func:`repro.core.traces.scale_to_pmr`,
the paper's Section V-D transform) and ``mean_jobs``.  :func:`generate` turns
one into a ``(n_traces, n_slots)`` integer demand batch; :func:`make_workload`
goes one step further and returns a ready
:class:`~repro.core.provision.Workload` with an optional
:class:`~repro.core.provision.PredictionNoise` attached (``noise_std`` may be
a ``(S,)`` sweep, the spec axis the eval harness consumes).

Trace ``i`` of a batch is drawn from ``default_rng((seed, i))`` — the same
convention as ``TokenPipeline.batch_at`` — so batches are deterministic,
extendable (the first ``B`` traces of a bigger batch are unchanged), and
shared across eval cells (common random numbers).

Register new generators with :func:`register_scenario`; see
:mod:`repro.scenarios.generators` for the built-in bank and
``docs/scenarios.md`` for the how-to.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import numpy as np

from repro.core.traces import scale_to_pmr

GeneratorFn = Callable[..., np.ndarray]

_REGISTRY: dict[str, GeneratorFn] = {}

#: Relative tolerance on the *realized* peak-to-mean ratio of a generated
#: integer trace vs ``Scenario.target_pmr``.  ``scale_to_pmr`` hits the
#: target on the continuous trace, but the subsequent mean rescale +
#: ``rint`` + clip drifts the realized PMR (worst for bursty shapes at low
#: means, e.g. ``heavy_tail_bursts``); :func:`generate` re-fits the
#: pre-rounding target until the rounded trace lands within this tolerance,
#: and warns when it cannot (a trace whose raw shape caps the reachable
#: PMR below the target, e.g. a near-binary ``step_outage``).
PMR_TOL = 0.05

#: Secant-correction attempts before :func:`generate` gives up and warns.
PMR_REFITS = 4


def register_scenario(name: str) -> Callable[[GeneratorFn], GeneratorFn]:
    """Decorator: register ``fn(rng, n_slots, **params) -> (n_slots,) float``
    under ``name``.  Re-registering a taken name raises (rename or remove
    the old generator explicitly)."""

    def deco(fn: GeneratorFn) -> GeneratorFn:
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} is already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def scenario_names() -> tuple[str, ...]:
    """All registered generator names, sorted."""
    return tuple(sorted(_REGISTRY))


def get_generator(name: str) -> GeneratorFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}: registered scenarios are {scenario_names()}"
        ) from None


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One workload population: a registered generator plus its knobs.

    ``params`` go to the generator verbatim; ``target_pmr``/``mean_jobs``
    are applied afterwards by :func:`generate` (PMR first — the rescale is
    mean-preserving — then the mean), so every scenario hits the same scale
    regardless of its raw shape.  ``target_pmr=None`` keeps the generator's
    natural peakiness.
    """

    name: str
    params: dict = dataclasses.field(default_factory=dict)
    seed: int = 0
    target_pmr: float | None = None
    mean_jobs: float = 32.0

    def describe(self) -> str:
        kv = ", ".join(f"{k}={v}" for k, v in sorted(self.params.items()))
        pmr = "natural" if self.target_pmr is None else f"{self.target_pmr:g}"
        return (
            f"{self.name}(seed={self.seed}, pmr={pmr}, "
            f"mean={self.mean_jobs:g}{', ' + kv if kv else ''})"
        )


def _quantize(a: np.ndarray, mean_jobs: float) -> np.ndarray:
    """Shared tail of the rescale: mean to ``mean_jobs``, rint, clip at 0."""
    mean = a.mean()
    if mean > 0:
        a = a / mean * mean_jobs
    return np.maximum(np.rint(a), 0).astype(np.int64)


def _fit_pmr(a: np.ndarray, target: float, mean_jobs: float,
             label: str) -> np.ndarray:
    """Integer trace whose *realized* PMR is within ``PMR_TOL`` of target.

    ``scale_to_pmr`` only controls the continuous trace; rounding drifts
    the realized ratio.  Measure it post-rounding and secant-correct the
    pre-rounding target (deterministically — no extra randomness) until the
    rounded trace lands inside the tolerance; warn if the trace's shape
    makes the target unreachable.
    """
    goal = target
    best, best_err = None, np.inf
    for _ in range(PMR_REFITS + 1):
        q = _quantize(scale_to_pmr(a, goal), mean_jobs)
        mean = q.mean()
        realized = float(q.max() / mean) if mean > 0 else 0.0
        err = abs(realized - target) / target
        if err < best_err:
            best, best_err = q, err
        if err <= PMR_TOL or realized <= 0:
            break
        goal = max(1.0 + 1e-6, goal * target / realized)
    if best_err > PMR_TOL:
        warnings.warn(
            f"scenario {label}: realized PMR after rounding is off target "
            f"{target:g} by {best_err:.1%} (> {PMR_TOL:.0%}) even after "
            f"{PMR_REFITS} re-fits — the trace shape or mean_jobs "
            f"{mean_jobs:g} caps the reachable peak-to-mean ratio",
            RuntimeWarning,
            stacklevel=3,
        )
    return best


def generate(scenario: Scenario, n_traces: int, n_slots: int) -> np.ndarray:
    """``(n_traces, n_slots)`` int64 demand batch for one scenario.

    Each trace gets its own ``default_rng((seed, i))`` stream, then the
    shared rescale: ``scale_to_pmr`` to ``target_pmr`` (if set, re-fit so
    the rounded trace realizes it within ``PMR_TOL``), mean to
    ``mean_jobs``, round to integer jobs, clip at 0.
    """
    fn = get_generator(scenario.name)
    out = np.empty((n_traces, n_slots), np.int64)
    for i in range(n_traces):
        rng = np.random.default_rng((scenario.seed, i))
        a = np.asarray(fn(rng, n_slots, **scenario.params), np.float64)
        if a.shape != (n_slots,):
            raise ValueError(
                f"scenario {scenario.name!r} generator returned shape "
                f"{a.shape}, expected ({n_slots},)"
            )
        if scenario.target_pmr is not None:
            out[i] = _fit_pmr(a, float(scenario.target_pmr),
                              scenario.mean_jobs, f"{scenario.name!r}[{i}]")
        else:
            out[i] = _quantize(a, scenario.mean_jobs)
    return out


def _component_trace(comp: Scenario, rng: np.random.Generator,
                     n_slots: int) -> np.ndarray:
    """One component's *float* trace for a combinator: the component's own
    generator, ``target_pmr`` and ``mean_jobs`` applied in the continuous
    domain (no rounding — the outer :func:`generate` pipeline quantizes
    once, after combination).  The component draws from a child stream
    seeded off the combinator's ``rng``, so the whole composite stays a
    deterministic function of ``(seed, trace_index)``."""
    fn = get_generator(comp.name)
    child = np.random.default_rng(rng.integers(2**63))
    a = np.asarray(fn(child, n_slots, **comp.params), np.float64)
    if comp.target_pmr is not None:
        a = scale_to_pmr(a, float(comp.target_pmr))
    mean = a.mean()
    if mean > 0:
        a = a / mean * comp.mean_jobs
    return a


def _check_components(components) -> tuple:
    components = tuple(components)
    if not components:
        raise ValueError("need at least one component scenario")
    bad = [c for c in components if not isinstance(c, Scenario)]
    if bad:
        raise ValueError(f"components must be Scenario instances, got {bad}")
    return components


@register_scenario("mix")
def _mix_generator(rng, n_slots, *, components, weights=None) -> np.ndarray:
    """Overlay: the weighted sum of the component traces — e.g. a diurnal
    base carrying heavy-tail burst traffic on top."""
    components = _check_components(components)
    if weights is None:
        weights = (1.0,) * len(components)
    weights = np.asarray(weights, np.float64)
    if weights.shape != (len(components),) or (weights < 0).any() \
            or weights.sum() <= 0:
        raise ValueError(
            f"weights must be {len(components)} non-negative numbers with a "
            f"positive sum, got {weights}"
        )
    out = np.zeros(n_slots, np.float64)
    for w, comp in zip(weights, components):
        out += w * _component_trace(comp, rng, n_slots)
    return out


@register_scenario("concat")
def _concat_generator(rng, n_slots, *, components, fractions=None) -> np.ndarray:
    """Splice: the timeline divided among the components — e.g. a sinusoidal
    week that turns into a flash crowd for its last quarter.  ``fractions``
    are relative segment lengths (default equal); every segment gets at
    least one slot and the last absorbs the rounding remainder."""
    components = _check_components(components)
    if fractions is None:
        fractions = (1.0,) * len(components)
    fractions = np.asarray(fractions, np.float64)
    if fractions.shape != (len(components),) or (fractions <= 0).any():
        raise ValueError(
            f"fractions must be {len(components)} positive numbers, "
            f"got {fractions}"
        )
    if n_slots < len(components):
        raise ValueError(
            f"cannot splice {len(components)} components into {n_slots} slots"
        )
    bounds = np.rint(
        np.cumsum(fractions) / fractions.sum() * n_slots
    ).astype(np.int64)
    bounds[-1] = n_slots
    # every segment gets >= 1 slot even under aggressive rounding
    for i in range(1, len(bounds)):
        bounds[i] = max(bounds[i], bounds[i - 1] + 1)
    starts = np.concatenate([[0], bounds[:-1]])
    return np.concatenate([
        _component_trace(comp, rng, int(hi - lo))
        for comp, lo, hi in zip(components, starts, bounds)
    ])


def mix(
    *components: Scenario,
    weights=None,
    seed: int = 0,
    target_pmr: float | None = None,
    mean_jobs: float = 32.0,
) -> Scenario:
    """Overlay combinator: one :class:`Scenario` whose traces are the
    weighted sum of the component scenarios' (continuous) traces.

    Each component applies its own ``target_pmr``/``mean_jobs`` before the
    weighting, so the weights are in units of the components' means; the
    outer ``target_pmr``/``mean_jobs`` then rescale the composite through
    the standard :func:`generate` pipeline.  The result composes everywhere
    a built-in scenario does — ``generate``, ``make_workload``, eval grids.
    """
    return Scenario(
        "mix",
        params={
            "components": _check_components(components),
            "weights": None if weights is None
            else tuple(float(w) for w in weights),
        },
        seed=seed,
        target_pmr=target_pmr,
        mean_jobs=mean_jobs,
    )


def concat(
    *components: Scenario,
    fractions=None,
    seed: int = 0,
    target_pmr: float | None = None,
    mean_jobs: float = 32.0,
) -> Scenario:
    """Splice combinator: one :class:`Scenario` whose timeline is divided
    among the components in ``fractions`` (default: equal shares).

    Segment ``j`` is the ``j``-th component's trace generated at the
    segment's length (its own ``target_pmr``/``mean_jobs`` applied in the
    continuous domain); the outer knobs rescale the composite afterwards,
    exactly like :func:`mix`.
    """
    return Scenario(
        "concat",
        params={
            "components": _check_components(components),
            "fractions": None if fractions is None
            else tuple(float(f) for f in fractions),
        },
        seed=seed,
        target_pmr=target_pmr,
        mean_jobs=mean_jobs,
    )


def make_workload(
    scenario: Scenario,
    n_traces: int,
    n_slots: int,
    *,
    noise_std=None,
    noise_key=None,
    clip_to: int | None = None,
    deferral=None,
):
    """A ready :class:`~repro.core.provision.Workload` for one scenario.

    ``noise_std``: optional ``std_frac`` for a
    :class:`~repro.core.provision.PredictionNoise` — a scalar, or a ``(S,)``
    array to sweep prediction-error levels as a leading result axis (common
    random numbers: one normal draw per trace, scaled per level).
    ``noise_key``: PRNG key for the noise draws; defaults to
    ``jax.random.key(scenario.seed)``.  ``clip_to``: cap demand at a fleet
    capacity (typed fleets pin theirs via ``CostModel.n_levels`` — a
    scenario's peak may exceed it, and provisioning requires
    ``demand <= n_levels``).  ``deferral``: optional
    :class:`~repro.deferral.DeferralSpec` attached to the workload; with
    both ``deferral`` and ``clip_to`` set the demand is *not* hard-clipped
    — the cap becomes the deferral spec's service ceiling, so displaced
    work re-enters the backlog (work conservation) instead of being
    silently dropped.  A single trace (``n_traces=1``) still yields a
    ``(1, n_slots)`` batch — index ``demand[0]`` if you want the unbatched
    convention.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.provision import PredictionNoise, Workload

    raw = generate(scenario, n_traces, n_slots)
    if clip_to is not None:
        if clip_to < 1:
            raise ValueError(f"clip_to={clip_to} must be >= 1")
        if deferral is not None:
            cap = clip_to if deferral.cap is None else min(deferral.cap,
                                                          clip_to)
            deferral = dataclasses.replace(deferral, cap=cap)
        else:
            raw = np.minimum(raw, clip_to)
    demand = jnp.asarray(raw, jnp.int32)
    noise = None
    if noise_std is not None:
        if noise_key is None:
            noise_key = jax.random.key(scenario.seed)
        noise = PredictionNoise(std_frac=noise_std, key=noise_key)
    return Workload(demand=demand, noise=noise, deferral=deferral)
