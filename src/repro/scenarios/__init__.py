"""Scenario library: parametric workload generators behind one registry.

The evaluation counterpart of the provisioning engine: where
:mod:`repro.core.provision` answers "what does policy π cost on trace a",
this package answers "which traces should π be judged on".  Six generator
families ship registered (``msr_diurnal``, ``sinusoidal``, ``flash_crowd``,
``step_outage``, ``heavy_tail_bursts``, ``replay``); each yields
deterministic ``(B, T)`` demand batches at a target peak-to-mean ratio, and
:func:`make_workload` bridges straight into a ``Workload`` with an optional
prediction-noise sweep and/or a deferral spec.  :func:`mix` and
:func:`concat` combine registered families into composite scenarios
(weighted overlay / timeline splice).  ``repro.eval`` runs the full grid.
"""
from .registry import (
    Scenario,
    concat,
    generate,
    get_generator,
    make_workload,
    mix,
    register_scenario,
    scenario_names,
)
from .generators import SAMPLE_TRACE_PATH  # noqa: F401  (registers the bank)

#: The default evaluation bank: every built-in generator at the paper's
#: scale (PMR 4.63, Section V-A) — ``replay`` keeps its recording's natural
#: peakiness (rescaling a replayed trace would defeat the point).
DEFAULT_SCENARIOS = (
    Scenario("msr_diurnal", target_pmr=4.63),
    Scenario("sinusoidal", target_pmr=4.63),
    Scenario("flash_crowd", target_pmr=4.63),
    Scenario("step_outage", target_pmr=4.63),
    Scenario("heavy_tail_bursts", target_pmr=4.63),
    Scenario("replay"),
)

__all__ = [
    "DEFAULT_SCENARIOS",
    "SAMPLE_TRACE_PATH",
    "Scenario",
    "concat",
    "generate",
    "get_generator",
    "make_workload",
    "mix",
    "register_scenario",
    "scenario_names",
]
