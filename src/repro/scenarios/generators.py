"""Parametric workload-shape generators behind the scenario registry.

Every generator has the same signature::

    fn(rng: np.random.Generator, n_slots: int, **params) -> (n_slots,) float64

and returns an *unnormalized* non-negative demand shape.  The registry
pipeline (:func:`repro.scenarios.generate`) then rescales every trace to the
scenario's ``target_pmr`` via :func:`repro.core.traces.scale_to_pmr` and to
its ``mean_jobs`` before rounding to integer jobs-per-slot, so shape and
scale are orthogonal knobs: a generator only describes *when* load arrives,
never how much.

The bank mirrors how the right-sizing literature evaluates (Albers &
Quedenfeld; Hübotter): a diurnal baseline plus the shapes that stress
ski-rental policies from different directions — smooth periodicity
(``sinusoidal``), sudden onset/decay (``flash_crowd``), level shifts and
dropouts (``step_outage``, the regime where toggling is most tempting and
most dangerous), heavy-tailed burst sizes (``heavy_tail_bursts``), and real
recorded traces (``replay``).
"""
from __future__ import annotations

import pathlib

import numpy as np

from repro.core.traces import SLOTS_PER_DAY, msr_like_trace

from .registry import register_scenario

#: Two days of an MSR-like trace checked in as the ``replay`` sample.
SAMPLE_TRACE_PATH = pathlib.Path(__file__).parent / "data" / "msr_sample.csv"


@register_scenario("msr_diurnal")
def msr_diurnal(
    rng: np.random.Generator,
    n_slots: int,
    *,
    noise: float = 0.08,
    spike_prob: float = 0.004,
) -> np.ndarray:
    """The paper's synthetic MSR-Cambridge-like week: diurnal + weekly humps,
    occasional flash spikes (wraps :func:`repro.core.traces.msr_like_trace`)."""
    return msr_like_trace(
        rng, n_slots=n_slots, noise=noise, spike_prob=spike_prob
    ).astype(np.float64)


@register_scenario("sinusoidal")
def sinusoidal(
    rng: np.random.Generator,
    n_slots: int,
    *,
    period: int = SLOTS_PER_DAY,
    depth: float = 0.8,
    second_harmonic: float = 0.2,
    noise: float = 0.05,
) -> np.ndarray:
    """Smooth periodic load: 1 + depth·sin(2πt/period) (+ a second harmonic),
    random phase, multiplicative noise.  The gentlest scenario — idle gaps
    change length slowly, so predictions are most informative here."""
    t = np.arange(n_slots)
    phase = rng.uniform(0.0, 2 * np.pi)
    w = 2 * np.pi * t / period
    base = 1.0 + depth * np.sin(w + phase) + second_harmonic * np.sin(2 * w + phase)
    base = base * (1.0 + noise * rng.standard_normal(n_slots))
    return np.clip(base, 0.02, None)


@register_scenario("flash_crowd")
def flash_crowd(
    rng: np.random.Generator,
    n_slots: int,
    *,
    n_events: int = 3,
    spike_mag: float = 8.0,
    rise_slots: int = 2,
    decay_slots: int = 24,
    base_depth: float = 0.3,
) -> np.ndarray:
    """Quiet diurnal baseline plus sudden spikes (the paper's "Lady Gaga"
    events, footnote 2): each event ramps up over ``rise_slots`` and decays
    exponentially with time constant ``decay_slots``.  Stresses the
    turn-*on* path and rewards policies that don't power down too eagerly
    right after a crowd disperses."""
    t = np.arange(n_slots)
    base = 1.0 + base_depth * np.sin(2 * np.pi * t / SLOTS_PER_DAY + rng.uniform(0, 2 * np.pi))
    population = max(n_slots - decay_slots, 1)      # short horizons: fewer events
    n_events = min(n_events, population)
    starts = rng.choice(population, size=n_events, replace=False)
    mags = spike_mag * rng.uniform(0.5, 1.0, n_events)
    for s, m in zip(starts, mags):
        rel = t - s
        ramp = np.clip(rel / max(rise_slots, 1), 0.0, 1.0)
        decay = np.exp(-np.clip(rel - rise_slots, 0, None) / decay_slots)
        base = base + m * np.where(rel >= 0, ramp * decay, 0.0)
    return np.clip(base, 0.02, None)


@register_scenario("step_outage")
def step_outage(
    rng: np.random.Generator,
    n_slots: int,
    *,
    n_steps: int = 6,
    level_lo: float = 0.2,
    level_hi: float = 2.0,
    outage_slots: int = 12,
    noise: float = 0.03,
) -> np.ndarray:
    """Piecewise-constant level shifts plus one hard dropout (demand = 0 for
    ``outage_slots``).  Idle gaps here are exactly the shapes the ski-rental
    lower bound is built from — gaps near Δ — so this is the adversarial
    scenario for A1/A2/A3."""
    edges = np.sort(rng.choice(np.arange(1, n_slots), size=n_steps - 1, replace=False))
    levels = rng.uniform(level_lo, level_hi, n_steps)
    base = levels[np.searchsorted(edges, np.arange(n_slots), side="right")]
    base = base * (1.0 + noise * rng.standard_normal(n_slots))
    out0 = rng.integers(0, max(n_slots - outage_slots, 1))
    base[out0 : out0 + outage_slots] = 0.0
    return np.clip(base, 0.0, None)


@register_scenario("heavy_tail_bursts")
def heavy_tail_bursts(
    rng: np.random.Generator,
    n_slots: int,
    *,
    burst_prob: float = 0.06,
    zipf_s: float = 1.6,
    max_burst: int = 64,
    hold_slots: int = 4,
    base_level: float = 0.5,
) -> np.ndarray:
    """Low baseline plus Zipf-sized job bursts: each arriving burst holds for
    ``hold_slots`` then decays geometrically.  The size distribution's heavy
    tail makes peak-to-mean large and the demand *derivative* bursty — the
    regime where toggle costs dominate energy."""
    sizes = np.minimum(rng.zipf(zipf_s, n_slots), max_burst).astype(np.float64)
    arrivals = (rng.uniform(size=n_slots) < burst_prob) * sizes
    base = np.full(n_slots, base_level)
    active = 0.0
    for t in range(n_slots):
        active = active * (0.5 ** (1.0 / hold_slots)) + arrivals[t]
        base[t] += active
    return base


@register_scenario("replay")
def replay(
    rng: np.random.Generator,
    n_slots: int,
    *,
    path: str | pathlib.Path | None = None,
    key: str = "demand",
) -> np.ndarray:
    """Replay a recorded trace from a ``.csv`` (one demand value per line,
    ``#`` comments allowed) or ``.npz`` (array under ``key``, else the first
    array) file, tiled/cropped to ``n_slots``.  Defaults to the checked-in
    two-day MSR-like sample.  Deterministic: the rng is unused, so every
    trace in a batch replays the same recording."""
    p = pathlib.Path(path) if path is not None else SAMPLE_TRACE_PATH
    if p.suffix == ".npz":
        with np.load(p) as z:
            arr = z[key] if key in z.files else z[z.files[0]]
    else:
        arr = np.loadtxt(p, comments="#", delimiter=",", ndmin=1)
    a = np.asarray(arr, np.float64).reshape(-1)
    if a.size == 0:
        raise ValueError(f"replay trace {p} is empty")
    reps = -(-n_slots // a.size)
    return np.tile(a, reps)[:n_slots]
