"""Small shared utilities: logging, timing, tree helpers."""
from __future__ import annotations

import contextlib
import logging
import sys
import time
from typing import Any, Iterator

LOGGER_NAME = "repro"


def get_logger(name: str = LOGGER_NAME) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(
            logging.Formatter("[%(asctime)s %(name)s %(levelname)s] %(message)s", "%H:%M:%S")
        )
        logger.addHandler(handler)
        logger.setLevel(logging.INFO)
        logger.propagate = False
    return logger


@contextlib.contextmanager
def timed(label: str, sink: dict | None = None) -> Iterator[None]:
    """Context manager recording wall time; optionally writes into ``sink[label]``."""
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    if sink is not None:
        sink[label] = dt
    else:
        get_logger().info("%s: %.3fs", label, dt)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def flatten_dict(d: dict, prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in d.items():
        key = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_dict(v, key))
        else:
            out[key] = v
    return out
