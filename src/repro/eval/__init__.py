"""Competitive-ratio evaluation harness over the scenario library.

``evaluate(EvalGrid(...)) -> EvalReport``: empirical CR of every policy ×
scenario × noise-std × window cell against the offline optimum, checked
against the paper's bounds, as warmed batched device programs.  The report
serializes to ``BENCH_provision.json`` (``benchmarks/cr_eval.py``).
"""
from .harness import EvalGrid, evaluate
from .report import SCHEMA, CellResult, EvalReport

__all__ = ["SCHEMA", "CellResult", "EvalGrid", "EvalReport", "evaluate"]
