"""Competitive-ratio evaluation harness over the scenario library.

``evaluate(EvalGrid(...)) -> EvalReport``: empirical CR of every policy ×
scenario × noise-std × window cell against the offline optimum, checked
against the paper's bounds, as warmed batched device programs.  The report
serializes to ``BENCH_provision.json`` (``benchmarks/cr_eval.py``).
"""
from .harness import TYPED_POLICIES, EvalGrid, evaluate
from .report import (
    CR_QUANTILES,
    SCHEMA,
    SCHEMA_V1,
    SCHEMA_V2,
    SCHEMA_V3,
    SCHEMA_V4,
    CellResult,
    EvalReport,
    StreamingRow,
)

__all__ = [
    "CR_QUANTILES",
    "SCHEMA",
    "SCHEMA_V1",
    "SCHEMA_V2",
    "SCHEMA_V3",
    "SCHEMA_V4",
    "TYPED_POLICIES",
    "CellResult",
    "EvalGrid",
    "EvalReport",
    "StreamingRow",
    "evaluate",
]
