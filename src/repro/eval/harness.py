"""Batched competitive-ratio evaluation: the paper's headline claims as a grid.

``evaluate(EvalGrid(...))`` measures every (policy × scenario × noise-std ×
window) cell's empirical competitive ratio against the offline optimum and
checks it against the paper's worst-case bounds — A1 ≤ 2−α, A2 ≤ (e−α)/(e−1)
and A3 ≤ e/(e−1+α) *in expectation* (Theorems 2–4), delayed-off ≤ 2 — within
a statistical tolerance.

``EvalGrid.typed_groups`` adds a typed-fleet block to the same report: per
scenario, each ``typed_policies`` entry (the Albers–Quedenfeld ``AQ-det``/
``AQ-rand``) runs on the d-type fleet ``CostModel.from_groups(*groups)``
and is checked against the aggregate 2d (deterministic) or d·e/(e−1)
(randomized) guarantee, with per-server-type CR columns verified against
the per-type ski-rental bounds (2 and e/(e−1) — the level decomposition
achieves the per-type bound, which is strictly stronger than the
aggregate).

The whole grid runs as warmed batched device programs, not a Python loop per
cell: one ``provision(spec)`` call per (policy, scenario) covers the full
``(S, W, B)`` block via the ``PredictionNoise.std_frac`` sweep axis and
``PolicySpec.windows``, and every scenario shares one fleet size so shapes —
hence compiled programs — are reused across scenarios.  Common random
numbers throughout: trace ``i`` is identical in every cell, the noise sweep
shares its normal draws across std levels, and the α-sweep shares its wait
draws across windows, so CR *curves* over any axis are variance-reduced.

The result serializes to ``BENCH_provision.json`` via
:class:`repro.eval.report.EvalReport` (see ``benchmarks/cr_eval.py``).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    PAPER_COSTS,
    CostModel,
    DeferralSpec,
    PolicySpec,
    PredictionNoise,
    ProvisionSpec,
    ServerGroup,
    Workload,
    provision,
    theoretical_ratio,
)
from repro.core.jax_provision import KEYED
from repro.core.traces import WEEK_SLOTS
from repro.deferral import RULES
from repro.obs.jaxwatch import CompileWatcher
from repro.obs.telemetry import get_telemetry
from repro.scenarios import DEFAULT_SCENARIOS, Scenario

from .report import CR_QUANTILES, CellResult, EvalReport

#: typed-fleet policies the harness knows bounds for (Albers–Quedenfeld)
TYPED_POLICIES = ("AQ-det", "AQ-rand")


@dataclasses.dataclass(frozen=True)
class EvalGrid:
    """The declarative input of one evaluation run.

    ``costs`` must be homogeneous (scalar fields): the paper's bounds are
    stated for one Δ, and a per-level model has no single α per window.
    ``tol`` is the statistical slack on the *expectation* bound checks —
    randomized policies are evaluated over ``n_traces`` PRNG replicas, so
    the empirical mean sits within O(1/√n_traces) of its expectation.

    ``mesh``: run every online-policy cell through the sharded Pallas fleet
    path (the level axis over ``mesh_axis``; the offline baseline stays on
    the closed form, which has no slot scan).  The kernel is bit-exact
    against the lax.scan programs, so the report's cells are identical
    either way — this knob exists to run the eval grid *as* a fleet-path
    regression gate.  ``use_pallas=False`` keeps the sharded lax.scan body.

    ``typed_groups``: optional :class:`~repro.core.ServerGroup` tuple — a
    d-type fleet evaluated (per scenario, no noise/window axes: the AQ
    policies never peek) as extra cells with per-type CR columns, one cell
    per ``typed_policies`` entry per scenario.  The typed fleet rides
    ``mesh``/``use_pallas`` too, exercising the group-aligned kernel
    layout.

    ``deferral_slacks``: optional slack sweep (slots) — adds one deferral
    cell per (``deferral_policies`` entry × scenario × slack), each running
    the defer-then-provision path (``Workload(deferral=...)``, rule
    ``deferral_rule``) at window 0 with exact predictions.  The CR
    denominator is the offline optimum *on the deferred profile* (the CR
    bound is a property of the provisioning game, whatever demand it is
    fed), so cost-vs-slack shows up in ``mean_cost``/``mean_opt_cost``
    falling while CR stays bounded; the latency side lands in the
    ``slo_ok`` verdict — no deadline misses and p99 delay within the
    granted slack.  Deferral cells ride ``mesh``/``use_pallas`` too.
    """

    policies: tuple[str, ...] = ("A1", "A2", "A3")
    scenarios: tuple[Scenario, ...] = DEFAULT_SCENARIOS
    noise_stds: tuple[float, ...] = (0.0,)
    windows: tuple[int, ...] = (0, 1, 2, 3, 4, 5)
    n_traces: int = 16
    n_slots: int = WEEK_SLOTS
    costs: CostModel = PAPER_COSTS
    seed: int = 0
    tol: float = 0.05
    #: Extra slack per unit of prediction-noise std: the paper's bounds
    #: assume *exact* predictions (Sec. V-C only studies noise empirically),
    #: and measured degradation is ≲ 0.4·std, so a noisy cell must satisfy
    #: ``mean_cr <= bound + tol + noise_slack * noise_std``.
    noise_slack: float = 0.5
    mesh: "jax.sharding.Mesh | None" = None
    mesh_axis: str = "data"
    use_pallas: bool = True
    typed_groups: tuple[ServerGroup, ...] | None = None
    typed_policies: tuple[str, ...] = TYPED_POLICIES
    deferral_slacks: tuple[int, ...] | None = None
    deferral_rule: str = "EDF"
    deferral_policies: tuple[str, ...] = ("A1",)

    def validate(self) -> "EvalGrid":
        if self.costs.is_heterogeneous:
            raise ValueError(
                "EvalGrid needs a homogeneous CostModel: competitive-ratio "
                "bounds are per-Δ, and a per-level model has no single α "
                "(typed fleets go through typed_groups=, which carries the "
                "per-type structure the bounds need)"
            )
        if self.typed_groups is not None:
            if not self.typed_groups:
                raise ValueError("typed_groups needs at least one ServerGroup")
            for g in self.typed_groups:
                g.validate()
            unknown = [p for p in self.typed_policies if p not in TYPED_POLICIES]
            if unknown or not self.typed_policies:
                raise ValueError(
                    f"typed_policies must be drawn from {TYPED_POLICIES}, "
                    f"got {self.typed_policies}"
                )
        if not self.policies or not self.scenarios:
            raise ValueError("EvalGrid needs at least one policy and scenario")
        if any(w < 0 for w in self.windows) or not self.windows:
            raise ValueError(f"windows must be non-negative, got {self.windows}")
        if any(s < 0 for s in self.noise_stds) or not self.noise_stds:
            raise ValueError(
                f"noise_stds must be non-negative, got {self.noise_stds}"
            )
        if self.mesh is not None and "offline" in self.policies:
            raise ValueError(
                "mesh= runs cells through the sharded fleet path, which has "
                "no offline slot scan; drop 'offline' from policies (the "
                "offline baseline is computed regardless)"
            )
        if self.deferral_slacks is not None:
            if not self.deferral_slacks or any(
                k < 0 for k in self.deferral_slacks
            ):
                raise ValueError(
                    "deferral_slacks must be a non-empty tuple of "
                    f"non-negative slot counts, got {self.deferral_slacks}"
                )
            if self.deferral_rule not in RULES:
                raise ValueError(
                    f"deferral_rule must be one of {RULES}, "
                    f"got {self.deferral_rule!r}"
                )
            bad = [p for p in self.deferral_policies
                   if p == "offline" or _bound(p, 1.0) is None]
            if bad or not self.deferral_policies:
                raise ValueError(
                    "deferral_policies must be online policies with a "
                    f"stated bound, got {self.deferral_policies}"
                )
        return self


def _timed(label: str, fn, **span_labels):
    """Run ``fn`` under a telemetry span with compile accounting.

    Returns ``(blocked result, wall_ms, compiles_added)`` — the per-cell
    runtime-health pair the v4 report schema serializes.  One
    :class:`~repro.obs.jaxwatch.CompileWatcher` region per call replaces
    the hand-rolled ``_engine_cache_size`` delta this harness used to
    carry; ``compiles_added`` is -1 when the cache API is unobservable.
    """
    with get_telemetry().span(label, **span_labels):
        t0 = time.perf_counter()
        with CompileWatcher() as w:
            out = jax.block_until_ready(fn())
        wall_ms = (time.perf_counter() - t0) * 1e3
    return out, wall_ms, w.added


def _bound(policy: str, alpha: float) -> float | None:
    """Paper worst-case ratio for a policy at prediction fraction α.

    Dispatches on the policy *name* — ``theoretical_ratio`` covers the
    paper's A1/A2/A3 theorems only, and leaning on its raise type for the
    fallback is brittle (a ``ValueError`` there would silently strip the
    offline/delayedoff cells of their bounds, or crash the harness).
    """
    if policy == "offline":
        return 1.0              # hindsight optimum IS the denominator
    if policy == "delayedoff":
        return 2.0              # break-even timer Δ, classic ski-rental bound
    if policy in ("A1", "A2", "A3"):
        return theoretical_ratio(policy, alpha)
    if policy == "AQ-det":
        return 2.0              # per-type break-even timer (d = 1 view)
    if policy == "AQ-rand":
        return math.e / (math.e - 1.0)
    return None


def _typed_bounds(policy: str, d: int) -> tuple[float, float]:
    """(aggregate, per-type) competitive-ratio bounds on a d-type fleet.

    The Albers–Quedenfeld guarantees: 2d for the deterministic algorithm,
    d·e/(e−1) for the randomized one.  The per-type column is the plain
    ski-rental bound (2 / e/(e−1)) — the per-level decomposition achieves
    it type by type, which implies the aggregate bound with room to spare.
    """
    per_type = _bound(policy, 0.0)
    if per_type is None or policy not in TYPED_POLICIES:
        raise ValueError(f"no typed bound for policy {policy!r}")
    return d * per_type, per_type


def _scenario_labels(scenarios: tuple[Scenario, ...]) -> list[str]:
    """Unique per-scenario labels (name, suffixed on collision)."""
    seen: dict[str, int] = {}
    labels = []
    for sc in scenarios:
        k = seen.get(sc.name, 0)
        seen[sc.name] = k + 1
        labels.append(sc.name if k == 0 else f"{sc.name}#{k}")
    return labels


def _evaluate_typed(
    grid: EvalGrid, labels: list[str], demands: list, base_statics: tuple
) -> tuple[list[CellResult], int]:
    """Typed-fleet cells for every (typed policy, scenario) pair.

    One ``provision`` per pair plus one typed offline baseline per scenario
    — no noise/window axes (the AQ policies never peek).  Returns the cells
    and the number of extra compiled programs the block is expected to add
    (``base_statics`` is the untyped block's (n_levels, max_h) static key:
    the typed offline baseline reuses its program when the keys collide).
    """
    if grid.typed_groups is None:
        return [], 0
    costs = CostModel.from_groups(*grid.typed_groups)
    d = costs.n_groups
    expected = len(set(grid.typed_policies))
    if (costs.n_levels, costs.delta_slots()) != base_statics:
        expected += 1                                   # the typed offline
    cells: list[CellResult] = []
    for label, demand_np in zip(labels, demands):
        # typed fleets pin their capacity; cap demand at it (same semantic
        # as make_workload(clip_to=...)) so every scenario fits the fleet
        demand = jnp.minimum(
            jnp.asarray(demand_np, jnp.int32), costs.n_levels
        )
        opt_group = provision(ProvisionSpec(
            costs=costs,
            workload=Workload(demand=demand),
            policy=PolicySpec("offline"),
        )).group_cost                                   # (B, d)
        opt_group = np.asarray(jax.block_until_ready(opt_group), np.float64)
        opt = opt_group.sum(axis=-1)
        for pi, policy in enumerate(grid.typed_policies):
            spec = ProvisionSpec(
                costs=costs,
                workload=Workload(demand=demand),
                policy=PolicySpec(
                    policy,
                    key=(
                        jax.random.fold_in(jax.random.key(grid.seed + 2), pi)
                        if policy in KEYED
                        else None
                    ),
                ),
                mesh=grid.mesh,
                mesh_axis=grid.mesh_axis,
                use_pallas=grid.use_pallas,
            )
            cost_group, wall_ms, compiles = _timed(
                "eval/typed_cell",
                lambda: provision(spec).group_cost,     # (B, d)
                policy=policy, scenario=label,
            )
            cost_group = np.asarray(cost_group, np.float64)
            cost = cost_group.sum(axis=-1)
            cr = cost / opt
            bound, per_type_bound = _typed_bounds(policy, d)
            # a type the offline optimum never powers is never powered
            # online either (same dispatcher condition), so 0/0 cells are
            # vacuously ratio 1
            group_cr = np.where(
                opt_group > 0,
                cost_group / np.where(opt_group > 0, opt_group, 1.0),
                1.0,
            ).mean(axis=0)                              # (d,)
            mean_cr = float(cr.mean())
            quantiles = [float(q) for q in np.quantile(cr, CR_QUANTILES)]
            cells.append(CellResult(
                policy=policy,
                scenario=label,
                noise_std=0.0,
                window=0,
                alpha=0.0,                              # no peek
                bound=bound,
                mean_cr=mean_cr,
                p95_cr=float(np.percentile(cr, 95)),
                max_cr=float(cr.max()),
                mean_cost=float(cost.mean()),
                mean_opt_cost=float(opt.mean()),
                bound_ok=mean_cr <= bound + grid.tol,
                p50_cr=quantiles[CR_QUANTILES.index(0.5)],
                cr_quantiles=quantiles,
                group_names=list(costs.group_names),
                group_mean_cr=[float(v) for v in group_cr],
                group_bound=[per_type_bound] * d,
                group_bound_ok=[
                    bool(v <= per_type_bound + grid.tol) for v in group_cr
                ],
                wall_ms=wall_ms,
                compiles=compiles,
            ))
    return cells, expected


def _evaluate_deferral(
    grid: EvalGrid, labels: list[str], demands: list, n_levels: int
) -> tuple[list[CellResult], int]:
    """Deferral cells: (deferral policy × scenario × slack) at window 0.

    One ``provision`` per (scenario, slack, policy) plus one deferred
    offline baseline per (scenario, slack) — slack is jit *data* (and the
    offline program is shared with the main block), so the whole sweep
    adds ``len(set(deferral_policies))`` compiled engine programs.  Each
    cell's CR is measured against the offline optimum on the *same*
    deferred profile; the slack axis shows up as ``mean_cost`` falling
    and the ``slo_ok`` latency verdict.
    """
    if grid.deferral_slacks is None:
        return [], 0
    max_slack = max(grid.deferral_slacks)
    alpha = min(1.0, 1.0 / float(grid.costs.delta))         # window = 0
    cells: list[CellResult] = []
    for label, demand_np in zip(labels, demands):
        demand = jnp.asarray(demand_np, jnp.int32)
        for slack in grid.deferral_slacks:
            dspec = DeferralSpec(
                slack=slack, rule=grid.deferral_rule, max_slack=max_slack
            )
            opt = provision(ProvisionSpec(
                costs=grid.costs,
                workload=Workload(demand=demand, deferral=dspec),
                policy=PolicySpec("offline"),
                n_levels=n_levels,
            )).cost                                         # (B,)
            opt = np.asarray(jax.block_until_ready(opt), np.float64)
            for pi, policy in enumerate(grid.deferral_policies):
                spec = ProvisionSpec(
                    costs=grid.costs,
                    workload=Workload(demand=demand, deferral=dspec),
                    policy=PolicySpec(
                        policy,
                        key=(
                            jax.random.fold_in(
                                jax.random.key(grid.seed + 3), pi
                            )
                            if policy in KEYED
                            else None
                        ),
                    ),
                    n_levels=n_levels,
                    mesh=grid.mesh,
                    mesh_axis=grid.mesh_axis,
                    use_pallas=grid.use_pallas,
                )
                res, wall_ms, compiles = _timed(
                    "eval/deferral_cell", lambda: provision(spec),
                    policy=policy, scenario=label, slack=slack,
                )
                cost = np.asarray(res.cost, np.float64)     # (B,)
                cr = cost / opt
                misses = int(np.asarray(res.deadline_misses).sum())
                unserved = int(np.asarray(res.unserved).sum())
                p99 = int(np.asarray(res.p99_delay).max())
                max_delay = int(np.asarray(res.max_delay).max())
                bound = _bound(policy, alpha)
                mean_cr = float(cr.mean())
                quantiles = [float(q) for q in np.quantile(cr, CR_QUANTILES)]
                cells.append(CellResult(
                    policy=policy,
                    scenario=label,
                    noise_std=0.0,
                    window=0,
                    alpha=alpha,
                    bound=bound,
                    mean_cr=mean_cr,
                    p95_cr=float(np.percentile(cr, 95)),
                    max_cr=float(cr.max()),
                    mean_cost=float(cost.mean()),
                    mean_opt_cost=float(opt.mean()),
                    bound_ok=mean_cr <= bound + grid.tol,
                    p50_cr=quantiles[CR_QUANTILES.index(0.5)],
                    cr_quantiles=quantiles,
                    slack=int(slack),
                    rule=grid.deferral_rule,
                    max_delay=max_delay,
                    p99_delay=p99,
                    deadline_misses=misses,
                    slo_ok=(
                        misses == 0 and unserved == 0 and p99 <= int(slack)
                    ),
                    wall_ms=wall_ms,
                    compiles=compiles,
                ))
    return cells, len(set(grid.deferral_policies))


def evaluate(grid: EvalGrid) -> EvalReport:
    """Run the full grid and return the scored :class:`EvalReport`.

    One device program per (policy, scenario) pair — the noise and window
    axes live inside the program — and one per scenario for the offline
    baseline.  Because every scenario shares the fleet size and trace
    shapes, the jit cache holds at most ``len(set(policies)) + 1`` entries
    for the whole run — plus one per typed policy and one typed offline
    when ``typed_groups`` is set, and one per deferral policy when
    ``deferral_slacks`` is set (slack itself is jit data; reported as
    ``expected_compiles`` and asserted by ``benchmarks/cr_eval.py
    --smoke``).  With ``grid.mesh`` set the policy
    programs run through the sharded Pallas fleet path instead
    (``_sharded_grid``, counted by the same cache watcher); the cells are
    bit-exact either way.
    """
    from repro.scenarios import generate

    grid.validate()
    t0 = time.perf_counter()
    labels = _scenario_labels(grid.scenarios)
    demands = [generate(sc, grid.n_traces, grid.n_slots) for sc in grid.scenarios]
    # one fleet size for every scenario => one compiled program per policy
    n_levels = int(max(d.max() for d in demands)) + 1
    delta = float(grid.costs.delta)
    stds = jnp.asarray(grid.noise_stds, jnp.float32)
    windows = jnp.asarray(grid.windows, jnp.int32)

    watch = CompileWatcher()
    entries_before = watch.snapshot()

    cells: list[CellResult] = []
    for si, (label, demand_np) in enumerate(zip(labels, demands)):
        demand = jnp.asarray(demand_np, jnp.int32)
        opt, _, _ = _timed(
            "eval/offline_baseline",
            lambda: provision(ProvisionSpec(
                costs=grid.costs,
                workload=Workload(demand=demand),
                policy=PolicySpec("offline"),
                n_levels=n_levels,
            )).cost,                                        # (B,)
            scenario=label,
        )
        opt = np.asarray(opt, np.float64)
        noise = PredictionNoise(
            std_frac=stds, key=jax.random.fold_in(jax.random.key(grid.seed + 1), si)
        )
        for pi, policy in enumerate(grid.policies):
            spec = ProvisionSpec(
                costs=grid.costs,
                workload=Workload(demand=demand, noise=noise),
                policy=PolicySpec(
                    policy,
                    windows=windows,
                    key=(
                        jax.random.fold_in(jax.random.key(grid.seed), pi)
                        if policy in KEYED
                        else None
                    ),
                ),
                n_levels=n_levels,
                mesh=grid.mesh,
                mesh_axis=grid.mesh_axis,
                use_pallas=grid.use_pallas,
            )
            # the whole (S, W, B) block is one device program, so its cells
            # share the block's runtime-health pair (documented on the v4
            # schema: block totals, not per-cell splits)
            cost, wall_ms, compiles = _timed(
                "eval/policy_block", lambda: provision(spec).cost,
                policy=policy, scenario=label,
            )                                               # (S, W, B)
            cost = np.asarray(cost, np.float64)
            cr = cost / opt[None, None, :]
            for s, std in enumerate(grid.noise_stds):
                for w, window in enumerate(grid.windows):
                    alpha = min(1.0, (window + 1) / delta)
                    bound = _bound(policy, alpha)
                    mean_cr = float(cr[s, w].mean())
                    quantiles = [float(q) for q in
                                 np.quantile(cr[s, w], CR_QUANTILES)]
                    cells.append(CellResult(
                        policy=policy,
                        scenario=label,
                        noise_std=float(std),
                        window=int(window),
                        alpha=alpha,
                        bound=bound,
                        mean_cr=mean_cr,
                        p95_cr=float(np.percentile(cr[s, w], 95)),
                        max_cr=float(cr[s, w].max()),
                        mean_cost=float(cost[s, w].mean()),
                        mean_opt_cost=float(opt.mean()),
                        bound_ok=(
                            bound is None
                            or mean_cr
                            <= bound + grid.tol + grid.noise_slack * float(std)
                        ),
                        p50_cr=quantiles[CR_QUANTILES.index(0.5)],
                        cr_quantiles=quantiles,
                        wall_ms=wall_ms,
                        compiles=compiles,
                    ))

    typed_cells, typed_compiles = _evaluate_typed(
        grid, labels, demands, (n_levels, grid.costs.delta_slots())
    )
    cells.extend(typed_cells)

    deferral_cells, deferral_compiles = _evaluate_deferral(
        grid, labels, demands, n_levels
    )
    cells.extend(deferral_cells)

    entries_after = watch.snapshot()
    entries_added = -1 if entries_before < 0 else entries_after - entries_before
    return EvalReport(
        grid={
            "policies": list(grid.policies),
            "scenarios": [sc.describe() for sc in grid.scenarios],
            "scenario_labels": labels,
            "noise_stds": list(grid.noise_stds),
            "windows": list(grid.windows),
            "n_traces": grid.n_traces,
            "n_slots": grid.n_slots,
            "n_levels": n_levels,
            "delta": delta,
            "seed": grid.seed,
            "tol": grid.tol,
            "noise_slack": grid.noise_slack,
            "mesh": None if grid.mesh is None else dict(grid.mesh.shape),
            "use_pallas": grid.use_pallas,
            "cr_quantiles": list(CR_QUANTILES),
            "typed_groups": (
                None if grid.typed_groups is None
                else [dataclasses.asdict(g) for g in
                      CostModel.from_groups(*grid.typed_groups).groups]
            ),
            "typed_policies": (
                None if grid.typed_groups is None else list(grid.typed_policies)
            ),
            "deferral_slacks": (
                None if grid.deferral_slacks is None
                else list(grid.deferral_slacks)
            ),
            "deferral_rule": (
                None if grid.deferral_slacks is None else grid.deferral_rule
            ),
            "deferral_policies": (
                None if grid.deferral_slacks is None
                else list(grid.deferral_policies)
            ),
        },
        cells=cells,
        backend=jax.default_backend(),
        jit_entries_added=entries_added,
        expected_compiles=(
            len(set(grid.policies)) + 1 + typed_compiles + deferral_compiles
        ),
        elapsed_s=time.perf_counter() - t0,
    )
