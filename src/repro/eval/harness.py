"""Batched competitive-ratio evaluation: the paper's headline claims as a grid.

``evaluate(EvalGrid(...))`` measures every (policy × scenario × noise-std ×
window) cell's empirical competitive ratio against the offline optimum and
checks it against the paper's worst-case bounds — A1 ≤ 2−α, A2 ≤ (e−α)/(e−1)
and A3 ≤ e/(e−1+α) *in expectation* (Theorems 2–4), delayed-off ≤ 2 — within
a statistical tolerance.

The whole grid runs as warmed batched device programs, not a Python loop per
cell: one ``provision(spec)`` call per (policy, scenario) covers the full
``(S, W, B)`` block via the ``PredictionNoise.std_frac`` sweep axis and
``PolicySpec.windows``, and every scenario shares one fleet size so shapes —
hence compiled programs — are reused across scenarios.  Common random
numbers throughout: trace ``i`` is identical in every cell, the noise sweep
shares its normal draws across std levels, and the α-sweep shares its wait
draws across windows, so CR *curves* over any axis are variance-reduced.

The result serializes to ``BENCH_provision.json`` via
:class:`repro.eval.report.EvalReport` (see ``benchmarks/cr_eval.py``).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    PAPER_COSTS,
    CostModel,
    PolicySpec,
    PredictionNoise,
    ProvisionSpec,
    Workload,
    provision,
    theoretical_ratio,
)
from repro.core.jax_provision import (
    RANDOMIZED,
    _run,
    _run_noise_sweep,
    _sharded_grid,
)
from repro.core.traces import WEEK_SLOTS
from repro.scenarios import DEFAULT_SCENARIOS, Scenario

from .report import CellResult, EvalReport


@dataclasses.dataclass(frozen=True)
class EvalGrid:
    """The declarative input of one evaluation run.

    ``costs`` must be homogeneous (scalar fields): the paper's bounds are
    stated for one Δ, and a per-level model has no single α per window.
    ``tol`` is the statistical slack on the *expectation* bound checks —
    randomized policies are evaluated over ``n_traces`` PRNG replicas, so
    the empirical mean sits within O(1/√n_traces) of its expectation.

    ``mesh``: run every online-policy cell through the sharded Pallas fleet
    path (the level axis over ``mesh_axis``; the offline baseline stays on
    the closed form, which has no slot scan).  The kernel is bit-exact
    against the lax.scan programs, so the report's cells are identical
    either way — this knob exists to run the eval grid *as* a fleet-path
    regression gate.  ``use_pallas=False`` keeps the sharded lax.scan body.
    """

    policies: tuple[str, ...] = ("A1", "A2", "A3")
    scenarios: tuple[Scenario, ...] = DEFAULT_SCENARIOS
    noise_stds: tuple[float, ...] = (0.0,)
    windows: tuple[int, ...] = (0, 1, 2, 3, 4, 5)
    n_traces: int = 16
    n_slots: int = WEEK_SLOTS
    costs: CostModel = PAPER_COSTS
    seed: int = 0
    tol: float = 0.05
    #: Extra slack per unit of prediction-noise std: the paper's bounds
    #: assume *exact* predictions (Sec. V-C only studies noise empirically),
    #: and measured degradation is ≲ 0.4·std, so a noisy cell must satisfy
    #: ``mean_cr <= bound + tol + noise_slack * noise_std``.
    noise_slack: float = 0.5
    mesh: "jax.sharding.Mesh | None" = None
    mesh_axis: str = "data"
    use_pallas: bool = True

    def validate(self) -> "EvalGrid":
        if self.costs.is_heterogeneous:
            raise ValueError(
                "EvalGrid needs a homogeneous CostModel: competitive-ratio "
                "bounds are per-Δ, and a per-level model has no single α"
            )
        if not self.policies or not self.scenarios:
            raise ValueError("EvalGrid needs at least one policy and scenario")
        if any(w < 0 for w in self.windows) or not self.windows:
            raise ValueError(f"windows must be non-negative, got {self.windows}")
        if any(s < 0 for s in self.noise_stds) or not self.noise_stds:
            raise ValueError(
                f"noise_stds must be non-negative, got {self.noise_stds}"
            )
        if self.mesh is not None and "offline" in self.policies:
            raise ValueError(
                "mesh= runs cells through the sharded fleet path, which has "
                "no offline slot scan; drop 'offline' from policies (the "
                "offline baseline is computed regardless)"
            )
        return self


def _engine_cache_size() -> int:
    """Total compiled-program count across the engine entrypoints — the
    offline/scalar path (``_run``), the noise-sweep path
    (``_run_noise_sweep``) and the sharded fleet path (``_sharded_grid``),
    each a distinct jitted function precisely so its compiles are
    observable here.  Returns -1 if the private JAX cache API is gone."""
    sizes = [getattr(f, "_cache_size", None)
             for f in (_run, _run_noise_sweep, _sharded_grid)]
    if any(s is None for s in sizes):
        return -1
    return sum(s() for s in sizes)


def _bound(policy: str, alpha: float) -> float | None:
    """Paper worst-case ratio for a policy at prediction fraction α.

    Dispatches on the policy *name* — ``theoretical_ratio`` covers the
    paper's A1/A2/A3 theorems only, and leaning on its raise type for the
    fallback is brittle (a ``ValueError`` there would silently strip the
    offline/delayedoff cells of their bounds, or crash the harness).
    """
    if policy == "offline":
        return 1.0              # hindsight optimum IS the denominator
    if policy == "delayedoff":
        return 2.0              # break-even timer Δ, classic ski-rental bound
    if policy in ("A1", "A2", "A3"):
        return theoretical_ratio(policy, alpha)
    return None


def _scenario_labels(scenarios: tuple[Scenario, ...]) -> list[str]:
    """Unique per-scenario labels (name, suffixed on collision)."""
    seen: dict[str, int] = {}
    labels = []
    for sc in scenarios:
        k = seen.get(sc.name, 0)
        seen[sc.name] = k + 1
        labels.append(sc.name if k == 0 else f"{sc.name}#{k}")
    return labels


def evaluate(grid: EvalGrid) -> EvalReport:
    """Run the full grid and return the scored :class:`EvalReport`.

    One device program per (policy, scenario) pair — the noise and window
    axes live inside the program — and one per scenario for the offline
    baseline.  Because every scenario shares the fleet size and trace
    shapes, the jit cache holds at most ``len(set(policies)) + 1`` entries
    for the whole run (reported as ``expected_compiles`` and asserted by
    ``benchmarks/cr_eval.py --smoke``).  With ``grid.mesh`` set the policy
    programs run through the sharded Pallas fleet path instead
    (``_sharded_grid``, counted by the same cache watcher); the cells are
    bit-exact either way.
    """
    from repro.scenarios import generate

    grid.validate()
    t0 = time.perf_counter()
    labels = _scenario_labels(grid.scenarios)
    demands = [generate(sc, grid.n_traces, grid.n_slots) for sc in grid.scenarios]
    # one fleet size for every scenario => one compiled program per policy
    n_levels = int(max(d.max() for d in demands)) + 1
    delta = float(grid.costs.delta)
    stds = jnp.asarray(grid.noise_stds, jnp.float32)
    windows = jnp.asarray(grid.windows, jnp.int32)

    entries_before = _engine_cache_size()

    cells: list[CellResult] = []
    for si, (label, demand_np) in enumerate(zip(labels, demands)):
        demand = jnp.asarray(demand_np, jnp.int32)
        opt = provision(ProvisionSpec(
            costs=grid.costs,
            workload=Workload(demand=demand),
            policy=PolicySpec("offline"),
            n_levels=n_levels,
        )).cost                                             # (B,)
        opt = np.asarray(jax.block_until_ready(opt), np.float64)
        noise = PredictionNoise(
            std_frac=stds, key=jax.random.fold_in(jax.random.key(grid.seed + 1), si)
        )
        for pi, policy in enumerate(grid.policies):
            cost = provision(ProvisionSpec(
                costs=grid.costs,
                workload=Workload(demand=demand, noise=noise),
                policy=PolicySpec(
                    policy,
                    windows=windows,
                    key=(
                        jax.random.fold_in(jax.random.key(grid.seed), pi)
                        if policy in RANDOMIZED
                        else None
                    ),
                ),
                n_levels=n_levels,
                mesh=grid.mesh,
                mesh_axis=grid.mesh_axis,
                use_pallas=grid.use_pallas,
            )).cost                                         # (S, W, B)
            cost = np.asarray(jax.block_until_ready(cost), np.float64)
            cr = cost / opt[None, None, :]
            for s, std in enumerate(grid.noise_stds):
                for w, window in enumerate(grid.windows):
                    alpha = min(1.0, (window + 1) / delta)
                    bound = _bound(policy, alpha)
                    mean_cr = float(cr[s, w].mean())
                    cells.append(CellResult(
                        policy=policy,
                        scenario=label,
                        noise_std=float(std),
                        window=int(window),
                        alpha=alpha,
                        bound=bound,
                        mean_cr=mean_cr,
                        p95_cr=float(np.percentile(cr[s, w], 95)),
                        max_cr=float(cr[s, w].max()),
                        mean_cost=float(cost[s, w].mean()),
                        mean_opt_cost=float(opt.mean()),
                        bound_ok=(
                            bound is None
                            or mean_cr
                            <= bound + grid.tol + grid.noise_slack * float(std)
                        ),
                    ))

    entries_after = _engine_cache_size()
    entries_added = -1 if entries_before < 0 else entries_after - entries_before
    return EvalReport(
        grid={
            "policies": list(grid.policies),
            "scenarios": [sc.describe() for sc in grid.scenarios],
            "scenario_labels": labels,
            "noise_stds": list(grid.noise_stds),
            "windows": list(grid.windows),
            "n_traces": grid.n_traces,
            "n_slots": grid.n_slots,
            "n_levels": n_levels,
            "delta": delta,
            "seed": grid.seed,
            "tol": grid.tol,
            "noise_slack": grid.noise_slack,
            "mesh": None if grid.mesh is None else dict(grid.mesh.shape),
            "use_pallas": grid.use_pallas,
        },
        cells=cells,
        backend=jax.default_backend(),
        jit_entries_added=entries_added,
        expected_compiles=len(set(grid.policies)) + 1,
        elapsed_s=time.perf_counter() - t0,
    )
