"""The evaluation report schema: one JSON artifact per competitive-ratio run.

``EvalReport`` is the serialized deliverable of :func:`repro.eval.evaluate`
— the repo's benchmark trajectory (``BENCH_provision.json``).  It is plain
dataclasses + ``json`` so the artifact diffs cleanly across PRs and loads
without JAX: every (policy, scenario, noise_std, window) grid cell carries
its empirical competitive-ratio statistics against the offline optimum and
the paper-bound verdict.  ``schema`` is versioned; bump it when a field
changes meaning, not when fields are appended.

v2 adds (all backward-compatible, defaulted on v1 loads): the per-cell CR
distribution (``p50_cr`` plus ``cr_quantiles``, the ratio values at the
fixed :data:`CR_QUANTILES` probabilities) and the typed-fleet columns
(``group_names``/``group_mean_cr``/``group_bound``/``group_bound_ok`` —
per-server-type CR statistics and verdicts, None on untyped cells).

v3 adds the deferral-slack columns (None on rigid cells and on loaded
v1/v2 artifacts): ``slack``/``rule`` identify a deferral cell (slack in
slots, queue dispatch rule), ``max_delay``/``p99_delay`` are the worst
per-trace queueing delays, ``deadline_misses`` the total expired units
over the batch, and ``slo_ok`` the latency-SLO verdict — no deadline
misses and p99 delay within the granted slack.

v4 adds the runtime-health columns ``wall_ms`` (wall-clock of the cell's
provision call, host-side, ms) and ``compiles`` (jitted engine programs
the call added, via ``repro.obs.jaxwatch.CompileWatcher``; -1 when the
cache API is unobservable).  Both are *runtime* facts, not results: they
are excluded from cell equality (``compare=False``) so determinism checks
— same grid, same cells — keep holding across machines, and they are None
on cells loaded from v1–v3 artifacts.  Cells produced by one device
program (a shared (noise × window) sweep) report the program's totals on
each of its cells.

v5 adds the report-level ``streaming`` section: one :class:`StreamingRow`
per serving chunk size, recording the ``FleetProvisioner.advance()``
stepper's plan-latency p50/p99 and the number of jit traces the whole
chunked loop needed (the steady-state-zero-recompiles claim, gated) at
T_chunk ∈ {1, 64, 1024}.  The latency columns are wall-clock facts
(``compare=False``, diffed informationally by ``bench_diff.py`` — never
gated); ``compiles``/``chunks``/``slots`` are results.  ``streaming`` is
None on artifacts loaded from v1–v4.  :meth:`EvalReport.load` still reads
every older version (pinned by ``tests/fixtures/report_v*.json``).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

SCHEMA = "repro.eval/v5"
SCHEMA_V4 = "repro.eval/v4"
SCHEMA_V3 = "repro.eval/v3"
SCHEMA_V2 = "repro.eval/v2"
SCHEMA_V1 = "repro.eval/v1"

#: the fixed probabilities ``CellResult.cr_quantiles`` reports CR values at
CR_QUANTILES = (0.05, 0.25, 0.5, 0.75, 0.95)


@dataclasses.dataclass(frozen=True)
class CellResult:
    """One grid cell: a (policy, scenario, noise_std, window) combination.

    ``mean_cr``/``p95_cr``/``max_cr`` are statistics of the per-trace ratio
    ``cost / offline_cost`` over the scenario's trace batch.  ``bound`` is
    the paper's worst-case ratio at this cell's α (``None`` when the policy
    has no stated bound), and ``bound_ok`` is the verdict
    ``mean_cr <= bound + tol + noise_slack * noise_std`` (the grid's slack
    for sampling error and prediction noise) — an *expectation* check: the randomized
    A2/A3 guarantee their ratio in expectation only, so the mean (not the
    max) is what the paper promises.

    ``p50_cr``/``cr_quantiles``: the per-trace CR distribution — the median
    plus the values at the fixed :data:`CR_QUANTILES` probabilities (None
    on cells loaded from v1 artifacts).  Typed-fleet cells additionally
    carry per-server-type columns: ``group_names`` (routing-priority
    order), ``group_mean_cr`` (mean of per-type cost over per-type offline
    cost), ``group_bound`` (the per-type ski-rental bound: 2 for AQ-det,
    e/(e−1) for AQ-rand) and ``group_bound_ok`` verdicts; the cell-level
    ``bound`` is the aggregate Albers–Quedenfeld guarantee (2d / d·e/(e−1)).

    Deferral cells (v3) carry ``slack`` (slots of deferral granted),
    ``rule`` (queue dispatch rule), the latency statistics ``max_delay`` /
    ``p99_delay`` (worst per-trace values, in slots) and
    ``deadline_misses`` (total expired units over the batch), plus the
    SLO verdict ``slo_ok``: True iff no unit missed its deadline and the
    p99 queueing delay stayed within the granted slack.  All None on
    rigid cells.

    ``wall_ms``/``compiles`` (v4) are runtime health, not results —
    ``compare=False`` keeps them out of ``==`` so two runs of the same grid
    still produce *equal* cells (the determinism and mesh-vs-plain gates
    compare whole cell lists).  None on cells from pre-v4 artifacts.
    """

    policy: str
    scenario: str
    noise_std: float
    window: int
    alpha: float
    bound: float | None
    mean_cr: float
    p95_cr: float
    max_cr: float
    mean_cost: float
    mean_opt_cost: float
    bound_ok: bool
    p50_cr: float | None = None
    cr_quantiles: list[float] | None = None
    group_names: list[str] | None = None
    group_mean_cr: list[float] | None = None
    group_bound: list[float] | None = None
    group_bound_ok: list[bool] | None = None
    slack: int | None = None
    rule: str | None = None
    max_delay: int | None = None
    p99_delay: int | None = None
    deadline_misses: int | None = None
    slo_ok: bool | None = None
    wall_ms: float | None = dataclasses.field(default=None, compare=False)
    compiles: int | None = dataclasses.field(default=None, compare=False)


@dataclasses.dataclass(frozen=True)
class StreamingRow:
    """One serving-loop measurement: ``FleetProvisioner.advance()`` driven
    at a fixed ``t_chunk`` for ``chunks`` chunks (``slots`` demand slots
    total, after a warmup chunk).  ``p50_ms``/``p99_ms`` are the stepper's
    per-call plan latencies from :class:`repro.serving.metrics.PlanMetrics`
    — wall-clock facts, excluded from equality and never gated.
    ``compiles`` counts jit traces the measured loop added: 0 is the
    steady-state claim (the warmup call owns the bucket's trace)."""

    policy: str
    t_chunk: int
    chunks: int
    slots: int
    compiles: int
    p50_ms: float | None = dataclasses.field(default=None, compare=False)
    p99_ms: float | None = dataclasses.field(default=None, compare=False)


@dataclasses.dataclass
class EvalReport:
    """The full grid's results plus enough metadata to reproduce them."""

    grid: dict
    cells: list[CellResult]
    backend: str
    jit_entries_added: int
    expected_compiles: int
    elapsed_s: float
    schema: str = SCHEMA
    streaming: list[StreamingRow] | None = None

    @property
    def bounds_ok(self) -> bool:
        """True iff every cell's empirical CR respects its paper bound —
        including, on typed cells, every per-server-type verdict, and on
        deferral cells the latency-SLO verdict."""
        return all(
            c.bound_ok
            and (c.group_bound_ok is None or all(c.group_bound_ok))
            and (c.slo_ok is None or c.slo_ok)
            for c in self.cells
        )

    def violations(self) -> list[CellResult]:
        return [
            c for c in self.cells
            if not c.bound_ok
            or (c.group_bound_ok is not None and not all(c.group_bound_ok))
            or (c.slo_ok is not None and not c.slo_ok)
        ]

    def threshold(self, c: CellResult) -> float | None:
        """The value ``bound_ok`` compared ``mean_cr`` against: the paper
        bound plus the grid's sampling tolerance and per-std noise slack."""
        if c.bound is None:
            return None
        return (
            c.bound
            + float(self.grid.get("tol", 0.0))
            + float(self.grid.get("noise_slack", 0.0)) * c.noise_std
        )

    def worst(self, n: int = 5) -> list[CellResult]:
        """The ``n`` cells with the least slack to their *effective*
        threshold (the same one ``bound_ok`` used), tightest first;
        boundless cells sort by raw mean CR."""
        def slack(c: CellResult) -> float:
            t = self.threshold(c)
            return (t - c.mean_cr) if t is not None else -c.mean_cr

        return sorted(self.cells, key=slack)[:n]

    def to_dict(self) -> dict:
        d = {
            "schema": self.schema,
            "grid": self.grid,
            "backend": self.backend,
            "jit_entries_added": self.jit_entries_added,
            "expected_compiles": self.expected_compiles,
            "elapsed_s": self.elapsed_s,
            "bounds_ok": self.bounds_ok,
            "cells": [dataclasses.asdict(c) for c in self.cells],
        }
        if self.streaming is not None:
            d["streaming"] = [dataclasses.asdict(r) for r in self.streaming]
        return d

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.to_dict(), indent=2) + "\n")
        return path

    @classmethod
    def from_dict(cls, d: dict) -> "EvalReport":
        # v1-v4 artifacts load as-is: the newer fields are all defaulted,
        # so an older cell dict simply leaves them None (back-compat
        # contract, pinned by tests/fixtures/report_v*.json)
        readable = (SCHEMA, SCHEMA_V4, SCHEMA_V3, SCHEMA_V2, SCHEMA_V1)
        if d.get("schema") not in readable:
            raise ValueError(
                f"report schema {d.get('schema')!r} != expected {SCHEMA!r} "
                f"(or the readable {', '.join(map(repr, readable[1:]))})"
            )
        return cls(
            grid=d["grid"],
            cells=[CellResult(**c) for c in d["cells"]],
            backend=d["backend"],
            jit_entries_added=d["jit_entries_added"],
            expected_compiles=d["expected_compiles"],
            elapsed_s=d["elapsed_s"],
            schema=d["schema"],
            streaming=(
                None if d.get("streaming") is None
                else [StreamingRow(**r) for r in d["streaming"]]
            ),
        )

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "EvalReport":
        return cls.from_dict(json.loads(pathlib.Path(path).read_text()))

    def summary_lines(self) -> list[str]:
        """Human-readable per-cell table (policy-major, CSV-ish)."""
        lines = ["policy,scenario,noise,window,alpha,mean_cr,p50_cr,p95_cr,bound,ok"]
        for c in self.cells:
            b = "-" if c.bound is None else f"{c.bound:.4f}"
            p50 = "-" if c.p50_cr is None else f"{c.p50_cr:.4f}"
            line = (
                f"{c.policy},{c.scenario},{c.noise_std:g},{c.window},"
                f"{c.alpha:.2f},{c.mean_cr:.4f},{p50},{c.p95_cr:.4f},{b},"
                f"{'ok' if c.bound_ok else 'VIOLATED'}"
            )
            if c.group_mean_cr is not None:
                per_type = " ".join(
                    f"{n}={v:.3f}{'' if ok else '!'}" for n, v, ok in
                    zip(c.group_names, c.group_mean_cr, c.group_bound_ok)
                )
                line += f",types[{per_type}]"
            if c.slo_ok is not None:
                line += (
                    f",defer[{c.rule} slack={c.slack} p99={c.p99_delay} "
                    f"miss={c.deadline_misses} "
                    f"{'slo_ok' if c.slo_ok else 'SLO_VIOLATED'}]"
                )
            lines.append(line)
        if self.streaming:
            lines.append(
                "streaming: policy,t_chunk,chunks,slots,p50_ms,p99_ms,compiles"
            )
            for r in self.streaming:
                p50 = "-" if r.p50_ms is None else f"{r.p50_ms:.3f}"
                p99 = "-" if r.p99_ms is None else f"{r.p99_ms:.3f}"
                lines.append(
                    f"streaming: {r.policy},{r.t_chunk},{r.chunks},{r.slots},"
                    f"{p50},{p99},{r.compiles}"
                )
        return lines
