"""The evaluation report schema: one JSON artifact per competitive-ratio run.

``EvalReport`` is the serialized deliverable of :func:`repro.eval.evaluate`
— the repo's benchmark trajectory (``BENCH_provision.json``).  It is plain
dataclasses + ``json`` so the artifact diffs cleanly across PRs and loads
without JAX: every (policy, scenario, noise_std, window) grid cell carries
its empirical competitive-ratio statistics against the offline optimum and
the paper-bound verdict.  ``schema`` is versioned; bump it when a field
changes meaning, not when fields are appended.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

SCHEMA = "repro.eval/v1"


@dataclasses.dataclass(frozen=True)
class CellResult:
    """One grid cell: a (policy, scenario, noise_std, window) combination.

    ``mean_cr``/``p95_cr``/``max_cr`` are statistics of the per-trace ratio
    ``cost / offline_cost`` over the scenario's trace batch.  ``bound`` is
    the paper's worst-case ratio at this cell's α (``None`` when the policy
    has no stated bound), and ``bound_ok`` is the verdict
    ``mean_cr <= bound + tol + noise_slack * noise_std`` (the grid's slack
    for sampling error and prediction noise) — an *expectation* check: the randomized
    A2/A3 guarantee their ratio in expectation only, so the mean (not the
    max) is what the paper promises.
    """

    policy: str
    scenario: str
    noise_std: float
    window: int
    alpha: float
    bound: float | None
    mean_cr: float
    p95_cr: float
    max_cr: float
    mean_cost: float
    mean_opt_cost: float
    bound_ok: bool


@dataclasses.dataclass
class EvalReport:
    """The full grid's results plus enough metadata to reproduce them."""

    grid: dict
    cells: list[CellResult]
    backend: str
    jit_entries_added: int
    expected_compiles: int
    elapsed_s: float
    schema: str = SCHEMA

    @property
    def bounds_ok(self) -> bool:
        """True iff every cell's empirical CR respects its paper bound."""
        return all(c.bound_ok for c in self.cells)

    def violations(self) -> list[CellResult]:
        return [c for c in self.cells if not c.bound_ok]

    def threshold(self, c: CellResult) -> float | None:
        """The value ``bound_ok`` compared ``mean_cr`` against: the paper
        bound plus the grid's sampling tolerance and per-std noise slack."""
        if c.bound is None:
            return None
        return (
            c.bound
            + float(self.grid.get("tol", 0.0))
            + float(self.grid.get("noise_slack", 0.0)) * c.noise_std
        )

    def worst(self, n: int = 5) -> list[CellResult]:
        """The ``n`` cells with the least slack to their *effective*
        threshold (the same one ``bound_ok`` used), tightest first;
        boundless cells sort by raw mean CR."""
        def slack(c: CellResult) -> float:
            t = self.threshold(c)
            return (t - c.mean_cr) if t is not None else -c.mean_cr

        return sorted(self.cells, key=slack)[:n]

    def to_dict(self) -> dict:
        return {
            "schema": self.schema,
            "grid": self.grid,
            "backend": self.backend,
            "jit_entries_added": self.jit_entries_added,
            "expected_compiles": self.expected_compiles,
            "elapsed_s": self.elapsed_s,
            "bounds_ok": self.bounds_ok,
            "cells": [dataclasses.asdict(c) for c in self.cells],
        }

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.to_dict(), indent=2) + "\n")
        return path

    @classmethod
    def from_dict(cls, d: dict) -> "EvalReport":
        if d.get("schema") != SCHEMA:
            raise ValueError(
                f"report schema {d.get('schema')!r} != expected {SCHEMA!r}"
            )
        return cls(
            grid=d["grid"],
            cells=[CellResult(**c) for c in d["cells"]],
            backend=d["backend"],
            jit_entries_added=d["jit_entries_added"],
            expected_compiles=d["expected_compiles"],
            elapsed_s=d["elapsed_s"],
            schema=d["schema"],
        )

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "EvalReport":
        return cls.from_dict(json.loads(pathlib.Path(path).read_text()))

    def summary_lines(self) -> list[str]:
        """Human-readable per-cell table (policy-major, CSV-ish)."""
        lines = ["policy,scenario,noise,window,alpha,mean_cr,p95_cr,bound,ok"]
        for c in self.cells:
            b = "-" if c.bound is None else f"{c.bound:.4f}"
            lines.append(
                f"{c.policy},{c.scenario},{c.noise_std:g},{c.window},"
                f"{c.alpha:.2f},{c.mean_cr:.4f},{c.p95_cr:.4f},{b},"
                f"{'ok' if c.bound_ok else 'VIOLATED'}"
            )
        return lines
