"""`DeferralSpec`: the slack/deadline model attached to a :class:`Workload`.

Declares *how long arriving work may wait*: a scalar slack (every batch
may wait that many slots) or a per-slot ``(T,)`` slack vector
(heterogeneous deadlines — batch arriving at ``t`` must finish by
``t + slack[t]``), plus the dispatch rule the queue uses and an optional
per-slot service cap.  Like the other spec pytrees, *values* (the slack
array) are jit data while *shape-like* knobs (rule, cap, the static
bucket bound) are metadata, so sweeping slack values never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .queue_scan import defer_demand as _defer_demand
from .queue_scan import queue_scan as _queue_scan

#: dispatch rules understood by the queue scan
RULES = ("EDF", "FIFO", "SPT", "LPT")


@dataclasses.dataclass(frozen=True)
class DeferralSpec:
    """Slack/deadline model for deferrable work.

    Attributes:
        slack: scalar or per-slot ``(T,)`` integer slots of slack.  The
            batch arriving at slot ``t`` must be served by ``t + slack``
            (clipped to the trace horizon).  ``0`` means rigid — the
            deferred profile is bit-exact with the raw demand.  Jit
            *data*: sweeping slack values reuses the compiled program.
            Per-slot slack keeps the zero-miss guarantee only under
            *monotone effective deadlines* (``t + slack[t]``
            non-decreasing — later work never jumps the queue); the
            transform satisfies the prefix envelope either way, but for
            non-monotone deadlines that is weaker than Hall's interval
            condition and the metrics may report genuine misses.
        rule: dispatch rule for the measurement queue, one of
            :data:`RULES`.  Static (part of the compile key).
        cap: optional per-slot ceiling on the deferred service profile
            (e.g. a fleet-size limit).  Displaced work re-enters the
            backlog rather than being dropped.  Static.
        max_slack: static bucket/scan bound, ``>= max(slack)``.  Usually
            inferred from a concrete ``slack``; must be given explicitly
            when ``slack`` is a tracer (inside jit/vmap), mirroring the
            engine's ``n_levels`` convention.
    """

    slack: Any = 0
    rule: str = "EDF"
    cap: int | None = None
    max_slack: int | None = None

    def validate(self) -> "DeferralSpec":
        if self.rule not in RULES:
            raise ValueError(
                f"unknown dispatch rule {self.rule!r}; expected one of {RULES}"
            )
        if self.cap is not None and int(self.cap) <= 0:
            raise ValueError(f"cap must be positive, got {self.cap}")
        bound = self.bound()
        if bound < 0:
            raise ValueError(f"slack must be non-negative, got {self.slack}")
        if np.ndim(self.slack) > 1:
            raise ValueError(
                f"slack must be a scalar or a (T,) vector, got shape "
                f"{np.shape(self.slack)}"
            )
        return self

    def bound(self) -> int:
        """The static slack bound (scan length / bucket count - 2).

        Derived from a concrete ``slack``; under tracing ``max_slack``
        must be set explicitly (clear error otherwise, like ``n_levels``).
        """
        if self.max_slack is not None:
            return int(self.max_slack)
        if isinstance(self.slack, jax.core.Tracer):
            raise ValueError(
                "DeferralSpec.slack is a tracer; pass max_slack= explicitly "
                "when calling provision() under jit/vmap"
            )
        return int(np.max(np.asarray(self.slack)))

    def slack_for(self, n_slots: int) -> jax.Array:
        """The per-slot slack vector, broadcast to ``(n_slots,)`` int32."""
        s = jnp.asarray(self.slack, jnp.int32)
        if s.ndim == 1 and s.shape[0] != n_slots:
            raise ValueError(
                f"per-slot slack has length {s.shape[0]} but the workload "
                f"has {n_slots} slots"
            )
        return jnp.broadcast_to(s, (n_slots,))

    def apply(self, demand: jax.Array) -> jax.Array:
        """Deferred service profile ``ã`` for ``(T,)`` or ``(B, T)`` demand."""
        demand = jnp.asarray(demand, jnp.int32)
        slack_t = self.slack_for(demand.shape[-1])

        def one(row):
            return _defer_demand(row, slack_t, cap=self.cap)

        if demand.ndim == 1:
            return one(demand)
        flat = demand.reshape(-1, demand.shape[-1])
        return jax.vmap(one)(flat).reshape(demand.shape)

    def metrics(self, arrivals: jax.Array, x: jax.Array) -> dict:
        """Queue metrics for true ``arrivals`` under capacity profile ``x``.

        ``arrivals``: ``(T,)`` or ``(B, T)``; ``x``: any shape broadcastable
        to ``(..., B, T)`` (e.g. the engine's ``(S, W, B, T)`` sweep grid).
        Leaves keep the leading sweep axes: ``backlog`` is ``(..., T)``,
        scalars (misses/unserved/max_delay/p99_delay) are ``(...,)``.
        """
        x = jnp.asarray(x, jnp.int32)
        a = jnp.broadcast_to(jnp.asarray(arrivals, jnp.int32), x.shape)
        K = self.bound()
        T = x.shape[-1]
        slack_t = self.slack_for(T)

        def one(a_row, x_row):
            return _queue_scan(
                a_row, x_row, slack_t, rule=self.rule, max_slack=K
            )

        out = jax.vmap(one)(a.reshape(-1, T), x.reshape(-1, T))
        lead = x.shape[:-1]
        return {
            key: val.reshape(lead + val.shape[1:]) for key, val in out.items()
        }


jax.tree_util.register_dataclass(
    DeferralSpec, data_fields=["slack"], meta_fields=["rule", "cap", "max_slack"]
)
