"""Vectorized slack-aware queueing: water-filled deferral + a batched queue scan.

Two jitted primitives replace the heap a discrete-event queue simulator
would use (Adnan et al., "Dynamic Deferral of Workload for Capacity
Provisioning in Data Centers", arXiv 1109.3839, PAPERS.md):

  * :func:`defer_demand` — the *defer-then-provision* transform.  Arrivals
    ``a(t)`` with per-job slack become the water-filled service profile
    ``ã(t)``: the least capacity that still meets every deadline, computed
    from two prefix-sum envelopes (cumulative arrivals ``A`` above,
    cumulative work due ``L`` below) with an optimal-available rate rule —
    at each slot serve ``max_k ceil((L(t+k) − S(t−1)) / (k+1))`` over the
    remaining horizon.  Peaks flatten by up to ``slack + 1``× (a burst's
    work spreads over its whole deadline span) and the deferred remainder
    rides the next valley.  Zero slack makes every envelope tight, so
    ``ã ≡ a`` **bit-exactly** — the rigid path is the fixed point, not a
    special case (property-gated in ``tests/test_deferral.py``).

  * :func:`queue_scan` — the measurement half.  Given true arrivals and a
    capacity profile ``x(t)`` (typically a provisioned schedule), simulate
    the queue under a dispatch rule and return backlog/latency metrics.
    Instead of a heap, the backlog lives in *age buckets*: ``w[j]`` is the
    unserved work of the batch that arrived ``j`` slots ago (``j ≤
    max_slack``, plus one merged bucket for late work), so each slot is a
    shift + a **sorted prefix-sum waterfill**: order buckets by the rule's
    priority key, serve ``clip(x(t) − work_ahead, 0, w)`` cumulatively,
    scatter back.  Everything is fixed-shape ``jnp`` ops inside one
    ``lax.scan``, so the whole thing jits, vmaps over any ``(S, W, B)``
    sweep grid, and composes with both the lax.scan and Pallas fleet paths
    (which only ever see the deferred profile).

Dispatch rules (:data:`repro.deferral.spec.RULES`, idiom from anafor's
LPT/SPT stream schedulers — SNIPPETS.md):

  * ``EDF`` — earliest deadline first among live batches; expired work is
    served last (it cannot be saved, so it must not starve a tight batch).
    For unit jobs this greedy is throughput-optimal, hence the
    EDF-dominance law: no rule misses fewer deadlines.
  * ``FIFO`` — strict arrival order, expired work included (it is oldest,
    so it stays head-of-line — the honest queue).
  * ``SPT`` / ``LPT`` — smallest / largest remaining batch first among
    live batches (shortest/longest processing time on the per-slot arrival
    batches), expired work last.

Metric conventions: a unit *misses* its deadline when it is still queued
as its remaining slack crosses below zero (counted exactly once, at
expiry; late units stay queued — work is conserved, never dropped, so
``served + unserved == arrived`` always).  Queueing delay of a served
unit is its age in slots at service time; delays beyond ``max_slack + 1``
are lumped into the merged late bucket (exact wherever deadlines can
still be met, which is where the SLO verdict looks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def due_envelope(a: jax.Array, slack: jax.Array | int) -> jax.Array:
    """``L(t)``: cumulative work whose deadline is at or before slot ``t``.

    ``a``: (T,) integer arrivals; ``slack``: scalar or (T,) slots of slack
    for the batch arriving at each slot (deadline ``t + slack(t)``, clipped
    to the horizon — all work must finish in-trace, mirroring the engine's
    forced ``x(T) = a(T)`` boundary).  A plain scatter-add + prefix sum, so
    it traces under jit/vmap with no sorting.
    """
    T = a.shape[0]
    dead = jnp.clip(jnp.arange(T) + jnp.asarray(slack, jnp.int32), 0, T - 1)
    return jnp.cumsum(jax.ops.segment_sum(a, dead, num_segments=T))


@functools.partial(jax.jit, static_argnames=("cap",))
def defer_demand(
    a: jax.Array,
    slack: jax.Array | int,
    *,
    cap: int | None = None,
) -> jax.Array:
    """Water-filled service profile ``ã``: (T,) int32, the deferred demand.

    The optimal-available rate rule over the deadline envelope: with
    ``S(t−1)`` work served so far, slot ``t`` serves

        ``ã(t) = min(A(t) − S,  max_{k=0..T−1−t} ⌈(L(t+k) − S)/(k+1)⌉)``

    — the smallest rate that, held for ``k+1`` slots, still clears every
    pending deadline, never exceeding what has actually arrived (``A`` =
    cumulative arrivals).  The density max ranges over the *full* remaining
    horizon (the OA speed-scaling rule), so deadline mass the trace
    boundary concentrates at ``T−1`` is anticipated from the first slot
    and spread at the mean rate instead of surfacing as a late catch-up
    burst.  O(T²) per trace, which is fine at planning horizons
    (provisioning slots, not the streaming kernel's microsecond ticks).

    ``cap`` additionally clamps ``ã(t) ≤ cap`` — a fleet-capacity ceiling.
    A binding cap makes laziness unsafe (deferred work could strand beyond
    the horizon), so the lower envelope is first tightened to
    ``L'(t) = max_{j≥t} (L(j) − cap·(j−t))`` — serve early enough that the
    remaining capped slots can still absorb everything due.  Work the cap
    displaces thus re-enters the backlog and is served in *earlier* or
    later slots, never dropped: ``sum(ã) == sum(a)`` whenever a feasible
    schedule exists at all (the conservation law
    ``make_workload(clip_to=...)`` leans on).  An infeasible cap (arrivals
    outrun ``cap`` for longer than slack covers) leaves a shortfall;
    :func:`queue_scan` reports it as misses/unserved.

    With ``slack = 0`` and no cap the causality bound is also the ``k=0``
    density term, so ``ã == a`` bit-exactly.
    """
    T = a.shape[0]
    a = jnp.asarray(a, jnp.int32)
    A = jnp.cumsum(a)
    L = due_envelope(a, slack)
    if cap is not None:
        j = jnp.arange(T, dtype=L.dtype)
        L = jnp.flip(jax.lax.cummax(jnp.flip(L - cap * j))) + cap * j
    # pad with the total so out-of-horizon terms are dominated, not special
    Lpad = jnp.concatenate([L, jnp.full((T,), L[-1], L.dtype)])
    k = jnp.arange(T)

    def step(S, t):
        fut = jax.lax.dynamic_slice(Lpad, (t,), (T,))
        need = (jnp.maximum(fut - S, 0) + k) // (k + 1)     # integer ceil
        c = jnp.minimum(need.max(), A[t] - S)               # causality
        if cap is not None:
            c = jnp.minimum(c, jnp.int32(cap))
        c = jnp.maximum(c, 0)
        return S + c, c

    _, out = jax.lax.scan(step, jnp.zeros((), jnp.int32), jnp.arange(T))
    return out.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Streaming (carry-based) twins: O(slack) state, chunk-size invariant
# ---------------------------------------------------------------------------

def defer_stream_init(slack: int) -> dict:
    """Fresh carry for :func:`defer_stream`: ``awin[j]`` = cumulative
    arrivals through ``j + 1`` slots ago (all zero before the trace) and
    ``served`` = total work served so far."""
    K = int(slack)
    return {
        "awin": jnp.zeros((max(K, 1),), jnp.int32),
        "served": jnp.zeros((), jnp.int32),
    }


def defer_stream(a, state, *, slack: int, cap: int | None = None, valid=None):
    """Causal streaming deferral: one chunk of arrivals → service profile.

    The stepper's online twin of :func:`defer_demand`.  The batch arriving
    at ``u`` is due by ``u + slack``, so by slot ``t`` the work due within
    ``k`` more slots is ``A(t − slack + k)`` — *cumulative arrivals only*,
    no future terms — and the slot serves the smallest rate that clears
    every known deadline::

        c(t) = clip(min(A(t) − S, max_{k ≤ slack} ⌈(A(t−slack+k) − S)/(k+1)⌉),
                    0, cap)

    The carry is the ``slack``-deep cumulative-arrival window plus the
    served total — O(slack) state, so the profile is *chunk-size
    invariant*: any split of the arrival stream into ``defer_stream`` calls
    yields identical output (property-gated in tests/test_streaming.py).
    Uncapped, every deadline is met (the ``k = 0`` term forces all due work
    out), and ``slack = 0`` returns the arrivals bit-exactly.

    This is deliberately *not* :func:`defer_demand`, which implements the
    hindsight OA rule: its density max ranges over the full remaining
    horizon, so it pre-spreads bursts it has not seen yet (anticipative
    even uncapped — e.g. arrivals ``[3, 0, 300]`` with ``slack = 2`` serve
    3 units at ``t = 0`` under OA but only 1 causally).  Batch evaluation
    keeps the OA profile; live serving gets this honest causal rule
    (docs/deferral.md).

    ``a``: (Tc,) int32 chunk of arrivals; ``valid``: optional (Tc,) bool —
    masked slots serve nothing and freeze the carry (the stepper's pow2 pad
    tail).  Returns ``(deferred (Tc,) int32, new_state)``.
    """
    K = int(slack)
    a = jnp.asarray(a, jnp.int32)
    Tc = a.shape[0]
    v = jnp.ones((Tc,), bool) if valid is None else jnp.asarray(valid, bool)
    if K == 0:
        out = jnp.where(v, a, 0)
        new = {
            "awin": state["awin"],
            "served": state["served"] + out.sum(),
        }
        return out, new
    k = jnp.arange(K + 1, dtype=jnp.int32)

    def step(carry, inp):
        awin, S = carry
        a_t, v_t = inp
        A_t = awin[0] + a_t                    # cumulative arrivals through t
        lvals = jnp.concatenate([awin[::-1], A_t[None]])   # A(t-K) .. A(t)
        need = (jnp.maximum(lvals - S, 0) + k) // (k + 1)  # integer ceil
        c = jnp.minimum(need.max(), A_t - S)
        if cap is not None:
            c = jnp.minimum(c, jnp.int32(cap))
        c = jnp.maximum(c, 0)
        c = jnp.where(v_t, c, 0)
        awin = jnp.where(v_t, jnp.concatenate([A_t[None], awin[:-1]]), awin)
        return (awin, S + c), c

    (awin, S), out = jax.lax.scan(
        step, (state["awin"], state["served"]), (a, v)
    )
    return out.astype(jnp.int32), {"awin": awin, "served": S}


def queue_stream_init(max_slack: int) -> dict:
    """Fresh carry for :func:`queue_stream`: empty age buckets, zero miss
    counter, zero served-by-age histogram."""
    nb = int(max_slack) + 2
    return {
        "w": jnp.zeros((nb,), jnp.int32),
        "miss": jnp.zeros((), jnp.int32),
        "hist": jnp.zeros((nb,), jnp.int32),
    }


def queue_stream(a, x, state, *, rule: str = "EDF", max_slack: int, valid=None):
    """One chunk of the deferral queue, carry in age buckets.

    The streaming twin of :func:`queue_scan` for *scalar* slack: identical
    per-slot dynamics (age → expire-count → admit → sorted prefix-sum
    waterfill), but the ``(w, miss, hist)`` state crosses call boundaries,
    so a mid-flight backlog split by a chunk boundary is continued exactly
    (chunk-size invariance, property-gated).  The end-of-horizon
    correction — counting leftovers whose deadline lands exactly at the
    final slot — is **not** applied here (the trace has not ended); call
    :func:`queue_stream_finalize` when it has.

    ``a``/``x``: (Tc,) int32 arrivals and capacity; ``valid``: optional
    (Tc,) bool pad mask (masked slots freeze the carry and repeat the
    previous backlog).  Returns ``(backlog (Tc,) int32, new_state)``.
    """
    K = int(max_slack)
    nb = K + 2
    a = jnp.asarray(a, jnp.int32)
    x = jnp.asarray(x, jnp.int32)
    Tc = a.shape[0]
    v = jnp.ones((Tc,), bool) if valid is None else jnp.asarray(valid, bool)
    ages = jnp.arange(nb, dtype=jnp.int32)
    rem = jnp.concatenate(
        [jnp.int32(K) - ages[: K + 1], jnp.full((1,), -1, jnp.int32)]
    )
    # EDF/FIFO keys depend only on ages under scalar slack, so the serve
    # order is one host-side lexsort; SPT/LPT re-key per slot (bucket sizes)
    static_order = rule in ("EDF", "FIFO")
    if static_order:
        prim, sec = _priority(rule, None, rem, rem >= 0, ages, nb)
        order0 = jnp.lexsort((sec, prim))

    def step(carry, inp):
        w, miss, hist = carry
        a_t, x_t, v_t = inp
        miss2 = miss + w[K]            # last chance was the previous slot
        w_new = jnp.concatenate([a_t[None], w[:-1]]).at[nb - 1].add(w[nb - 1])
        if static_order:
            order = order0
        else:
            p, s = _priority(rule, w_new, rem, rem >= 0, ages, nb)
            order = jnp.lexsort((s, p))
        ws = w_new[order]
        ahead = jnp.cumsum(ws) - ws
        served_sorted = jnp.clip(x_t - ahead, 0, ws)
        served = jnp.zeros_like(w_new).at[order].set(served_sorted)
        w_after = w_new - served
        w_out = jnp.where(v_t, w_after, w)
        miss_out = jnp.where(v_t, miss2, miss)
        hist_out = jnp.where(v_t, hist + served, hist)
        return (w_out, miss_out, hist_out), w_out.sum()

    (w, miss, hist), backlog = jax.lax.scan(
        step, (state["w"], state["miss"], state["hist"]), (a, x, v)
    )
    return backlog, {"w": w, "miss": miss, "hist": hist}


def queue_stream_finalize(state, *, max_slack: int) -> dict:
    """Close the horizon on a :func:`queue_stream` carry: apply
    :func:`queue_scan`'s end-of-trace correction (units due exactly at the
    final slot plus merged-late leftovers count as misses) and derive the
    delay metrics from the served-by-age histogram.  Returns the same
    metric names as :func:`queue_scan` minus the per-slot ``backlog``.
    """
    K = int(max_slack)
    nb = K + 2
    hist = state["hist"]
    ages = jnp.arange(nb, dtype=jnp.int32)
    miss = state["miss"] + state["w"][K] + state["w"][nb - 1]
    total = hist.sum()
    cum = jnp.cumsum(hist)
    p99 = jnp.argmax(cum >= jnp.ceil(0.99 * total)).astype(jnp.int32)
    return {
        "served_by_age": hist,
        "deadline_misses": miss,
        "unserved": state["w"].sum(),
        "max_delay": jnp.maximum(jnp.max(jnp.where(hist > 0, ages, -1)), 0),
        "p99_delay": p99,
    }


def _priority(rule: str, w, rem, live, ages, n_buckets):
    """(primary, secondary) sort keys, smaller served first.

    Expired work (``~live``) sorts after every live batch for all rules
    except FIFO, whose strict arrival order keeps it head-of-line.  The
    secondary key breaks ties oldest-first, so every rule is a total,
    deterministic order.
    """
    late = jnp.int32(n_buckets + 1)
    if rule == "EDF":
        prim = jnp.where(live, rem, late)
    elif rule == "FIFO":
        prim = -ages                               # oldest first, late included
    elif rule == "SPT":
        prim = jnp.where(live, w, jnp.int32(2**30))
    elif rule == "LPT":
        prim = jnp.where(live, -w, jnp.int32(2**30))
    else:  # pragma: no cover - guarded by DeferralSpec.validate
        raise ValueError(f"unknown dispatch rule {rule!r}")
    return prim, (n_buckets - 1) - ages


@functools.partial(jax.jit, static_argnames=("rule", "max_slack"))
def queue_scan(
    a: jax.Array,
    x: jax.Array,
    slack: jax.Array | int,
    *,
    rule: str = "EDF",
    max_slack: int,
) -> dict:
    """Simulate the deferral queue for one (arrivals, capacity) pair.

    ``a``/``x``: (T,) int32 arrivals and per-slot service capacity;
    ``slack``: scalar or (T,) slack of each slot's arrival batch;
    ``max_slack``: static bucket bound (≥ the largest slack).  Each slot:
    age the buckets (counting units whose deadline just expired), admit the
    new batch, then serve ``x(t)`` units by the rule's sorted prefix-sum
    waterfill.  Late work stays queued at the rule's late priority until
    served or the trace ends.

    Returns a dict of device arrays:

    - ``backlog`` (T,): units still queued at the end of each slot;
    - ``served_by_age`` (max_slack + 2,): served-unit delay histogram
      (index = slots waited; the last bucket lumps delays > max_slack);
    - ``deadline_misses`` (): units that were still queued when their
      deadline expired (each counted once);
    - ``unserved`` (): units left at the horizon (0 whenever the capacity
      profile covers the deferred demand — work conservation);
    - ``max_delay`` / ``p99_delay`` (): the max and 99th-percentile
      queueing delay over all served units, in slots.
    """
    T = a.shape[0]
    K = max_slack
    nb = K + 2                                    # ages 0..K + merged late
    a = jnp.asarray(a, jnp.int32)
    x = jnp.asarray(x, jnp.int32)
    slack_t = jnp.broadcast_to(jnp.asarray(slack, jnp.int32), (T,))
    # spad[t + K - j] = slack of the batch that arrived at t - j
    spad = jnp.concatenate([jnp.zeros((K,), jnp.int32), slack_t])
    ages = jnp.arange(nb, dtype=jnp.int32)

    def slack_window(t):
        """slack of the batch aged j at slot t, j = 0..K (junk for t-j < 0,
        where the bucket is empty anyway)."""
        return jax.lax.dynamic_slice(spad, (t,), (K + 1,))[::-1]

    def step(carry, t):
        w, miss, hist = carry
        # units whose last service chance was slot t-1 and are still queued
        prev_rem = slack_window(t - 1) - ages[: K + 1]
        miss = miss + jnp.sum(jnp.where(prev_rem == 0, w[: K + 1], 0))
        # age every bucket; ages past K merge into the late bucket
        w_new = jnp.concatenate([a[t][None], w[:-1]]).at[nb - 1].add(w[nb - 1])
        rem = jnp.concatenate(
            [slack_window(t) - ages[: K + 1], jnp.full((1,), -1, jnp.int32)]
        )
        prim, sec = _priority(rule, w_new, rem, rem >= 0, ages, nb)
        order = jnp.lexsort((sec, prim))
        ws = w_new[order]
        ahead = jnp.cumsum(ws) - ws
        served_sorted = jnp.clip(x[t] - ahead, 0, ws)
        served = jnp.zeros_like(w_new).at[order].set(served_sorted)
        w_after = w_new - served
        return (w_after, miss, hist + served), w_after.sum()

    init = (
        jnp.zeros((nb,), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((nb,), jnp.int32),
    )
    (w_final, miss, hist), backlog = jax.lax.scan(step, init, jnp.arange(T))
    # deadlines that expire exactly at the horizon never age past it inside
    # the scan; count their leftovers here
    final_rem = slack_window(T - 1) - ages[: K + 1]
    miss = miss + jnp.sum(jnp.where(final_rem <= 0, w_final[: K + 1], 0))
    miss = miss + w_final[nb - 1]                 # merged late leftovers
    total = hist.sum()
    cum = jnp.cumsum(hist)
    p99 = jnp.argmax(cum >= jnp.ceil(0.99 * total)).astype(jnp.int32)
    return {
        "backlog": backlog,
        "served_by_age": hist,
        "deadline_misses": miss,
        "unserved": w_final.sum(),
        "max_delay": jnp.maximum(jnp.max(jnp.where(hist > 0, ages, -1)), 0),
        "p99_delay": p99,
    }
