"""repro.deferral — slack-aware workload deferral as a batched JAX layer.

The subsystem has two halves: :func:`defer_demand` turns arrivals + slack
into the water-filled service profile the provisioning engine runs on
(defer-then-provision), and :func:`queue_scan` measures the resulting
queue — backlog, queueing delay, deadline misses — under a dispatch rule.
:class:`DeferralSpec` is the user-facing model attached to
``Workload(deferral=...)``; see ``docs/deferral.md``.
"""
from .queue_scan import defer_demand, due_envelope, queue_scan
from .spec import RULES, DeferralSpec

__all__ = [
    "DeferralSpec",
    "RULES",
    "defer_demand",
    "due_envelope",
    "queue_scan",
]
