"""repro.deferral — slack-aware workload deferral as a batched JAX layer.

The subsystem has two halves: :func:`defer_demand` turns arrivals + slack
into the water-filled service profile the provisioning engine runs on
(defer-then-provision), and :func:`queue_scan` measures the resulting
queue — backlog, queueing delay, deadline misses — under a dispatch rule.
:class:`DeferralSpec` is the user-facing model attached to
``Workload(deferral=...)``; see ``docs/deferral.md``.

The streaming serving path (``FleetProvisioner.advance``) uses the
carry-based twins — :func:`defer_stream` (the honest *causal* deferral
rule, O(slack) state) and :func:`queue_stream` /
:func:`queue_stream_finalize` (the same age-bucket queue with the carry
crossing call boundaries) — both chunk-size invariant by construction.
"""
from .queue_scan import (
    defer_demand,
    defer_stream,
    defer_stream_init,
    due_envelope,
    queue_scan,
    queue_stream,
    queue_stream_finalize,
    queue_stream_init,
)
from .spec import RULES, DeferralSpec

__all__ = [
    "DeferralSpec",
    "RULES",
    "defer_demand",
    "defer_stream",
    "defer_stream_init",
    "due_envelope",
    "queue_scan",
    "queue_stream",
    "queue_stream_finalize",
    "queue_stream_init",
]
