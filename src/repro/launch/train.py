"""Training launcher: ``--arch <id>`` selects any assigned architecture.

Local mode (default) trains the reduced config on the host mesh with the
full fault-tolerant loop (checkpoints, auto-resume, compression).  With
``--dry-run`` it lowers/compiles the FULL config's train step for the
production mesh instead (no allocation).

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --dry-run
"""
from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        import os

        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=512"
        ).strip()
        from pathlib import Path

        from repro.launch.dryrun import run_cell

        rep = run_cell(args.arch, args.shape, args.multi_pod,
                       Path("reports/dryrun"))
        print(f"compiled {args.arch} x {args.shape}: "
              f"flops/dev={rep['hlo_flops_per_device']:.3e}")
        return 0

    from repro.configs import get_config
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch, reduced=True).replace(remat="none")
    tcfg = TrainerConfig(
        total_steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, grad_compression=args.compress_grads,
    )
    out = Trainer(cfg, tcfg).run()
    if out["history"]:
        print(f"final loss: {out['history'][-1][1]:.4f} "
              f"@ step {out['final_step']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
