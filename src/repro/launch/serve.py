"""Serving launcher: session stream -> paper autoscaler (+ real generation).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --policy A1 --alpha 0.5 [--real-tokens] [--dry-run --shape decode_32k]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default="A1", choices=["A1", "A2", "A3", "offline"])
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--slots", type=int, default=60)
    ap.add_argument("--concurrency", type=float, default=4.0)
    ap.add_argument("--real-tokens", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        import os

        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=512"
        ).strip()
        from pathlib import Path

        from repro.launch.dryrun import run_cell

        rep = run_cell(args.arch, args.shape, args.multi_pod,
                       Path("reports/dryrun"))
        print(f"compiled {args.arch} x {args.shape}: "
              f"flops/dev={rep['hlo_flops_per_device']:.3e}")
        return 0

    from repro.configs import get_config
    from repro.core import CostModel
    from repro.data.requests import generate_sessions
    from repro.serving import InferenceEngine, make_window_max_predictor, run_cluster

    costs = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
    trace = generate_sessions(np.random.default_rng(0), n_slots=args.slots,
                              mean_concurrency=args.concurrency)
    factory = None
    if args.real_tokens:
        import jax

        from repro.models import init_params

        cfg = get_config(args.arch, reduced=True).replace(remat="none")
        params = init_params(cfg, jax.random.key(0))
        def factory():
            return InferenceEngine(cfg, params, max_batch=1, max_seq=96)

    rep = run_cluster(
        trace, costs, policy=args.policy, alpha=args.alpha,
        predictor=make_window_max_predictor(trace), engine_factory=factory,
        rng=np.random.default_rng(1),
    )
    print(f"{args.policy}(alpha={args.alpha}): sessions={rep.sessions_served} "
          f"cost={rep.total_cost:,.1f} static={rep.static_cost:,.0f} "
          f"reduction={rep.reduction:.1%}"
          + (f" tokens={rep.tokens_generated}" if args.real_tokens else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
