"""Step builders: jit-able train / prefill / decode steps with shardings.

Used by the trainer, the serving engine, and the multi-pod dry-run: each
builder returns (fn, in_shardings, out_shardings, abstract_args) so callers
can either execute or just ``jit(...).lower(*abstract).compile()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.distributed.ctx import shard_ctx
from repro.models import model_zoo as zoo
from repro.optim import AdamWConfig, AdamWState, adamw_update, init_adamw


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    abstract_args: tuple
    donate_argnums: tuple = ()


def _named(tree_spec, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_spec)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def build_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: ShapeCell,
    opt_cfg: AdamWConfig | None = None,
) -> StepBundle:
    opt_cfg = opt_cfg or AdamWConfig()
    params_shape = zoo.abstract_params(cfg)
    opt_shape = jax.eval_shape(init_adamw, params_shape)
    batch_shape = zoo.input_specs(cfg, shape)

    p_spec = shd.param_specs(params_shape, mesh, fsdp_only=cfg.fsdp_only)
    o_spec = AdamWState(step=P(), m=p_spec, v=p_spec)
    b_spec = shd.batch_specs(batch_shape, mesh, fsdp_only=cfg.fsdp_only)

    def train_step(params, opt_state, batch):
        with shard_ctx(mesh, seq_parallel=cfg.seq_parallel,
                       fsdp_only=cfg.fsdp_only):
            def lf(p):
                return zoo.loss_fn(p, cfg, batch)

            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
            new_params, new_opt, opt_metrics = adamw_update(
                grads, opt_state, params, opt_cfg
            )
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return new_params, new_opt, metrics

    metrics_shape = jax.eval_shape(
        lambda p, o, b: train_step(p, o, b)[2], params_shape, opt_shape, batch_shape
    )
    m_spec = jax.tree.map(lambda _: P(), metrics_shape)

    return StepBundle(
        fn=train_step,
        in_shardings=(_named(p_spec, mesh), _named(o_spec, mesh), _named(b_spec, mesh)),
        out_shardings=(_named(p_spec, mesh), _named(o_spec, mesh), _named(m_spec, mesh)),
        abstract_args=(params_shape, opt_shape, batch_shape),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def _serving_params_shape(cfg: ModelConfig):
    ps = zoo.abstract_params(cfg)
    if cfg.serve_weight_dtype is None:
        return ps
    dt = cfg.serve_weight_dtype
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt)
        if jnp.issubdtype(s.dtype, jnp.floating) else s,
        ps,
    )


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeCell) -> StepBundle:
    params_shape = _serving_params_shape(cfg)
    batch_shape = zoo.input_specs(
        cfg, dataclasses.replace(shape, kind="prefill")
    )
    cache_shape = zoo.abstract_cache(cfg, shape)

    p_spec = shd.param_specs(params_shape, mesh, serving=True)
    b_spec = shd.batch_specs(batch_shape, mesh)
    c_spec = shd.cache_specs(cache_shape, mesh)

    def prefill(params, batch, cache):
        with shard_ctx(mesh, seq_parallel=cfg.seq_parallel):
            return zoo.prefill_fn(params, cfg, batch, cache)

    logits_shape = jax.eval_shape(prefill, params_shape, batch_shape, cache_shape)[0]
    l_spec = shd.batch_specs(logits_shape, mesh)

    return StepBundle(
        fn=prefill,
        in_shardings=(_named(p_spec, mesh), _named(b_spec, mesh), _named(c_spec, mesh)),
        out_shardings=(_named(l_spec, mesh), _named(c_spec, mesh)),
        abstract_args=(params_shape, batch_shape, cache_shape),
        donate_argnums=(2,),
    )


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeCell) -> StepBundle:
    params_shape = _serving_params_shape(cfg)
    cache_shape = zoo.abstract_cache(cfg, shape)
    B = shape.global_batch
    token_shape = jax.ShapeDtypeStruct((B,), jnp.int32)
    len_shape = jax.ShapeDtypeStruct((), jnp.int32)

    p_spec = shd.param_specs(params_shape, mesh, serving=True)
    c_spec = shd.cache_specs(cache_shape, mesh, prefer_seq=cfg.sp_decode)
    t_spec = shd.batch_specs(token_shape, mesh)

    def decode(params, token, cur_len, cache):
        with shard_ctx(mesh):
            return zoo.decode_fn(params, cfg, token, cur_len, cache)

    logits_shape = jax.eval_shape(
        decode, params_shape, token_shape, len_shape, cache_shape
    )[0]
    l_spec = shd.batch_specs(logits_shape, mesh)

    return StepBundle(
        fn=decode,
        in_shardings=(
            _named(p_spec, mesh),
            _named(t_spec, mesh),
            NamedSharding(mesh, P()),
            _named(c_spec, mesh),
        ),
        out_shardings=(_named(l_spec, mesh), _named(c_spec, mesh)),
        abstract_args=(params_shape, token_shape, len_shape, cache_shape),
        donate_argnums=(3,),
    )


def build_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeCell, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape)
    return build_decode_step(cfg, mesh, shape)


def lower_step(bundle: StepBundle, mesh: Mesh):
    """jit + lower the bundle's fn on abstract args (no allocation)."""
    jitted = jax.jit(
        bundle.fn,
        in_shardings=bundle.in_shardings,
        out_shardings=bundle.out_shardings,
        donate_argnums=bundle.donate_argnums,
    )
    with mesh:
        return jitted.lower(*bundle.abstract_args)
