"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  Single pod: 16 x 16 = 256 chips (data x model).  Multi-pod:
2 x 16 x 16 = 512 chips (pod x data x model); the 'pod' axis is pure DP over
the inter-pod links, 'data' is FSDP over intra-pod ICI, 'model' is TP.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model_parallel = min(model_parallel, n)
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
