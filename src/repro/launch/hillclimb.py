import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
).strip()

"""Perf hillclimbing driver: re-lower a cell with config overrides and report
the roofline-term deltas vs the baseline artifact.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch command-r-plus-104b --shape decode_32k \
        --tag f8cache --set kv_cache_dtype=float8_e4m3fn sp_decode=true
"""
import argparse
import json
import sys
from pathlib import Path

import jax.numpy as jnp

from repro.launch.dryrun import run_cell
from repro.launch.roofline import analyse

_DTYPES = {
    "bfloat16": jnp.bfloat16,
    "float8_e4m3fn": jnp.float8_e4m3fn,
    "float32": jnp.float32,
}


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v in _DTYPES:
        return k, _DTYPES[v]
    if v.lower() in ("true", "false"):
        return k, v.lower() == "true"
    try:
        return k, int(v)
    except ValueError:
        pass
    try:
        return k, float(v)
    except ValueError:
        return k, v


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    overrides = dict(parse_override(kv) for kv in args.set)
    out_dir = Path(args.out)

    base_path = out_dir / f"{args.arch}__{args.shape}__{'2x16x16' if args.multi_pod else '16x16'}.json"
    base = json.loads(base_path.read_text()) if base_path.exists() else None

    rep = run_cell(args.arch, args.shape, args.multi_pod, out_dir,
                   overrides=overrides, tag_suffix=f"__{args.tag}")
    new = analyse(rep, overrides=overrides)

    print(f"== {args.arch} x {args.shape} [{args.tag}] overrides={overrides}")
    if base is not None:
        old = analyse(base)
        for k in ("compute_s", "memory_s", "collective_s"):
            delta = (new[k] - old[k]) / old[k] * 100 if old[k] else float("nan")
            print(f"  {k:>13}: {old[k]:.5f} -> {new[k]:.5f}  ({delta:+.1f}%)")
        print(f"  {'useful frac':>13}: {old['useful_frac']:.3f} -> {new['useful_frac']:.3f}")
        print(f"  {'dominant':>13}: {old['dominant']} -> {new['dominant']}")
    else:
        print(json.dumps({k: new[k] for k in ('compute_s', 'memory_s',
                                              'collective_s', 'dominant')}, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
