"""Roofline aggregation: dry-run artifacts -> per-cell three-term analysis.

Terms (per step, single-pod 16x16 = 256 chips, TPU v5e constants):

  compute_s    = HLO_FLOPs / (chips * 197e12)       [exact: depth-extrapolated]
  memory_s     = fused HBM traffic model / 819e9     [analytic, see below]
  collective_s = HLO collective bytes / 50e9         [exact: HLO parse]

XLA's 'bytes accessed' on the CPU backend counts every unfused op and wildly
overestimates HBM traffic for a fused TPU program, so it is reported only as
an upper bound; the memory term uses an explicit traffic model:

  train:   4 weight passes (fwd + remat + bwd, incl. FSDP gather buffers) at
           TP sharding + grad traffic + fp32 Adam state r/w (ZeRO over all
           chips) + 2x layer-boundary activations + 2x loss-chunk logits
  prefill: 1 weight pass + KV-cache write + 4x one-pass activations
  decode:  1 active-weight pass + full KV-cache/state read + logits

MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode) with
N excluding embeddings; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat and
redundant compute.  sLSTM's recurrent R-matmul runs inside a time scan
(cost-counted once); an analytic correction is added for the ssm family.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.models import model_zoo as zoo

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def cell_facts(arch: str, shape_name: str, mesh: str,
               overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    n_chips = 512 if mesh == "2x16x16" else 256
    tp = 16
    dp = n_chips // tp
    n_total = zoo.param_count(cfg)
    n_embed = zoo.embedding_param_count(cfg)
    n_active = zoo.active_param_count(cfg)
    body = n_total - n_embed
    body_active = n_active - n_embed
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(B // dp, 1)
    return dict(
        cfg=cfg, shape=shape, n_chips=n_chips, tp=tp, dp=dp,
        n_total=n_total, n_embed=n_embed, n_active=n_active,
        body=body, body_active=body_active, B=B, S=S, b_loc=b_loc,
    )


def model_flops(f: dict) -> float:
    """Global 'useful' FLOPs per step: 6ND / 2ND / 2*N_active*B."""
    kind = f["shape"].kind
    tokens = f["B"] * f["S"]
    n = f["body_active"] + f["n_embed"] / 2  # unembed matmul counts, gather not
    if kind == "train":
        return 6.0 * n * tokens
    if kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * f["B"]                   # decode: one token per sequence


def analytic_memory_bytes(f: dict) -> float:
    """Per-device fused HBM traffic per step (model described above)."""
    cfg, shape = f["cfg"], f["shape"]
    kind = shape.kind
    tp, chips = f["tp"], f["n_chips"]
    D = cfg.d_model
    L = cfg.n_layers
    tokens_loc = f["b_loc"] * f["S"]
    import jax.numpy as jnp
    serve_bytes = (
        jnp.dtype(cfg.serve_weight_dtype).itemsize
        if getattr(cfg, "serve_weight_dtype", None) is not None else 2.0
    )
    w_bf16 = 2.0 * f["n_total"]
    w_active = serve_bytes * f["n_active"]

    if kind == "train":
        weights = 4.0 * w_bf16 / tp
        grads = 2.0 * w_bf16 / tp
        adam = 6.0 * 4.0 * f["n_total"] / chips
        acts = 4.0 * L * tokens_loc * D * 2.0
        logits = 2.0 * tokens_loc * (cfg.vocab_size / tp) * 4.0
        return weights + grads + adam + acts + logits
    if kind == "prefill":
        weights = w_bf16 / tp
        cache = 2.0 * f["b_loc"] * min(f["S"], _cache_len(cfg, f["S"])) \
            * cfg.n_kv_heads * cfg.head_dim * 2.0
        acts = 4.0 * L * tokens_loc * D * 2.0
        return weights + cache * L + acts
    # decode
    weights = w_active / tp
    cache = _decode_state_bytes(cfg, f)
    logits = f["b_loc"] * (cfg.vocab_size / tp) * 4.0
    return weights + cache + logits


def _cache_len(cfg, S: int) -> int:
    if cfg.family == "hybrid" and cfg.window:
        return min(cfg.window, S)
    if cfg.family == "ssm":
        return 0
    return S


def _decode_state_bytes(cfg, f: dict) -> float:
    """Per-device bytes read per decode step: KV caches + recurrent states."""
    import jax.numpy as jnp

    b = f["b_loc"]
    L = cfg.n_layers
    total = 0.0
    clen = _cache_len(cfg, f["S"])
    cache_bytes = jnp.dtype(cfg.kv_cache_dtype).itemsize
    if cfg.n_kv_heads % f["tp"] == 0:
        kv_shard = f["tp"]
    elif getattr(cfg, "sp_decode", False) and clen and clen % f["tp"] == 0:
        kv_shard = f["tp"]          # sequence-sharded cache
    else:
        kv_shard = 1
    if clen:
        total += 2.0 * b * clen * cfg.n_kv_heads * cfg.head_dim * cache_bytes \
            / kv_shard * (L if cfg.family != "audio" else cfg.n_dec_layers)
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        nh = di // cfg.head_dim
        total += 4.0 * b * nh * cfg.ssm_state * cfg.head_dim * L  # fp32 state
    if cfg.family == "ssm":
        di = cfg.ssm_expand * cfg.d_model
        nh, hd = cfg.n_heads, di // cfg.n_heads
        total += 4.0 * b * nh * hd * (hd + 1) * L                 # mLSTM matrix
    if cfg.family == "audio":
        total += (2.0 * b * 4096 * cfg.n_kv_heads * cfg.head_dim
                  * jnp.dtype(cfg.kv_cache_dtype).itemsize * cfg.n_dec_layers)
    return total


def slstm_correction_flops(f: dict) -> float:
    """Uncounted per-device flops from sLSTM's in-scan R matmul (train/prefill)."""
    cfg = f["cfg"]
    if cfg.family != "ssm" or not cfg.slstm_every:
        return 0.0
    if f["shape"].kind == "decode":
        return 0.0
    di = cfg.ssm_expand * cfg.d_model
    nh, hd = cfg.n_heads, di // cfg.n_heads
    n_slstm = cfg.n_layers // cfg.slstm_every
    per_step = 2.0 * nh * hd * 4 * hd
    passes = 3.0 if f["shape"].kind == "train" else 1.0
    total = passes * n_slstm * per_step * f["B"] * f["S"]
    return total / f["n_chips"]


def analyse(report: dict, overrides: dict | None = None) -> dict:
    f = cell_facts(report["arch"], report["shape"], report["mesh"], overrides)
    hlo_flops = report["hlo_flops_per_device"] + slstm_correction_flops(f)
    mem_bytes = analytic_memory_bytes(f)
    coll = report["collective_total_per_device"]
    compute_s = hlo_flops / PEAK_FLOPS_BF16
    memory_s = mem_bytes / HBM_BW
    collective_s = coll / ICI_BW
    mf = model_flops(f)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    useful_frac = mf / (hlo_flops * f["n_chips"]) if hlo_flops else 0.0
    # roofline fraction: useful work per second at the bound vs peak
    mfu_at_bound = (mf / f["n_chips"] / bound_s) / PEAK_FLOPS_BF16 if bound_s else 0.0
    return dict(
        arch=report["arch"], shape=report["shape"], mesh=report["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        memory_s_hlo_upper=report["roofline"].get(
            "memory_s_hlo_upper", report["roofline"].get("memory_s")
        ),
        dominant=dominant, model_flops=mf,
        hlo_flops_total=hlo_flops * f["n_chips"],
        useful_frac=useful_frac, roofline_frac=mfu_at_bound,
        collectives=report["collective_bytes_per_device"],
        temp_bytes=report["bytes_per_device"]["temp"],
        arg_bytes=report["bytes_per_device"]["argument"],
    )


def comment(a: dict) -> str:
    """One sentence on what would move the dominant term down."""
    if a["dominant"] == "compute":
        if a["useful_frac"] < 0.3:
            return ("compute-bound with low useful fraction: cut remat "
                    "(policy=dots) and fp32 softmax/logit width")
        return "compute-bound near useful peak: only sharding wider helps"
    if a["dominant"] == "memory":
        if a["shape"] == "decode_32k" or a["shape"] == "long_500k":
            return ("cache-read bound: int8 KV cache and wider cache sharding "
                    "halve/shard the stream")
        return ("weight/activation traffic bound: fewer weight passes (remat "
                "policy), bf16 master-weight reads, larger per-device batch")
    return ("collective-bound: overlap all-gathers with compute (latency-"
            "hiding scheduler), shard KV heads instead of replicating, or "
            "move the reduce to the smaller axis")


def write_tables(reports_dir: Path, out_md: Path | None) -> str:
    rows = []
    for p in sorted(reports_dir.glob("*.json")):
        rep = json.loads(p.read_text())
        if rep["mesh"] != "16x16":
            continue  # roofline table is single-pod per the assignment
        rows.append(analyse(rep))
    lines = [
        "| arch | shape | compute_s | memory_s | coll_s | dominant | "
        "MODEL_FLOPS | useful frac | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.4f} | "
            f"{a['memory_s']:.4f} | {a['collective_s']:.5f} | {a['dominant']} | "
            f"{a['model_flops']:.3e} | {a['useful_frac']:.2f} | "
            f"{a['roofline_frac']:.3f} | {comment(a)} |"
        )
    md = "\n".join(lines)
    if out_md:
        out_md.write_text(md + "\n")
    return md


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reports", default="reports/dryrun")
    ap.add_argument("--out", default="reports/roofline.md")
    args = ap.parse_args()
    md = write_tables(Path(args.reports), Path(args.out))
    print(md)


if __name__ == "__main__":
    main()
