import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM analysis, and unsupported collectives all
surface here.  Roofline terms are extracted from the compiled artifact
(cost_analysis + HLO collective parse) and written to reports/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--all] [--out reports/dryrun]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

from repro.configs import SHAPES, get_config, list_archs, runnable_cells
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, lower_step

# TPU v5e hardware constants (roofline targets; this container is CPU-only).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in the HLO, by kind.

    Matches sync and async-start forms; '-done' lines are skipped so async
    pairs are not double counted.
    """
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        m = _COLLECTIVE_RE.search(rhs)
        if not m or "-done" in rhs[: m.end() + 8]:
            continue
        kind = m.group(1)
        # result shape(s) appear between '=' and the op name
        total = 0.0
        opname_idx = rhs.find(kind)
        for dt, dims in _SHAPE_RE.findall(rhs[:opname_idx]):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + total
    return out


def depth_period(cfg) -> int:
    """Smallest layer block that repeats identically (xLSTM: sLSTM period)."""
    if cfg.family == "ssm" and cfg.slstm_every:
        return cfg.slstm_every
    return 1


def with_depth(cfg, k: int):
    if cfg.is_encdec:
        return cfg.replace(n_enc_layers=k, n_dec_layers=k, n_layers=2 * k)
    return cfg.replace(n_layers=k)


def _compile_once(cfg, mesh, shape):
    bundle = build_step(cfg, mesh, shape)
    lowered = lower_step(bundle, mesh)
    compiled = lowered.compile()
    return compiled


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: Path,
    overrides: dict | None = None,
    tag_suffix: str = "",
) -> dict:
    """One dry-run cell: three compiles.

    1. Full-depth scanned program — the compile/sharding gate + per-device
       memory analysis (this is the artifact that must run on hardware).
    2+3. Depth-p and depth-2p *unrolled* programs — XLA cost analysis counts
       a while body once, so exact FLOP/collective totals are obtained by
       linear extrapolation in depth (every layer block is shape-identical):
           total(L) = f(p) + (L/p - 1) * (f(2p) - f(p)).
    """
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    t0 = time.time()
    compiled = _compile_once(cfg, mesh, shape)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # --- depth extrapolation for exact cost accounting
    p = depth_period(cfg)
    L_periods = (cfg.n_enc_layers if cfg.is_encdec else cfg.n_layers) // p
    t0 = time.time()
    acc = []
    for k in (p, 2 * p):
        c = _compile_once(with_depth(cfg, k).replace(scan_layers=False), mesh, shape)
        cost = c.cost_analysis()
        acc.append(
            {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": collective_bytes(c.as_text()),
            }
        )
    t_depth = time.time() - t0

    def extrap(key):
        f1, f2 = acc[0][key], acc[1][key]
        return f1 + (L_periods - 1) * (f2 - f1)

    flops = extrap("flops")
    bytes_accessed = extrap("bytes")
    kinds = set(acc[0]["coll"]) | set(acc[1]["coll"])
    coll = {
        k: acc[0]["coll"].get(k, 0.0)
        + (L_periods - 1) * (acc[1]["coll"].get(k, 0.0) - acc[0]["coll"].get(k, 0.0))
        for k in kinds
    }
    coll_total = sum(coll.values())

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "compile_s": round(t_compile, 2),
        "depth_probe_s": round(t_depth, 2),
        # memory_analysis is per-device for SPMD executables
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "generated_code": getattr(mem, "generated_code_size_in_bytes", None),
        },
        # per-device, exact via depth extrapolation
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll,
        "collective_total_per_device": coll_total,
        "roofline": {
            "compute_s": flops / PEAK_FLOPS_BF16,
            # nb: XLA 'bytes accessed' is unfused (CPU backend) — treated as
            # an upper bound; launch/roofline.py adds the fused traffic model.
            "memory_s_hlo_upper": bytes_accessed / HBM_BW,
            "collective_s": coll_total / ICI_BW,
        },
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{report['mesh']}{tag_suffix}"
    (out_dir / f"{tag}.json").write_text(json.dumps(report, indent=2))
    return report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every runnable cell")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    cells: list[tuple[str, str]]
    if args.all:
        cells = runnable_cells()
    else:
        archs = [args.arch] if args.arch else list_archs()
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [
            (a, s)
            for a in archs
            for s in shapes
            if (a, s) in set(runnable_cells())
        ]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                rep = run_cell(arch, shape, mp, out_dir)
                r = rep["roofline"]
                print(
                    f"OK   {tag}: compile={rep['compile_s']}s "
                    f"flops/dev={rep['hlo_flops_per_device']:.3e} "
                    f"compute={r['compute_s']:.4f}s "
                    f"mem_ub={r['memory_s_hlo_upper']:.4f}s "
                    f"coll={r['collective_s']:.4f}s",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
