"""AdamW with global-norm clipping — in-house, pytree-native, ZeRO-sharded.

Optimizer state mirrors the parameter pytree (same shapes), so the parameter
PartitionSpecs apply verbatim to m/v (ZeRO-3: optimizer state is sharded
exactly like the FSDP-sharded parameters).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: Any                   # first moment, fp32, like params
    v: Any                   # second moment, fp32, like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to lr_min_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr_peak * cos)


def init_adamw(params: Any) -> AdamWState:
    def zeros():
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    grads: Any, state: AdamWState, params: Any, cfg: AdamWConfig
) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
