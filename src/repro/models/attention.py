"""GQA attention: training (full/sliding causal), prefill, and cached decode.

The jnp einsum path is the portable implementation used for lowering /
dry-runs; ``repro.kernels`` provides the Pallas TPU kernels with identical
semantics (tests assert allclose between the two).

GQA expands K/V to the full head count right before the SDPA einsums (XLA
fuses the gather); heads shard cleanly over the 'model' axis where divisible.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain_attention, constrain_attention_decode
from .layers import apply_rope, init_dense


class KVCache(NamedTuple):
    """KV cache; for sliding-window layers it is a ring buffer of size W.

    ``pos`` holds the absolute position stored in each slot (-1 = empty), so
    masking never needs to reason about ring wrap-around.
    """

    k: jax.Array          # (B, S_cache, KVH, hd)
    v: jax.Array          # (B, S_cache, KVH, hd)
    pos: jax.Array        # (S_cache,) int32, absolute positions, -1 = empty


def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_dense(k1, (d, h, hd), cfg.param_dtype, fan_in=d),
        "wk": init_dense(k2, (d, kvh, hd), cfg.param_dtype, fan_in=d),
        "wv": init_dense(k3, (d, kvh, hd), cfg.param_dtype, fan_in=d),
        "wo": init_dense(k4, (h, hd, d), cfg.param_dtype, fan_in=h * hd),
    }


def _sdpa(q, k, v, mask, compute_dtype):
    """SDPA over flat heads.

    q: (B, Sq, H, hd); k/v: (B, Skv, H, hd) (KV pre-expanded to H heads —
    XLA fuses the expansion gather; heads shard over 'model' when divisible).
    mask: broadcastable (B?, 1?, Sq, Skv).
    """
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _expand_kv(x, n_heads: int):
    """(B, S, KVH, hd) -> (B, S, H, hd) by repeating each KV head."""
    b, s, kvh, hd = x.shape
    if kvh == n_heads:
        return x
    return jnp.repeat(x, n_heads // kvh, axis=2)


def _causal_mask(q_len: int, kv_len: int, window, q_offset) -> jax.Array:
    """Boolean (q_len, kv_len): True = attend.  window=0 -> full causal."""
    q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (q_len, kv_len), 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (q_len, kv_len), 1)
    mask = k_pos <= q_pos
    if isinstance(window, jax.Array):
        mask &= k_pos > q_pos - window
    elif window > 0:
        mask &= k_pos > q_pos - jnp.int32(window)
    return mask


def _qkv(x, p, cd):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    return q, k, v


def _local_attention(q, k, v, window: int, cd):
    """Banded sliding-window attention in chunks of W (hillclimb lever).

    Chunk i attends to chunks {i-1, i}: compute/memory O(S*2W) instead of
    O(S^2) with the same semantics as the masked full-score path.
    q/k/v: (B, S, H, hd) with KV pre-expanded; requires S % W == 0.
    """
    B, S, H, hd = q.shape
    W = window
    nc = S // W
    qc = q.reshape(B, nc, W, H, hd)
    kc = k.reshape(B, nc, W, H, hd)
    vc = v.reshape(B, nc, W, H, hd)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kc], axis=2)               # (B, nc, 2W, H, hd)
    v2 = jnp.concatenate([v_prev, vc], axis=2)
    scores = jnp.einsum("bcqhd,bckhd->bchqk", qc, k2).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    qi = jax.lax.broadcasted_iota(jnp.int32, (W, 2 * W), 0)  # local q index
    ki = jax.lax.broadcasted_iota(jnp.int32, (W, 2 * W), 1)  # index into [prev|cur]
    rel = qi + W - ki                                        # k_pos = q_pos - rel
    band = (rel >= 0) & (rel < W)
    ci = jnp.arange(nc)[:, None, None]
    valid_prev = (ci > 0) | (ki[None] >= W)                  # chunk 0 has no prev
    mask = band[None] & valid_prev                           # (nc, W, 2W)
    scores = jnp.where(mask[None, :, None], scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(cd)
    out = jnp.einsum("bchqk,bckhd->bcqhd", probs, v2)
    return out.reshape(B, S, H, hd)


def attention_train(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    positions: jax.Array,
    window=0,
    bidirectional: bool = False,
    use_rope: bool = True,
) -> jax.Array:
    """Self-attention over a full sequence (training / encoder)."""
    cd = cfg.compute_dtype
    b, s, _ = x.shape
    q, k, v = _qkv(x, p, cd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if (
        cfg.local_attention
        and not bidirectional
        and isinstance(window, int)
        and window > 0
        and s % window == 0
        and s >= 2 * window
    ):
        q, ke, ve = constrain_attention(q, _expand_kv(k, cfg.n_heads),
                                        _expand_kv(v, cfg.n_heads))
        out = _local_attention(q, ke, ve, window, cd)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    if bidirectional:
        mask = jnp.ones((1, 1, s, s), dtype=bool)
    else:
        mask = _causal_mask(s, s, window, 0)[None, None]
    q, ke, ve = constrain_attention(q, _expand_kv(k, cfg.n_heads),
                                    _expand_kv(v, cfg.n_heads))
    out = _sdpa(q, ke, ve, mask, cd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))


def cross_attention(x, memory, p, cfg: ModelConfig) -> jax.Array:
    cd = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(cd))
    mask = jnp.ones((1, 1, x.shape[1], memory.shape[1]), dtype=bool)
    q, ke, ve = constrain_attention(q, _expand_kv(k, cfg.n_heads),
                                    _expand_kv(v, cfg.n_heads))
    out = _sdpa(q, ke, ve, mask, cd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int) -> KVCache:
    """max_len: cache slots; for sliding-window layers pass min(W, seq)."""
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, cfg.kv_cache_dtype),
        v=jnp.zeros(shape, cfg.kv_cache_dtype),
        pos=jnp.full((max_len,), -1, jnp.int32),
    )


def prefill_attention(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: KVCache,
    window=0,
) -> tuple[jax.Array, KVCache]:
    """Full-sequence attention that also fills the KV cache.

    If the cache is smaller than S (ring/window cache) only the last
    ``cache_len`` tokens are stored.
    """
    cd = cfg.compute_dtype
    b, s, _ = x.shape
    cache_len = cache.k.shape[1]
    q, k, v = _qkv(x, p, cd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cache_len < s:
        k_store, v_store = k[:, s - cache_len:], v[:, s - cache_len:]
        pos_store = jnp.arange(s - cache_len, s, dtype=jnp.int32)
    else:
        k_store, v_store = k, v
        pos_store = jnp.where(
            jnp.arange(cache_len) < s, jnp.arange(cache_len), -1
        ).astype(jnp.int32)
        k_store = jnp.pad(k_store, ((0, 0), (0, cache_len - s), (0, 0), (0, 0)))
        v_store = jnp.pad(v_store, ((0, 0), (0, cache_len - s), (0, 0), (0, 0)))
    new_cache = KVCache(
        k=k_store.astype(cache.k.dtype),
        v=v_store.astype(cache.v.dtype),
        pos=pos_store,
    )
    q, ke, ve = constrain_attention(q, _expand_kv(k, cfg.n_heads),
                                    _expand_kv(v, cfg.n_heads))
    if (
        cfg.local_attention
        and isinstance(window, int)
        and window > 0
        and s % window == 0
        and s >= 2 * window
    ):
        out = _local_attention(q, ke, ve, window, cd)
    else:
        mask = _causal_mask(s, s, window, 0)[None, None]
        out = _sdpa(q, ke, ve, mask, cd)
    return (
        jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)),
        new_cache,
    )


def decode_attention(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    cache: KVCache,
    cur_len: jax.Array,
    window=0,
) -> tuple[jax.Array, KVCache]:
    """One-token attention against the cache (ring-buffer aware).

    x: (B, 1, D); ``cur_len``: scalar int32 — absolute position of the new
    token; it is written at slot ``cur_len % cache_len``.
    """
    cd = cfg.compute_dtype
    b = x.shape[0]
    cache_len = cache.k.shape[1]
    pos = jnp.full((b, 1), cur_len, dtype=jnp.int32)
    q, k, v = _qkv(x, p, cd)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    slot = jnp.mod(cur_len, cache_len)
    new_cache = KVCache(
        k=jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                       (0, slot, 0, 0)),
        v=jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                       (0, slot, 0, 0)),
        pos=jax.lax.dynamic_update_slice(
            cache.pos, jnp.reshape(cur_len, (1,)).astype(jnp.int32), (slot,)
        ),
    )
    kpos = new_cache.pos[None, :]                       # (1, cache_len)
    mask = (kpos >= 0) & (kpos <= cur_len)
    if isinstance(window, jax.Array):
        mask &= kpos > cur_len - window
    elif window > 0:
        mask &= kpos > cur_len - jnp.int32(window)
    q, ke, ve = constrain_attention_decode(
        q,
        _expand_kv(new_cache.k.astype(cd), cfg.n_heads),
        _expand_kv(new_cache.v.astype(cd), cfg.n_heads),
    )
    out = _sdpa(q, ke, ve, mask[None, None], cd)
    return (
        jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)),
        new_cache,
    )
