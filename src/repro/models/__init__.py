"""Composable model zoo for the 10 assigned architectures."""
from .model_zoo import (
    abstract_cache,
    abstract_params,
    active_param_count,
    decode_fn,
    embedding_param_count,
    init_cache,
    init_params,
    input_specs,
    logits_fn,
    loss_fn,
    param_count,
    prefill_fn,
)

__all__ = [
    "abstract_cache",
    "abstract_params",
    "active_param_count",
    "decode_fn",
    "embedding_param_count",
    "init_cache",
    "init_params",
    "input_specs",
    "logits_fn",
    "loss_fn",
    "param_count",
    "prefill_fn",
]
