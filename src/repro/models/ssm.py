"""Selective state-space (Mamba-2 / SSD style) blocks, chunk-parallel.

TPU adaptation (DESIGN.md): the recurrence

    H_t = a_t * H_{t-1} + k_t (x) v_t        y_t = q_t . H_t

with a scalar per-head decay ``a_t`` is computed in *chunked* form: intra-chunk
terms become (L x L) masked matmuls (MXU-friendly), inter-chunk terms a short
``lax.scan`` over chunk summaries.  This is the standard SSD algorithm and is
the TPU-native replacement for the CUDA selective-scan kernel.

Decode is the O(1) recurrent step on the carried state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import init_dense


class SSMState(NamedTuple):
    h: jax.Array        # (B, nh, dk, dv) recurrent state
    conv: jax.Array     # (B, w-1, di) rolling conv input window


# ---------------------------------------------------------------------------
# Chunked gated linear recurrence (shared by SSM and mLSTM)
# ---------------------------------------------------------------------------

def ssd_chunked(
    q: jax.Array,       # (B, S, nh, dk)
    k: jax.Array,       # (B, S, nh, dk)
    v: jax.Array,       # (B, S, nh, dv)
    log_a: jax.Array,   # (B, S, nh)  log decay in (-inf, 0]
    chunk: int,
    h0: jax.Array | None = None,   # (B, nh, dk, dv)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y: (B, S, nh, dv), h_last: (B, nh, dk, dv)).  fp32 internally."""
    B, S_in, nh, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S_in)
    # pad to a chunk multiple: k=v=0 and log_a=0 contribute nothing to state
    pad = (-S_in) % L
    if pad:
        def zpad(a):
            return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))

        q, k, v, log_a = zpad(q), zpad(k), zpad(v), zpad(log_a)
    S = S_in + pad
    nc = S // L

    f32 = jnp.float32
    qc = q.reshape(B, nc, L, nh, dk).astype(f32)
    kc = k.reshape(B, nc, L, nh, dk).astype(f32)
    vc = v.reshape(B, nc, L, nh, dv).astype(f32)
    lac = log_a.reshape(B, nc, L, nh).astype(f32)

    A = jnp.cumsum(lac, axis=2)                      # (B, nc, L, nh) incl. own step
    A_last = A[:, :, -1:, :]                          # (B, nc, 1, nh)

    # --- intra-chunk: y_t += sum_{s<=t} exp(A_t - A_s) (q_t.k_s) v_s
    qk = jnp.einsum("bclhd,bcmhd->bchlm", qc, kc)     # (B, nc, nh, L, L)
    decay = A[:, :, :, None, :].transpose(0, 1, 4, 2, 3) - \
        A[:, :, None, :, :].transpose(0, 1, 4, 2, 3)  # (B, nc, nh, L(t), L(s)) = A_t - A_s
    causal = jnp.tril(jnp.ones((L, L), dtype=bool))
    # mask the decay exponent BEFORE exp: above the diagonal A_t - A_s > 0
    # and exp would overflow (it is discarded anyway).
    decay = jnp.where(causal, decay, -jnp.inf)
    scores = qk * jnp.exp(decay)
    y_intra = jnp.einsum("bchlm,bcmhv->bclhv", scores, vc)

    # --- chunk summaries: S_c = sum_s exp(A_last - A_s) k_s (x) v_s
    w = jnp.exp(A_last - A)                           # (B, nc, L, nh)
    S_c = jnp.einsum("bclh,bclhd,bclhv->bchdv", w, kc, vc)  # (B, nc, nh, dk, dv)
    a_chunk = jnp.exp(A_last[:, :, 0, :])             # (B, nc, nh) total chunk decay

    # --- inter-chunk scan
    h_init = (
        jnp.zeros((B, nh, dk, dv), f32) if h0 is None else h0.astype(f32)
    )

    def step(h, inputs):
        s_c, a_c = inputs                              # (B,nh,dk,dv), (B,nh)
        h_out = h * a_c[:, :, None, None] + s_c
        return h_out, h                                # emit h_in for y_cross

    S_cs = jnp.moveaxis(S_c, 1, 0)                     # (nc, B, nh, dk, dv)
    a_cs = jnp.moveaxis(a_chunk, 1, 0)                 # (nc, B, nh)
    h_last, h_ins = jax.lax.scan(step, h_init, (S_cs, a_cs))

    # --- cross-chunk contribution: y_t += exp(A_t) q_t . H_in(chunk)
    h_ins = jnp.moveaxis(h_ins, 0, 1)                  # (B, nc, nh, dk, dv)
    qw = qc * jnp.exp(A)[..., None]                    # (B, nc, L, nh, dk)
    y_cross = jnp.einsum("bclhd,bchdv->bclhv", qw, h_ins)

    y = (y_intra + y_cross).reshape(B, S, nh, dv)[:, :S_in]
    return y, h_last


def ssd_step(
    q: jax.Array,       # (B, nh, dk)
    k: jax.Array,
    v: jax.Array,       # (B, nh, dv)
    log_a: jax.Array,   # (B, nh)
    h: jax.Array,       # (B, nh, dk, dv)
) -> tuple[jax.Array, jax.Array]:
    """O(1) decode step."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    h_new = h.astype(f32) * a + jnp.einsum(
        "bhd,bhv->bhdv", k.astype(f32), v.astype(f32)
    )
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(f32), h_new)
    return y, h_new


# ---------------------------------------------------------------------------
# Mamba-style block (hymba's SSM half)
# ---------------------------------------------------------------------------

def ssm_dims(cfg: ModelConfig) -> tuple[int, int]:
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.head_dim
    return di, nh


def init_ssm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nh = ssm_dims(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_dense(ks[0], (d, 2 * di), cfg.param_dtype, fan_in=d),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv_width, di)) * 0.2).astype(
            cfg.param_dtype
        ),
        "wbc": init_dense(ks[2], (di, 2 * n), cfg.param_dtype, fan_in=di),
        "wdt": init_dense(ks[3], (di, nh), cfg.param_dtype, fan_in=di),
        "a_log": jnp.zeros((nh,), cfg.param_dtype),          # A = exp(a_log) > 0
        "d_skip": jnp.ones((nh,), cfg.param_dtype),
        "out_proj": init_dense(ks[4], (di, d), cfg.param_dtype, fan_in=di),
        "dt_bias": jnp.full((nh,), -1.0, cfg.param_dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None):
    """Depthwise causal conv, width W.  x: (B, S, di), w: (W, di)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+W-1, di)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else jnp.zeros_like(pad)
    return out, new_state


def _ssm_gates(xc, p, cfg, nh):
    """Common q/k/log_a computation from conv output xc: (B, S, di)."""
    bc = jnp.einsum("bsd,dn->bsn", xc, p["wbc"].astype(xc.dtype))
    b_in, c_out = jnp.split(bc, 2, axis=-1)                 # (B, S, n) each
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", xc, p["wdt"].astype(xc.dtype))
        + p["dt_bias"].astype(xc.dtype)
    )                                                       # (B, S, nh)
    a_pos = jnp.exp(p["a_log"].astype(jnp.float32))         # (nh,)
    log_a = -dt.astype(jnp.float32) * a_pos                 # (B, S, nh)
    # dt also scales the input (Mamba discretization: B <- dt * B)
    k = b_in[:, :, None, :] * dt[..., None]                 # (B, S, nh, n)
    q = jnp.broadcast_to(c_out[:, :, None, :], k.shape)     # (B, S, nh, n)
    return q, k, log_a


def ssm_train(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  Full-sequence chunked SSM."""
    cd = cfg.compute_dtype
    di, nh = ssm_dims(cfg)
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xi, p["conv"].astype(cd), None)
    xc = jax.nn.silu(xc)
    q, k, log_a = _ssm_gates(xc, p, cfg, nh)
    v = xc.reshape(B, S, nh, cfg.head_dim)
    chunk = cfg.attn_chunk or 256
    y, _ = ssd_chunked(q, k, v, log_a, chunk)
    y = y + v.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(cd)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    di, nh = ssm_dims(cfg)
    return SSMState(
        h=jnp.zeros((batch, nh, cfg.ssm_state, cfg.head_dim), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, di), jnp.float32),
    )


def ssm_prefill(
    x: jax.Array, p: dict, cfg: ModelConfig, state: SSMState
) -> tuple[jax.Array, SSMState]:
    """Like ssm_train but returns the final recurrent state (for decode)."""
    cd = cfg.compute_dtype
    di, nh = ssm_dims(cfg)
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xi, p["conv"].astype(cd), None)
    xc = jax.nn.silu(xc)
    q, k, log_a = _ssm_gates(xc, p, cfg, nh)
    v = xc.reshape(B, S, nh, cfg.head_dim)
    chunk = cfg.attn_chunk or 256
    y, h_last = ssd_chunked(q, k, v, log_a, chunk, h0=state.h)
    y = y + v.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(cd)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    return out, SSMState(h=h_last, conv=conv_state.astype(jnp.float32))


def ssm_decode(
    x: jax.Array, p: dict, cfg: ModelConfig, state: SSMState
) -> tuple[jax.Array, SSMState]:
    """One-token step.  x: (B, 1, D)."""
    cd = cfg.compute_dtype
    di, nh = ssm_dims(cfg)
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xi, p["conv"].astype(cd), state.conv)
    xc = jax.nn.silu(xc)
    q, k, log_a = _ssm_gates(xc, p, cfg, nh)
    v = xc.reshape(B, 1, nh, cfg.head_dim)
    y, h_new = ssd_step(q[:, 0], k[:, 0], v[:, 0], log_a[:, 0], state.h)
    y = y + v[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di).astype(cd)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    return out, SSMState(h=h_new, conv=conv_state.astype(jnp.float32))
