"""Model facade: one uniform API over all 10 assigned architectures.

  init_params(cfg, key)                -> param pytree
  loss_fn(params, cfg, batch)          -> (loss, metrics)
  prefill_fn(params, cfg, batch, cache)-> (logits, cache)
  decode_fn(params, cfg, token, cur_len, cache) -> (logits, cache)
  init_cache(cfg, batch, s_max, src_len) -> cache pytree
  input_specs(cfg, shape)              -> ShapeDtypeStructs (no allocation)
  param_count / active_param_count     -> roofline's MODEL_FLOPS terms
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from . import encdec, transformer


def init_params(cfg: ModelConfig, key) -> Any:
    if cfg.is_encdec:
        return encdec.init_encdec_params(key, cfg)
    return transformer.init_lm_params(key, cfg)


def abstract_params(cfg: ModelConfig) -> Any:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


def loss_fn(params, cfg: ModelConfig, batch: dict):
    if cfg.is_encdec:
        return encdec.encdec_loss(params, cfg, batch)
    return transformer.lm_loss(params, cfg, batch)


def logits_fn(params, cfg: ModelConfig, batch: dict):
    if cfg.is_encdec:
        memory = encdec.encode(params, cfg, batch["frontend"])
        h = encdec.decode_train(params, cfg, batch["tokens"], memory)
        from .layers import unembed

        return unembed(h, params["embed"])
    return transformer.lm_logits(params, cfg, batch)


def init_cache(cfg: ModelConfig, batch: int, s_max: int, src_len: int = 0):
    if cfg.is_encdec:
        return encdec.init_encdec_cache(cfg, batch, s_max, src_len or 4096)
    return transformer.init_lm_cache(cfg, batch, s_max)


def prefill_fn(params, cfg: ModelConfig, batch: dict, cache):
    if cfg.is_encdec:
        return encdec.encdec_prefill(params, cfg, batch, cache)
    return transformer.lm_prefill(params, cfg, batch, cache)


def decode_fn(params, cfg: ModelConfig, token, cur_len, cache):
    if cfg.is_encdec:
        return encdec.encdec_decode_step(params, cfg, token, cur_len, cache)
    return transformer.lm_decode_step(params, cfg, token, cur_len, cache)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, shardable, no device allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """Abstract inputs for one (arch x shape) cell.

    train:   {"tokens": (B, S)} (+ frontend embeddings for vlm/audio)
    prefill: same as train
    decode:  {"token": (B,), "cur_len": scalar}; the cache comes separately.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.bfloat16
    if shape.kind in ("train", "prefill"):
        specs: dict = {}
        if cfg.frontend == "vision_stub":
            nf = cfg.n_frontend_tokens
            specs["tokens"] = jax.ShapeDtypeStruct((B, S - nf), i32)
            specs["frontend"] = jax.ShapeDtypeStruct((B, nf, cfg.d_model), f32)
        elif cfg.frontend == "audio_stub":
            # enc-dec: source frames + target tokens, each of length S
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, encdec_src_len(cfg, shape), cfg.d_model), f32
            )
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    # decode
    return {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "cur_len": jax.ShapeDtypeStruct((), i32),
    }


def encdec_src_len(cfg: ModelConfig, shape: ShapeCell) -> int:
    """Source frames for enc-dec cells: match S for train/prefill; decode
    uses a fixed 4096-frame memory (the 32k/500k axis is the decoder cache)."""
    if shape.kind in ("train", "prefill"):
        return shape.seq_len
    return 4096


def abstract_cache(cfg: ModelConfig, shape: ShapeCell) -> Any:
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: init_cache(cfg, B, S, src_len=encdec_src_len(cfg, shape))
    )


# ---------------------------------------------------------------------------
# Parameter accounting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return sum(math.prod(x.shape) for x in jax.tree.leaves(tree))


def embedding_param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    n = math.prod(tree["embed"].shape)
    if "unembed" in tree:
        n += math.prod(tree["unembed"].shape)
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE: top_k of n_experts routed)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    tree = abstract_params(cfg)
    moe_total = sum(
        math.prod(x.shape)
        for path, x in jax.tree_util.tree_leaves_with_path(tree)
        if any(getattr(k, "key", None) == "moe" for k in path)
    )
    router = cfg.n_layers * cfg.d_model * cfg.n_experts
    expert_params = moe_total - router
    active = total - expert_params + expert_params * cfg.top_k / cfg.n_experts
    return int(active)
