"""xLSTM blocks: chunk-parallel mLSTM (matrix memory) + sequential sLSTM.

mLSTM is a gated linear recurrence and reuses the SSD machinery from
:mod:`repro.models.ssm`; its normalizer state is carried as an extra value
column (v' = [v, 1]) so a single matrix state covers both C and n:

    C_t = f_t C_{t-1} + i_t k_t (x) v_t        n_t = f_t n_{t-1} + i_t k_t
    h_t = o_t * (q_t C_t) / max(|q_t n_t|, 1)

sLSTM keeps per-head scalar memory with exponential gating and a stabilizer
state; it is inherently sequential (recurrent gate inputs) and runs as a
``lax.scan`` over time — the published xLSTM accepts this cost and so do we
(one sLSTM block every ``slstm_every`` layers).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import init_dense
from .ssm import ssd_chunked, ssd_step


class MLSTMState(NamedTuple):
    h: jax.Array          # (B, nh, dk, dv+1) matrix memory incl. normalizer


class SLSTMState(NamedTuple):
    c: jax.Array          # (B, nh, hd)
    n: jax.Array          # (B, nh, hd)
    m: jax.Array          # (B, nh, hd) stabilizer
    y: jax.Array          # (B, nh, hd) previous output (recurrent input)


def xlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    di = cfg.ssm_expand * cfg.d_model
    nh = cfg.n_heads
    hd = di // nh
    return di, nh, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nh, hd = xlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_dense(ks[0], (d, 2 * di), cfg.param_dtype, fan_in=d),
        "wq": init_dense(ks[1], (di, nh, hd), cfg.param_dtype, fan_in=di),
        "wk": init_dense(ks[2], (di, nh, hd), cfg.param_dtype, fan_in=di),
        "wif": init_dense(ks[3], (di, 2 * nh), cfg.param_dtype, fan_in=di),
        "if_bias": jnp.concatenate(
            [jnp.zeros((nh,)), jnp.full((nh,), 3.0)]
        ).astype(cfg.param_dtype),                       # forget bias ~ +3
        "out_proj": init_dense(ks[4], (di, d), cfg.param_dtype, fan_in=di),
    }


def _mlstm_qkvg(xi, p, cfg, nh, hd):
    cd = xi.dtype
    q = jnp.einsum("bsd,dhk->bshk", xi, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", xi, p["wk"].astype(cd)) * (hd ** -0.5)
    v = xi.reshape(*xi.shape[:2], nh, hd)
    gates = jnp.einsum("bsd,dh->bsh", xi, p["wif"].astype(cd)) + p["if_bias"].astype(cd)
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)        # (B, S, nh)
    log_f = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    i_sig = jax.nn.sigmoid(i_gate.astype(jnp.float32))   # stabilized input gate
    return q, k, v, i_sig, log_f


def _mlstm_read(y_aug):
    """Split [C-readout | normalizer] and normalize."""
    y, norm = y_aug[..., :-1], y_aug[..., -1:]
    return y / jnp.maximum(jnp.abs(norm), 1.0)


def mlstm_train(x: jax.Array, p: dict, cfg: ModelConfig,
                state: MLSTMState | None = None,
                return_state: bool = False):
    cd = cfg.compute_dtype
    di, nh, hd = xlstm_dims(cfg)
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    xi, z = jnp.split(xz, 2, axis=-1)
    q, k, v, i_sig, log_f = _mlstm_qkvg(xi, p, cfg, nh, hd)
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1) * i_sig[..., None]
    chunk = cfg.attn_chunk or 256
    h0 = state.h if state is not None else None
    y_aug, h_last = ssd_chunked(q, k, v_aug, log_f, chunk, h0=h0)
    y = _mlstm_read(y_aug).reshape(B, S, di).astype(cd)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    if return_state:
        return out, MLSTMState(h=h_last)
    return out


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    di, nh, hd = xlstm_dims(cfg)
    return MLSTMState(h=jnp.zeros((batch, nh, hd, hd + 1), jnp.float32))


def mlstm_decode(x: jax.Array, p: dict, cfg: ModelConfig, state: MLSTMState):
    cd = cfg.compute_dtype
    di, nh, hd = xlstm_dims(cfg)
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    xi, z = jnp.split(xz, 2, axis=-1)
    q, k, v, i_sig, log_f = _mlstm_qkvg(xi, p, cfg, nh, hd)
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1) * i_sig[..., None]
    y_aug, h_new = ssd_step(q[:, 0], k[:, 0], v_aug[:, 0], log_f[:, 0], state.h)
    y = _mlstm_read(y_aug).reshape(B, 1, di).astype(cd)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    return out, MLSTMState(h=h_new)


# ---------------------------------------------------------------------------
# sLSTM (sequential, exponential gating with stabilizer)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nh, hd = xlstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_in": init_dense(ks[0], (d, nh, 4 * hd), cfg.param_dtype, fan_in=d),
        "r_in": init_dense(ks[1], (nh, hd, 4 * hd), cfg.param_dtype, fan_in=hd),
        "bias": jnp.zeros((nh, 4 * hd), cfg.param_dtype),
        "out_proj": init_dense(ks[2], (di, d), cfg.param_dtype, fan_in=di),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    di, nh, hd = xlstm_dims(cfg)
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return SLSTMState(c=z, n=z, m=z - 1e9, y=z)


def _slstm_cell(p, cfg, x_proj_t, st: SLSTMState) -> tuple[jax.Array, SLSTMState]:
    """One sLSTM step.  x_proj_t: (B, nh, 4*hd) — input part precomputed
    outside the scan (hoisting the big matmul keeps the sequential body to
    the recurrent R term only)."""
    di, nh, hd = xlstm_dims(cfg)
    f32 = jnp.float32
    pre = (
        x_proj_t
        + jnp.einsum("bhj,hjk->bhk", st.y, p["r_in"].astype(f32))
        + p["bias"].astype(f32)
    )                                                     # (B, nh, 4*hd)
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    z_t = jnp.tanh(zi)
    o_t = jax.nn.sigmoid(oi)
    log_f = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + st.m, ii)
    i_p = jnp.exp(ii - m_new)
    f_p = jnp.exp(log_f + st.m - m_new)
    c_new = f_p * st.c + i_p * z_t
    n_new = f_p * st.n + i_p
    y_new = o_t * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return y_new, SLSTMState(c=c_new, n=n_new, m=m_new, y=y_new)


def slstm_train(x: jax.Array, p: dict, cfg: ModelConfig,
                state: SLSTMState | None = None,
                return_state: bool = False):
    """Sequential scan over time.  x: (B, S, D)."""
    cd = cfg.compute_dtype
    di, nh, hd = xlstm_dims(cfg)
    B, S, _ = x.shape
    st0 = state if state is not None else init_slstm_state(cfg, B)
    x_proj = jnp.einsum("bsd,dhk->bshk", x.astype(jnp.float32),
                        p["w_in"].astype(jnp.float32))     # hoisted from scan

    def step(st, xp_t):
        y, st_new = _slstm_cell(p, cfg, xp_t, st)
        return st_new, y

    st_last, ys = jax.lax.scan(step, st0, jnp.moveaxis(x_proj, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    if return_state:
        return out, st_last
    return out


def slstm_decode(x: jax.Array, p: dict, cfg: ModelConfig, state: SLSTMState):
    cd = cfg.compute_dtype
    di, nh, hd = xlstm_dims(cfg)
    B = x.shape[0]
    xp = jnp.einsum("bd,dhk->bhk", x[:, 0].astype(jnp.float32),
                    p["w_in"].astype(jnp.float32))
    y, st = _slstm_cell(p, cfg, xp, state)
    out = jnp.einsum(
        "bse,ed->bsd", y.reshape(B, 1, di).astype(cd), p["out_proj"].astype(cd)
    )
    return out, st
