"""Elementary model layers: norms, embeddings, rotary, MLPs.

All parameters are plain pytrees of jnp arrays.  Every ``init_*`` has a
matching ``spec_*`` in :mod:`repro.distributed.sharding` describing its
PartitionSpec; layer code only computes — sharding is annotated at the
train/serve-step level via constraints on activations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype=dtype)


def embed_tokens(tokens: jax.Array, table: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def unembed(x: jax.Array, table: jax.Array, softcap: float = 0.0) -> jax.Array:
    """Project to vocab logits; table is (V, D) (tied) — computed in fp32."""
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Interleaved-pair rotary embedding.  x: (B, S, H, hd).

    The pair (2i, 2i+1) formulation keeps every rotation within a contiguous
    2-element group, so a head_dim sharded over the 'model' axis never needs
    cross-shard data movement (the half-split formulation does).
    """
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                         # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr = x.astype(jnp.float32).reshape(*x.shape[:-1], hd // 2, 2)
    x1, x2 = xr[..., 0], xr[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    return {
        "wi": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "wg": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
    }


def mlp(x: jax.Array, p: dict, act: str, compute_dtype) -> jax.Array:
    wi = p["wi"].astype(compute_dtype)
    wg = p["wg"].astype(compute_dtype)
    wo = p["wo"].astype(compute_dtype)
    h = jnp.einsum("bsd,df->bsf", x, wi)
    g = jnp.einsum("bsd,df->bsf", x, wg)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("bsf,fd->bsd", h * g, wo)


def activation(x: jax.Array, act: str) -> jax.Array:
    return jax.nn.silu(x) if act == "silu" else jax.nn.gelu(x)


def init_dense(key, shape: tuple[int, ...], dtype, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) * fan ** -0.5).astype(dtype)
