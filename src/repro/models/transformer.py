"""Decoder-only LM (all families except enc-dec): init / train / serve.

The layer stack is a single ``lax.scan`` over stacked parameters, with
configurable rematerialization.  The LM loss streams over sequence chunks so
full (B, S, V) logits are never materialized (vocabularies here reach 257k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain_tokens_3d

from .blocks import (
    init_layer_cache,
    init_stacked_layers,
    layer_decode,
    layer_flags,
    layer_prefill,
    layer_train,
)
from .layers import (
    embed_tokens,
    init_dense,
    init_embedding,
    init_rms_norm,
    rms_norm,
    unembed,
)

LOSS_CHUNK = 512


def init_lm_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "blocks": init_stacked_layers(ks[1], cfg, cfg.n_layers),
        "final_ln": init_rms_norm(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = init_embedding(ks[2], cfg.vocab_size, cfg.d_model,
                                      cfg.param_dtype)
    if cfg.frontend != "none":
        p["frontend_proj"] = init_dense(ks[3], (cfg.d_model, cfg.d_model),
                                        cfg.param_dtype)
    return p


def _unembed_table(params: dict, cfg: ModelConfig) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["unembed"]


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "full"


def _embed_inputs(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Token embeddings, with modality-stub tokens fused at the front."""
    x = embed_tokens(batch["tokens"], params["embed"], cfg.compute_dtype)
    if cfg.frontend != "none":
        fe = batch["frontend"].astype(cfg.compute_dtype)
        fe = jnp.einsum("bfd,de->bfe", fe, params["frontend_proj"].astype(cfg.compute_dtype))
        x = jnp.concatenate([fe, x], axis=1)     # early fusion
    return constrain_tokens_3d(x)


def lm_backbone(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    """Scan the layer stack; returns (hidden states, total aux loss)."""
    flags = layer_flags(cfg)

    def body(carry, layer):
        h, aux = carry
        p, flag = layer
        h, a = layer_train(p, cfg, h, positions, flag)
        return (h, aux + a), None

    body = _remat(body, cfg)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   (params["blocks"], flags))
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            (x, aux), _ = body((x, aux), (p_i, flags[i]))
    return rms_norm(x, params["final_ln"], cfg.norm_eps), aux


def lm_loss(params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, dict]:
    """Next-token CE over text positions, streamed in sequence chunks."""
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = _embed_inputs(params, cfg, batch)
    S_total = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_total, dtype=jnp.int32), (B, S_total))
    h, aux = lm_backbone(params, cfg, x, positions)

    # predictions for text tokens only: positions offset..offset+S_text-1
    offset = S_total - S_text
    h_text = h[:, offset:, :]
    table = _unembed_table(params, cfg)

    # stream the CE over chunks so (B, S, V) never materializes
    n_pred = S_text - 1
    chunk = min(LOSS_CHUNK, max(n_pred, 1))
    n_chunks = -(-n_pred // chunk)                          # ceil
    padded = n_chunks * chunk
    h_pad = jnp.pad(h_text[:, :n_pred], ((0, 0), (0, padded - n_pred), (0, 0)))
    tgt_pad = jnp.pad(tokens[:, 1 : 1 + n_pred], ((0, 0), (0, padded - n_pred)))
    w_pad = (jnp.arange(padded) < n_pred).astype(jnp.float32)

    def ce_chunk(carry, idx):
        start = idx * chunk
        hs = jax.lax.dynamic_slice_in_dim(h_pad, start, chunk, axis=1)
        tgt = jax.lax.dynamic_slice_in_dim(tgt_pad, start, chunk, axis=1)
        w = jax.lax.dynamic_slice_in_dim(w_pad, start, chunk, axis=0)
        logits = unembed(hs, table, cfg.logit_softcap)       # (B, chunk, V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return carry + jnp.sum((lse - picked) * w[None, :]), None

    if cfg.scan_layers:
        total, _ = jax.lax.scan(ce_chunk, jnp.zeros((), jnp.float32),
                                jnp.arange(n_chunks))
    else:  # unrolled (dry-run accounting: while bodies are cost-counted once)
        total = jnp.zeros((), jnp.float32)
        for i in range(n_chunks):
            total, _ = ce_chunk(total, jnp.int32(i))
    loss = total / (B * n_pred)
    metrics = {"ce": loss, "aux": aux}
    if cfg.n_experts:
        loss = loss + 0.01 * aux
    return loss, metrics


def lm_logits(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Full logits (small configs / tests only)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = _embed_inputs(params, cfg, batch)
    S_total = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_total, dtype=jnp.int32), (B, S_total))
    h, _ = lm_backbone(params, cfg, x, positions)
    return unembed(h, _unembed_table(params, cfg), cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_lm_cache(cfg: ModelConfig, batch: int, s_max: int):
    caches = [init_layer_cache(cfg, batch, s_max) for _ in range(cfg.n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


def lm_prefill(params, cfg: ModelConfig, batch: dict, cache):
    """Returns (last-position logits, filled cache)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = _embed_inputs(params, cfg, batch)
    S_total = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_total, dtype=jnp.int32), (B, S_total))
    flags = layer_flags(cfg)

    def body(h, layer):
        p, flag, c = layer
        h, c_new = layer_prefill(p, cfg, h, positions, c, flag)
        return h, c_new

    body = _remat(body, cfg)
    h, new_cache = _scan_or_unroll(body, x, (params["blocks"], flags, cache),
                                   cfg.n_layers, cfg.scan_layers)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = unembed(h[:, -1:, :], _unembed_table(params, cfg), cfg.logit_softcap)
    return logits[:, 0, :], new_cache


def lm_decode_step(params, cfg: ModelConfig, token: jax.Array, cur_len, cache):
    """token: (B,) int32; cur_len: scalar int32 (tokens already cached)."""
    x = embed_tokens(token[:, None], params["embed"], cfg.compute_dtype)
    flags = layer_flags(cfg)

    def body(h, layer):
        p, flag, c = layer
        h, c_new = layer_decode(p, cfg, h, cur_len, c, flag)
        return h, c_new

    h, new_cache = _scan_or_unroll(body, x, (params["blocks"], flags, cache),
                                   cfg.n_layers, cfg.scan_layers)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = unembed(h[:, -1:, :], _unembed_table(params, cfg), cfg.logit_softcap)
    return logits[:, 0, :], new_cache


def _scan_or_unroll(body, carry, xs, n: int, use_scan: bool):
    """lax.scan, or an unrolled loop that restacks the per-layer outputs."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked
