"""Encoder-decoder backbone (seamless-m4t): bidirectional encoder over stub
frame embeddings + causal decoder with cross-attention.

The speech frontend is a stub — ``input_specs`` supplies (B, frames, d_model)
embeddings; a trainable projection maps them into the encoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain_tokens_3d

from .attention import (
    attention_train,
    cross_attention,
    decode_attention,
    init_attention,
    init_kv_cache,
    prefill_attention,
)
from .layers import (
    embed_tokens,
    init_dense,
    init_embedding,
    init_mlp,
    init_rms_norm,
    mlp,
    rms_norm,
    unembed,
)
from .transformer import _scan_or_unroll


def init_enc_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_rms_norm(cfg.d_model, cfg.param_dtype),
        "attn": init_attention(ks[0], cfg),
        "ln2": init_rms_norm(cfg.d_model, cfg.param_dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def init_dec_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_rms_norm(cfg.d_model, cfg.param_dtype),
        "attn": init_attention(ks[0], cfg),
        "lnx": init_rms_norm(cfg.d_model, cfg.param_dtype),
        "xattn": init_attention(ks[1], cfg),
        "ln2": init_rms_norm(cfg.d_model, cfg.param_dtype),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def init_encdec_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_dec_layers)
    return {
        "frontend_proj": init_dense(ks[2], (cfg.d_model, cfg.d_model),
                                    cfg.param_dtype),
        "embed": init_embedding(ks[3], cfg.vocab_size, cfg.d_model,
                                cfg.param_dtype),
        "encoder": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "decoder": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "enc_ln": init_rms_norm(cfg.d_model, cfg.param_dtype),
        "final_ln": init_rms_norm(cfg.d_model, cfg.param_dtype),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_src, D) stub embeddings -> encoder memory."""
    cd = cfg.compute_dtype
    x = jnp.einsum("bfd,de->bfe", frames.astype(cd),
                   params["frontend_proj"].astype(cd))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, p):
        h = constrain_tokens_3d(h)
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        h = h + attention_train(hn, p["attn"], cfg, positions, bidirectional=True)
        hn = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + mlp(hn, p["mlp"], cfg.act, cd)
        return h, None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = _scan_or_unroll(body, x, params["encoder"], cfg.n_enc_layers,
                           cfg.scan_layers)
    return rms_norm(x, params["enc_ln"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, tokens: jax.Array, memory: jax.Array):
    """Teacher-forced decoder hidden states."""
    cd = cfg.compute_dtype
    x = embed_tokens(tokens, params["embed"], cd)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, p):
        h = constrain_tokens_3d(h)
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        h = h + attention_train(hn, p["attn"], cfg, positions)
        hn = rms_norm(h, p["lnx"], cfg.norm_eps)
        h = h + cross_attention(hn, memory, p["xattn"], cfg)
        hn = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + mlp(hn, p["mlp"], cfg.act, cd)
        return h, None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = _scan_or_unroll(body, x, params["decoder"], cfg.n_dec_layers,
                           cfg.scan_layers)
    return rms_norm(x, params["final_ln"], cfg.norm_eps)


def encdec_loss(params, cfg: ModelConfig, batch: dict):
    memory = encode(params, cfg, batch["frontend"])
    h = decode_train(params, cfg, batch["tokens"], memory)
    B, S, _ = h.shape
    n_pred = S - 1
    logits = unembed(h[:, :n_pred], params["embed"])
    tgt = batch["tokens"][:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.sum(lse - picked) / (B * n_pred)
    return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_encdec_cache(cfg: ModelConfig, batch: int, s_max: int, src_len: int):
    xshape = (batch, src_len, cfg.n_kv_heads, cfg.head_dim)
    per_layer = [
        {
            "kv": init_kv_cache(cfg, batch, s_max),
            # cross K/V filled at prefill from the encoder memory
            "xk": jnp.zeros(xshape, cfg.kv_cache_dtype),
            "xv": jnp.zeros(xshape, cfg.kv_cache_dtype),
        }
        for _ in range(cfg.n_dec_layers)
    ]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def encdec_prefill(params, cfg: ModelConfig, batch: dict, cache):
    """Encode source, prefill decoder self-cache, compute cross K/V."""
    cd = cfg.compute_dtype
    memory = encode(params, cfg, batch["frontend"])
    tokens = batch["tokens"]
    x = embed_tokens(tokens, params["embed"], cd)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, layer):
        p, c = layer
        h = constrain_tokens_3d(h)
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        att, kv = prefill_attention(hn, p["attn"], cfg, positions, c["kv"])
        h = h + att
        hn = rms_norm(h, p["lnx"], cfg.norm_eps)
        h = h + cross_attention(hn, memory, p["xattn"], cfg)
        xk = jnp.einsum("bsd,dhk->bshk", memory, p["xattn"]["wk"].astype(cd))
        xv = jnp.einsum("bsd,dhk->bshk", memory, p["xattn"]["wv"].astype(cd))
        hn = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + mlp(hn, p["mlp"], cfg.act, cd)
        return h, {"kv": kv, "xk": xk.astype(c["xk"].dtype),
                   "xv": xv.astype(c["xk"].dtype)}

    h, new_cache = _scan_or_unroll(body, x, (params["decoder"], cache),
                                   cfg.n_dec_layers, cfg.scan_layers)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = unembed(h[:, -1:, :], params["embed"])
    return logits[:, 0, :], new_cache


def encdec_decode_step(params, cfg: ModelConfig, token, cur_len, cache):
    cd = cfg.compute_dtype
    x = embed_tokens(token[:, None], params["embed"], cd)

    def body(h, layer):
        p, c = layer
        h = constrain_tokens_3d(h)
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        att, kv = decode_attention(hn, p["attn"], cfg, c["kv"], cur_len)
        h = h + att
        hn = rms_norm(h, p["lnx"], cfg.norm_eps)
        h = h + _cached_cross(hn, c["xk"], c["xv"], p["xattn"], cfg)
        hn = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + mlp(hn, p["mlp"], cfg.act, cd)
        c_new = dict(c)
        c_new["kv"] = kv
        return h, c_new

    h, new_cache = _scan_or_unroll(body, x, (params["decoder"], cache),
                                   cfg.n_dec_layers, cfg.scan_layers)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = unembed(h[:, -1:, :], params["embed"])
    return logits[:, 0, :], new_cache


def _cached_cross(x, xk, xv, p, cfg: ModelConfig):
    from .attention import _expand_kv, _sdpa

    cd = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    mask = jnp.ones((1, 1, x.shape[1], xk.shape[1]), dtype=bool)
    out = _sdpa(q, _expand_kv(xk.astype(cd), cfg.n_heads),
                _expand_kv(xv.astype(cd), cfg.n_heads), mask, cd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
