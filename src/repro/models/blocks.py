"""Per-layer blocks for every architecture family, shaped for lax.scan.

Each family provides:
  * ``init_layer(key, cfg)``     — one layer's parameter pytree,
  * ``layer_train(p, cfg, x, positions, flag)``   -> (x, aux_loss),
  * ``layer_prefill(p, cfg, x, positions, cache)`` -> (x, cache),
  * ``layer_decode(p, cfg, x, cur_len, cache)``    -> (x, cache),
  * ``init_layer_cache(cfg, batch, s_max)``        — one layer's decode cache.

Layers are stacked (leading L axis) via vmap'd init and scanned over, so the
compiled HLO contains each layer body once regardless of depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain_tokens_3d
from . import xlstm as xl
from .attention import (
    attention_train,
    decode_attention,
    init_attention,
    init_kv_cache,
    prefill_attention,
)
from .layers import init_mlp, init_rms_norm, mlp, rms_norm
from .moe import init_moe, moe_layer
from .ssm import (
    init_ssm,
    init_ssm_state,
    ssm_decode,
    ssm_prefill,
    ssm_train,
)

ZERO = jnp.zeros((), jnp.float32)


def attn_window(cfg: ModelConfig) -> int:
    return cfg.window if cfg.family == "hybrid" else 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    fam = cfg.family
    p: dict = {"ln1": init_rms_norm(d, cfg.param_dtype)}
    if fam in ("dense", "vlm", "moe", "hybrid"):
        p["attn"] = init_attention(ks[0], cfg)
        p["ln2"] = init_rms_norm(d, cfg.param_dtype)
    if fam in ("dense", "vlm", "hybrid"):
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.param_dtype)
    if fam == "moe":
        p["moe"] = init_moe(ks[2], cfg)
    if fam == "hybrid":
        p["ssm"] = init_ssm(ks[3], cfg)
    if fam == "ssm":  # xLSTM: dual param sets, per-layer flag picks one
        p["mlstm"] = xl.init_mlstm(ks[4], cfg)
        p["slstm"] = xl.init_slstm(ks[5], cfg)
    return p


def init_stacked_layers(key, cfg: ModelConfig, n_layers: int):
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: init_layer(k, cfg))(keys)


def layer_flags(cfg: ModelConfig) -> jax.Array:
    """Per-layer scalar flags consumed as scan xs (xLSTM: is_slstm)."""
    n = cfg.n_layers if not cfg.is_encdec else cfg.n_dec_layers
    if cfg.family == "ssm" and cfg.slstm_every > 0:
        idx = jnp.arange(n)
        return (jnp.mod(idx + 1, cfg.slstm_every) == 0)
    return jnp.zeros((n,), dtype=bool)


# ---------------------------------------------------------------------------
# train (full sequence, no cache)
# ---------------------------------------------------------------------------

def layer_train(p: dict, cfg: ModelConfig, x, positions, flag) -> tuple[jax.Array, jax.Array]:
    x = constrain_tokens_3d(x)   # anchor per-layer activation sharding
    fam = cfg.family
    if fam in ("dense", "vlm"):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attention_train(h, p["attn"], cfg, positions, window=attn_window(cfg))
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(h, p["mlp"], cfg.act, cfg.compute_dtype)
        return x, ZERO
    if fam == "moe":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attention_train(h, p["attn"], cfg, positions)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, aux = moe_layer(h, p["moe"], cfg)
        return x + y, aux
    if fam == "hybrid":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        att = attention_train(h, p["attn"], cfg, positions, window=cfg.window)
        ssm = ssm_train(h, p["ssm"], cfg)
        x = x + att + ssm
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(h, p["mlp"], cfg.act, cfg.compute_dtype)
        return x, ZERO
    if fam == "ssm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y = jax.lax.cond(
            flag,
            lambda hh: xl.slstm_train(hh, p["slstm"], cfg),
            lambda hh: xl.mlstm_train(hh, p["mlstm"], cfg),
            h,
        )
        return x + y, ZERO
    raise KeyError(fam)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, batch: int, s_max: int):
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return {"kv": init_kv_cache(cfg, batch, s_max)}
    if fam == "hybrid":
        w = min(cfg.window, s_max) if cfg.window else s_max
        return {
            "kv": init_kv_cache(cfg, batch, w),
            "ssm": init_ssm_state(cfg, batch),
        }
    if fam == "ssm":
        return {
            "mlstm": xl.init_mlstm_state(cfg, batch),
            "slstm": xl.init_slstm_state(cfg, batch),
        }
    raise KeyError(fam)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def layer_prefill(p: dict, cfg: ModelConfig, x, positions, cache, flag):
    x = constrain_tokens_3d(x)
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        att, kv = prefill_attention(h, p["attn"], cfg, positions, cache["kv"],
                                    window=attn_window(cfg))
        x = x + att
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if fam == "moe":
            y, _ = moe_layer(h, p["moe"], cfg)
            x = x + y
        else:
            x = x + mlp(h, p["mlp"], cfg.act, cfg.compute_dtype)
        return x, {"kv": kv}
    if fam == "hybrid":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        att, kv = prefill_attention(h, p["attn"], cfg, positions, cache["kv"],
                                    window=cfg.window)
        ssm_y, ssm_state = ssm_prefill(h, p["ssm"], cfg, cache["ssm"])
        x = x + att + ssm_y
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(h, p["mlp"], cfg.act, cfg.compute_dtype)
        return x, {"kv": kv, "ssm": ssm_state}
    if fam == "ssm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)

        def do_slstm(hh):
            y, st = xl.slstm_train(hh, p["slstm"], cfg, state=cache["slstm"],
                                   return_state=True)
            return y, cache["mlstm"], st

        def do_mlstm(hh):
            y, st = xl.mlstm_train(hh, p["mlstm"], cfg, state=cache["mlstm"],
                                   return_state=True)
            return y, st, cache["slstm"]

        y, mstate, sstate = jax.lax.cond(flag, do_slstm, do_mlstm, h)
        return x + y, {"mlstm": mstate, "slstm": sstate}
    raise KeyError(fam)


# ---------------------------------------------------------------------------
# decode (one token)
# ---------------------------------------------------------------------------

def layer_decode(p: dict, cfg: ModelConfig, x, cur_len, cache, flag):
    x = constrain_tokens_3d(x)
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        att, kv = decode_attention(h, p["attn"], cfg, cache["kv"], cur_len,
                                   window=attn_window(cfg))
        x = x + att
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if fam == "moe":
            y, _ = moe_layer(h, p["moe"], cfg)
            x = x + y
        else:
            x = x + mlp(h, p["mlp"], cfg.act, cfg.compute_dtype)
        return x, {"kv": kv}
    if fam == "hybrid":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        att, kv = decode_attention(h, p["attn"], cfg, cache["kv"], cur_len,
                                   window=cfg.window)
        ssm_y, ssm_state = ssm_decode(h, p["ssm"], cfg, cache["ssm"])
        x = x + att + ssm_y
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(h, p["mlp"], cfg.act, cfg.compute_dtype)
        return x, {"kv": kv, "ssm": ssm_state}
    if fam == "ssm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)

        def do_slstm(hh):
            y, st = xl.slstm_decode(hh, p["slstm"], cfg, cache["slstm"])
            return y, cache["mlstm"], st

        def do_mlstm(hh):
            y, st = xl.mlstm_decode(hh, p["mlstm"], cfg, cache["mlstm"])
            return y, st, cache["slstm"]

        y, mstate, sstate = jax.lax.cond(flag, do_slstm, do_mlstm, h)
        return x + y, {"mlstm": mstate, "slstm": sstate}
    raise KeyError(fam)
