"""Mixture-of-Experts layer: top-k routing with capacity-bounded scatter
dispatch and expert-parallel (EP) sharding over the 'model' mesh axis.

TPU adaptation: instead of a ragged CUDA grouped-GEMM, tokens are scattered
into a static (E, C, D) buffer (capacity C per expert) and expert FFNs run as
one batched einsum over stacked expert weights — the buffer's expert axis is
sharded over 'model', so XLA inserts the dispatch all-to-all automatically.
Overflowing tokens are dropped (standard capacity-factor routing); the
residual path carries them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from .layers import activation, init_dense


def init_moe(key, cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], (d, e), jnp.float32, fan_in=d),
        "wi": init_dense(ks[1], (e, d, f), cfg.param_dtype, fan_in=d),
        "wg": init_dense(ks[2], (e, d, f), cfg.param_dtype, fan_in=d),
        "wo": init_dense(ks[3], (e, f, d), cfg.param_dtype, fan_in=f),
    }


def expert_capacity(cfg: ModelConfig, group_tokens: int) -> int:
    """Per-group (= per sequence) expert capacity."""
    c = int(cfg.capacity_factor * group_tokens * cfg.top_k / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)  # pad to 8 for TPU-friendly layout


def _position_in_expert(flat_e: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each (token, choice) among same-expert picks, O(N log N).

    Sort-based: rank = index_in_sorted - first_index_of_expert, scattered back.
    """
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e)                      # stable
    sorted_e = flat_e[order]
    first_of_expert = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
    ranks_sorted = jnp.arange(n) - first_of_expert[sorted_e]
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted.astype(jnp.int32))
    return ranks


def moe_layer(x: jax.Array, p: dict, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Router in fp32.

    Dispatch is per-sequence (group = one batch row): the (B, E, C, D) buffer
    keeps its leading dim sharded over the data axis, so routing/scatter is
    DP-local and only the expert einsum crosses the mesh (all-to-all from the
    E-axis sharding).  Capacity is per group (standard group_size routing).
    """
    cd = cfg.compute_dtype
    B, S, D = x.shape
    E, K, F = cfg.n_experts, cfg.top_k, cfg.moe_d_ff

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)        # (B, S, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style), fp32
    me = probs.mean(axis=(0, 1))                            # (E,)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)   # (B, S, K, E)
    ce = onehot.mean(axis=(0, 1, 2))
    aux = E * jnp.sum(me * ce)

    C = expert_capacity(cfg, S)

    flat_e = expert_idx.reshape(B, S * K)                   # per-group pairs
    pos = jax.vmap(lambda fe: _position_in_expert(fe, E))(flat_e)  # (B, S*K)
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)         # (B, S*K)

    # scatter tokens into the per-group (E*C+1, D) buffer (last row = trash)
    tok_rep = jnp.repeat(x.astype(cd), K, axis=1)           # (B, S*K, D)
    buf = jnp.zeros((B, E * C + 1, D), cd)
    buf = jax.vmap(lambda bb, dd, tt: bb.at[dd].set(tt, mode="drop"))(
        buf, dest, tok_rep
    )
    buf = buf[:, : E * C].reshape(B, E, C, D)
    buf = constrain(buf, "dp", "model", None, None)   # EP: experts over TP axis

    # expert FFN (SwiGLU); E shards over 'model' (EP) -> all-to-all at entry
    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(cd))
    g = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(cd))
    out = jnp.einsum("becf,efd->becd", h * activation(g, cfg.act),
                     p["wo"].astype(cd))

    # gather back and combine with gates
    out_flat = out.reshape(B, E * C, D)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((B, 1, D), cd)], axis=1)
    gathered = jax.vmap(lambda of, dd: of[dd])(out_flat, dest)   # (B, S*K, D)
    gates = (gate_vals.reshape(B, S * K) * keep).astype(cd)
    y = (gathered * gates[..., None]).reshape(B, S, K, D).sum(axis=2)
    return y, aux
