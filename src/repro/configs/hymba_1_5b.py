"""hymba-1.5b [hybrid]: parallel attention + Mamba heads [arXiv:2411.13676; hf].

Adaptation note (DESIGN.md): all attention layers use a sliding window (2048)
so the hybrid SSM state carries global context; the published model keeps 3
full-attention layers.  This keeps the 500k-decode KV cache O(window).
"""
from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        head_dim=64,
        ssm_state=16,
        window=2048,
        attn_chunk=256,
        rope_theta=10_000.0,
    ),
    reduced=ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        ssm_state=8,
        window=16,
        attn_chunk=8,
    ),
)
