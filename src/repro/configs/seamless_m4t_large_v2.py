"""seamless-m4t-large-v2 [audio]: enc-dec, multimodal [arXiv:2308.11596; hf].

The speech frontend (w2v-BERT feature extractor) is a STUB: ``input_specs``
provides precomputed frame embeddings (B, frames, d_model).  24 encoder +
24 decoder layers (the assigned 24L is the per-stack depth).
"""
from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        n_layers=48,             # 24 enc + 24 dec
        n_enc_layers=24,
        n_dec_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        head_dim=64,
        act="gelu",
        frontend="audio_stub",
        rope_theta=10_000.0,
    ),
    reduced=ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        n_layers=4,
        n_enc_layers=2,
        n_dec_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        act="gelu",
        frontend="audio_stub",
    ),
)
