"""Model / run configuration dataclasses and the architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # 0 -> d_ff
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    window: int = 0             # sliding attention window; 0 = full attention
    slstm_every: int = 0        # xLSTM: every k-th layer is an sLSTM block

    # encoder-decoder
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # modality frontends (STUBS: input_specs provides embeddings directly)
    frontend: str = "none"      # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0

    # misc
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    act: str = "silu"           # silu | gelu
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    attn_chunk: int = 0         # chunked linear-recurrence chunk size (SSM)

    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    # training substrate knobs (hillclimbing levers)
    remat: str = "full"         # none | dots | full
    kv_cache_dtype: Any = jnp.bfloat16   # jnp.float8_e4m3fn halves decode traffic
    scan_layers: bool = True
    sp_decode: bool = False     # shard the KV cache along sequence over 'model'
    local_attention: bool = False  # banded chunked attention for window > 0
    seq_parallel: bool = False  # Megatron-SP residual stream: S over 'model'
                                # between blocks (all-reduce -> RS + AG)
    fsdp_only: bool = False     # no TP: params sharded over BOTH mesh axes,
                                # batch over both axes (for models whose
                                # d_model is too small for TP=16)
    serve_weight_dtype: Any = None  # cast weights for serving bundles
                                    # (bfloat16 halves decode weight traffic)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.moe_d_ff == 0 and self.n_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode a 500k context without a full-attention KV?"""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, ModelConfig] = {}
_REDUCED: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, reduced: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced
    return cfg


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(table)}")
    return table[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells, honouring the long_500k sub-quadratic skip."""
    _ensure_loaded()
    cells = []
    for arch in sorted(_REGISTRY):
        cfg = _REGISTRY[arch]
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.subquadratic:
                continue  # full-attention archs skip 500k decode (DESIGN.md)
            cells.append((arch, shape.name))
    return cells


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from . import (  # noqa: F401  (import side effect: registration)
        command_r_plus_104b,
        deepseek_67b,
        hymba_1_5b,
        llama3_2_1b,
        llama4_scout_17b_a16e,
        paligemma_3b,
        qwen3_moe_30b_a3b,
        seamless_m4t_large_v2,
        xlstm_1_3b,
        yi_9b,
    )
