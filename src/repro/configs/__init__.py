"""Architecture configs: one module per assigned architecture.

``get_config("<arch-id>")`` returns the exact published configuration;
``get_config("<arch-id>", reduced=True)`` returns a small same-family config
for CPU smoke tests.
"""
from .base import (
    SHAPES,
    ModelConfig,
    ShapeCell,
    get_config,
    list_archs,
    register,
    runnable_cells,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeCell",
    "get_config",
    "list_archs",
    "register",
    "runnable_cells",
]
