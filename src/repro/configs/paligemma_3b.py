"""paligemma-3b [vlm]: SigLIP + gemma [arXiv:2407.07726; hf].

The SigLIP vision tower is a STUB: ``input_specs`` provides precomputed patch
embeddings (B, 256, d_model); a trainable projection fuses them with text.
"""
from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        d_ff=16384,
        vocab_size=257216,
        head_dim=256,
        act="gelu",
        frontend="vision_stub",
        n_frontend_tokens=256,
        rope_theta=10_000.0,
        tie_embeddings=True,
    ),
    reduced=ModelConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        head_dim=32,
        act="gelu",
        frontend="vision_stub",
        n_frontend_tokens=8,
        tie_embeddings=True,
    ),
)
