"""deepseek-67b [dense]: llama-arch GQA [arXiv:2401.02954; hf]."""
from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        head_dim=128,
        rope_theta=10_000.0,
    ),
    reduced=ModelConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=3,
        d_model=96,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        head_dim=24,
    ),
)
