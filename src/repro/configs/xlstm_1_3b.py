"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

mLSTM blocks use the chunked matrix-memory recurrence (MXU-friendly); every
``slstm_every``-th layer is a sequential sLSTM block (lax.scan).  d_ff = 0:
xLSTM blocks carry their own up/down projections (expand factor 2).
"""
from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        head_dim=512,
        ssm_expand=2,
        slstm_every=8,
        attn_chunk=256,
    ),
    reduced=ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        head_dim=32,
        ssm_expand=2,
        slstm_every=2,
        attn_chunk=8,
    ),
)
