"""llama3.2-1b [dense] [hf:meta-llama/Llama-3.2-1B; unverified]."""
from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama3.2-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        head_dim=64,
        rope_theta=500_000.0,
        tie_embeddings=True,
    ),
    reduced=ModelConfig(
        name="llama3.2-1b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=16,
        tie_embeddings=True,
    ),
)
