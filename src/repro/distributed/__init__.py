"""Distribution: sharding rules, collectives, fault tolerance, elastic scaling."""
