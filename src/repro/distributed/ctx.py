"""Trace-time sharding context: activation constraints for model code.

Model code is mesh-agnostic; step builders install a context (mesh + dp axes)
around tracing, and ``constrain`` points in the model then pin activation
shardings so XLA propagation can't collapse to replication (it does for
head counts indivisible by the TP axis — caught by the dry-run).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_shard_ctx", default=None)


@contextlib.contextmanager
def shard_ctx(mesh: Mesh, seq_parallel: bool = False,
              fsdp_only: bool = False) -> Iterator[None]:
    if fsdp_only:
        dp = tuple(mesh.axis_names)
    elif "pod" in mesh.axis_names:
        dp = ("pod", "data")
    else:
        dp = ("data",)
    token = _CTX.set((mesh, dp, seq_parallel, fsdp_only))
    try:
        yield
    finally:
        _CTX.reset(token)


def _get():
    return _CTX.get()


def constrain(x: jax.Array, *spec) -> jax.Array:
    """Pin sharding: 'dp' entries expand to the data axes; None = replicated.

    No-op when no context is installed (single-host tests) or when a dim is
    indivisible by its axes.
    """
    ctx = _get()
    if ctx is None:
        return x
    mesh, dp = ctx[0], ctx[1]
    import math

    names = []
    used: set = set()
    for dim, s in enumerate(spec):
        if s == "dp":
            size = math.prod(mesh.shape[a] for a in dp)
            if x.shape[dim] % size == 0:
                names.append(dp)
                used.update(dp)
            else:
                names.append(None)
        elif s is None or s in used:       # a mesh axis may appear only once
            names.append(None)
        else:
            if x.shape[dim] % mesh.shape[s] == 0:
                names.append(s)
                used.add(s)
            else:
                names.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*names))
    )


def constrain_tokens_3d(x: jax.Array) -> jax.Array:
    """(B, S, D) activations: batch over dp (+ S over 'model' in SP mode).

    Megatron-style sequence parallelism: pinning the residual stream
    S-sharded between blocks turns each TP boundary all-reduce into a
    reduce-scatter (1/TP the result bytes) + a later all-gather, and stores
    layer-boundary activations at 1/TP the footprint.
    """
    ctx = _get()
    if ctx is not None and len(ctx) > 2 and ctx[2]:
        return constrain(x, "dp", "model", None)
    return constrain(x, "dp", None, None)


def constrain_attention_decode(q: jax.Array, k: jax.Array, v: jax.Array):
    """Decode layout: KV sequence sharded over 'model', q tiny + replicated.

    The masked softmax over the sharded KV length lowers to local partials +
    small psums of the (B, H, 1) stats — the collective-optimal way to read
    a long cache when kv_heads don't divide the TP axis (all assigned archs).
    """
    ctx = _get()
    if ctx is None:
        return q, k, v
    mesh = ctx[0]
    tp = mesh.shape["model"]
    if k.shape[1] % tp == 0:
        k = constrain(k, "dp", "model", None, None)
        v = constrain(v, "dp", "model", None, None)
        q = constrain(q, "dp", None, None, None)
    return q, k, v


def constrain_attention(q: jax.Array, k: jax.Array, v: jax.Array):
    """Pick the attention TP layout for (B, S, H, hd) tensors.

    Heads shard over 'model' when divisible (Megatron); otherwise queries
    shard along their *sequence* dim (context parallelism) with K/V
    replicated — so archs like hymba (25H) / llama4 (40H) / paligemma (8H)
    still split their S x S score matrices across the TP axis instead of
    replicating them (dry-run caught 16x waste + 40GB scores otherwise).
    """
    ctx = _get()
    if ctx is None or (len(ctx) > 3 and ctx[3]):   # fsdp_only: dp covers all
        return q, k, v
    mesh = ctx[0]
    tp = mesh.shape["model"]
    if q.shape[2] % tp == 0 and k.shape[2] % tp == 0:
        q = constrain(q, "dp", None, "model", None)
        k = constrain(k, "dp", None, "model", None)
        v = constrain(v, "dp", None, "model", None)
    elif q.shape[1] % tp == 0 and q.shape[1] > 1:
        q = constrain(q, "dp", "model", None, None)
        k = constrain(k, "dp", None, None, None)
        v = constrain(v, "dp", None, None, None)
    return q, k, v
