"""Fault tolerance: preemption handling, straggler mitigation, failure policy.

Production posture (1000+ nodes, DESIGN.md §5):
  * checkpoint/restart — atomic async checkpoints + deterministic
    step-indexed data (``TokenPipeline.batch_at``) give exactly-once
    semantics across restarts;
  * preemption — SIGTERM triggers a final checkpoint before exit;
  * stragglers — per-step wall-time is tracked with an EMA; a replica/pod
    whose step time exceeds ``threshold x`` the fleet median is *evicted the
    way the paper retires a server*: it is treated as a departed job at the
    provisioning layer (LIFO push), and re-admitted only when demand pops it
    — no state migration, identical to the no-KV-migration argument.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable


@dataclasses.dataclass
class PreemptionGuard:
    """Installs SIGTERM/SIGINT hooks that request a clean stop."""

    requested: bool = False

    def install(self) -> "PreemptionGuard":
        def handler(signum, frame):
            self.requested = True

        signal.signal(signal.SIGTERM, handler)
        return self

    def should_stop(self) -> bool:
        return self.requested


@dataclasses.dataclass
class StragglerDetector:
    """EMA-based straggler detection over per-worker step times."""

    threshold: float = 2.0
    decay: float = 0.9
    ema: dict = dataclasses.field(default_factory=dict)

    def observe(self, worker: int, step_time: float) -> None:
        prev = self.ema.get(worker, step_time)
        self.ema[worker] = self.decay * prev + (1 - self.decay) * step_time

    def median(self) -> float:
        if not self.ema:
            return 0.0
        vals = sorted(self.ema.values())
        return vals[len(vals) // 2]

    def stragglers(self) -> list[int]:
        med = self.median()
        if med <= 0:
            return []
        return [w for w, v in self.ema.items() if v > self.threshold * med]


@dataclasses.dataclass
class StepWatchdog:
    """Wall-clock budget per step; on breach calls the eviction callback.

    The callback is expected to push the worker into the provisioning stack
    (paper semantics: the straggler 'departs'); the autoscaler's ski-rental
    then decides whether it powers off.
    """

    budget_s: float
    on_evict: Callable[[int], None]
    _start: float = 0.0

    def begin(self) -> None:
        self._start = time.monotonic()

    def end(self, worker: int) -> bool:
        elapsed = time.monotonic() - self._start
        if elapsed > self.budget_s:
            self.on_evict(worker)
            return True
        return False
