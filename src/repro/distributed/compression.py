"""Gradient compression with error feedback (cross-pod DP traffic reduction).

int8 per-tensor quantization cuts the inter-pod all-reduce payload 4x
(fp32->int8); the quantization error is carried in an error-feedback buffer
and re-added next step, which keeps SGD/Adam convergence (Seide et al.,
Karimireddy et al.).  In the SPMD program the quantize -> all-reduce ->
dequantize sandwich is expressed by casting before the grad psum; here the
transform wraps the grad tree so it also runs (and is testable) on one host.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any          # same structure as grads, fp32


def init_error_feedback(params: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(
    grads: Any, state: ErrorFeedbackState
) -> tuple[Any, ErrorFeedbackState, dict]:
    """Returns (compressed-then-decompressed grads, new EF state, metrics).

    The returned grads are exactly what every pod would see after an int8
    all-reduce; the residual keeps the information the quantizer dropped.
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq, g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    err_norm = jnp.sqrt(sum(jnp.sum(jnp.square(o[1])) for o in outs))
    return new_g, ErrorFeedbackState(residual=new_r), {"ef_residual_norm": err_norm}


def compression_ratio(grads: Any) -> float:
    """fp32 bytes / int8 bytes for the inter-pod payload."""
    return 4.0
