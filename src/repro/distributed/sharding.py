"""PartitionSpec rules for parameters, optimizer state, activations, caches.

Strategy (DESIGN.md §5): 2-D FSDP x TP inside a pod —

  * parameters/optimizer state: one dim sharded over 'data' (FSDP / ZeRO-3),
    one over 'model' (TP);   the 'pod' axis is pure DP (grad all-reduce).
  * activations: batch over ('pod','data'), model-parallel dims over 'model'.
  * KV caches: batch over dp, heads (or head_dim) over 'model'.

Rules are *candidate lists* per parameter name; each candidate is filtered by
divisibility against the actual mesh and the highest-coverage survivor wins.
This keeps every (arch x mesh) cell compilable without per-arch tables — e.g.
hymba's vocab 32001 is indivisible, so the embedding falls back to sharding
d_model only.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP_AXIS = "data"
TP_AXIS = "model"


def dp_axes(mesh: Mesh):
    """Axes used for data parallelism (batch dim)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return math.prod(_axis_size(mesh, n) for n in name)
    return mesh.shape[name]


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> tuple[P | None, int]:
    """Drop axis names whose size doesn't divide the dim; return (spec, score)."""
    out = []
    score = 1
    for d, name in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if name is None:
            out.append(None)
            continue
        size = _axis_size(mesh, name)
        if shape[d] % size == 0:
            out.append(name)
            score *= size
        else:
            out.append(None)
    return P(*out), score


def best_spec(candidates: list[P], shape: tuple[int, ...], mesh: Mesh) -> P:
    best, best_score = P(), 0
    for cand in candidates:
        spec, score = fit_spec(cand, shape, mesh)
        if score > best_score:
            best, best_score = spec, score
    return best


# ---------------------------------------------------------------------------
# Parameter rules (leaf-name keyed; leading L axis handled by the caller)
# ---------------------------------------------------------------------------

def _param_candidates(path: tuple[str, ...], shape: tuple[int, ...]) -> list[P]:
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    f, t = FSDP_AXIS, TP_AXIS
    rank = len(shape)

    if name in ("embed", "unembed"):                       # (V, D)
        return [P(t, f), P(f, t), P(None, t), P(None, f)]
    if name in ("final_ln", "enc_ln", "ln1", "ln2", "lnx"):
        return [P()]
    if name == "frontend_proj":
        return [P(f, t), P(None, t)]
    if parent in ("attn", "xattn"):
        # Megatron-style: shard heads over 'model'; when the head count is
        # indivisible (hymba 25H/5KV, paligemma 1KV) fall back to replicated
        # heads — flat SDPA then runs model-replicated (see DESIGN.md §Perf).
        if name == "wq":                                   # (D, H, hd)
            return [P(f, t, None), P(f, None, None)]
        if name in ("wk", "wv"):                           # (D, KVH, hd)
            return [P(f, t, None), P(f, None, None)]
        if name == "wo":                                   # (H, hd, D)
            return [P(t, None, f), P(None, None, f)]
    if parent == "mlp":
        if name in ("wi", "wg"):                           # (D, F)
            return [P(f, t), P(None, t)]
        if name == "wo":                                   # (F, D)
            return [P(t, f), P(t, None)]
    if parent == "moe":
        if name == "router":                               # (D, E)
            return [P(f, None), P()]
        if name in ("wi", "wg"):                           # (E, D, F)
            return [P(t, f, None), P(t, None, None), P(None, f, t)]
        if name == "wo":                                   # (E, F, D)
            return [P(t, None, f), P(t, None, None), P(None, t, f)]
    if parent == "ssm":
        if name == "in_proj":                              # (D, 2di)
            return [P(f, t), P(None, t)]
        if name == "conv":                                 # (W, di)
            return [P(None, t)]
        if name in ("wbc", "wdt"):                         # (di, .)
            return [P(t, None)]
        if name == "out_proj":                             # (di, D)
            return [P(t, f), P(t, None)]
        return [P()]                                       # a_log, d_skip, dt_bias
    if parent == "mlstm":
        if name == "in_proj":
            return [P(f, t), P(None, t)]
        if name in ("wq", "wk"):                           # (di, nh, hd)
            return [P(t, None, None), P(None, None, t)]
        if name == "wif":                                  # (di, 2nh)
            return [P(t, None)]
        if name == "out_proj":
            return [P(t, f), P(t, None)]
        return [P()]
    if parent == "slstm":
        if name == "w_in":                                 # (D, nh, 4hd)
            return [P(f, None, t), P(None, None, t)]
        if name == "r_in":                                 # (nh, hd, 4hd)
            return [P(None, None, t), P(None, t, None)]
        if name == "bias":                                 # (nh, 4hd)
            return [P(None, t)]
        if name == "out_proj":
            return [P(t, f), P(t, None)]
        return [P()]
    # fallback: shard the largest dim over model, next over data
    order = np.argsort(shape)[::-1]
    cand = [None] * rank
    cand[order[0]] = t
    if rank > 1:
        cand[order[1]] = f
    return [P(*cand), P()]


_STACKED_TOPS = ("blocks", "encoder", "decoder")


def _fsdp_only_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """Shard one dim over ALL mesh axes (ZeRO-3 across the whole slice)."""
    axes = tuple(mesh.axis_names)
    total = math.prod(mesh.shape[a] for a in axes)
    if len(shape) < 2:
        return P()
    for d in range(len(shape)):
        if shape[d] % total == 0:
            out = [None] * len(shape)
            out[d] = axes
            return P(*out)
    for d in range(len(shape)):          # fall back to the data axis only
        if shape[d] % mesh.shape[FSDP_AXIS] == 0:
            out = [None] * len(shape)
            out[d] = FSDP_AXIS
            return P(*out)
    return P()


def param_specs(params_shape: Any, mesh: Mesh, serving: bool = False,
                fsdp_only: bool = False) -> Any:
    """PartitionSpec tree matching an (abstract) parameter tree.

    ``serving``: inference replicas keep weights TP-sharded but replicated
    over the data axis (no ZeRO/FSDP — a per-token weight all-gather would
    dominate decode latency; the dry-run measured 0.17 s/token for
    deepseek-67b).  Training keeps FSDP over 'data'.
    """

    def walk(path, leaf):
        names = tuple(
            k.key if hasattr(k, "key") else (k.name if hasattr(k, "name") else str(k))
            for k in path
        )
        shape = leaf.shape
        stacked = names[0] in _STACKED_TOPS
        core_shape = shape[1:] if stacked else shape
        if fsdp_only:
            spec = _fsdp_only_spec(core_shape, mesh)
            return P(None, *spec) if stacked else spec
        cands = _param_candidates(names, core_shape)
        if serving:
            cands = [
                P(*(None if n == FSDP_AXIS else n for n in tuple(c)))
                for c in cands
            ]
        spec = best_spec(cands, core_shape, mesh)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(walk, params_shape)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh)
    )


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------

def batch_specs(batch_shape: Any, mesh: Mesh, fsdp_only: bool = False) -> Any:
    """Shard the leading batch dim over dp axes (dropped if indivisible)."""
    dp = tuple(mesh.axis_names) if fsdp_only else dp_axes(mesh)

    def leaf(x):
        if not x.shape:
            return P()
        return best_spec([P(dp), P(dp[-1:],)], x.shape, mesh)

    return jax.tree.map(leaf, batch_shape)


def cache_specs(cache_shape: Any, mesh: Mesh, prefer_seq: bool = False) -> Any:
    """KV caches: (L, B, S, KVH, hd) -> batch over dp, heads/hd over model.

    ``prefer_seq`` (sp_decode): shard the cache's *sequence* dim over
    'model' instead — decode attention then streams 1/TP of the cache per
    chip and combines partial softmax stats with a psum (XLA inserts it).

    SSM states (L, B, nh, dk, dv) and conv states (L, B, W, di) follow the
    same batch-first rule with 'model' on the widest trailing dim.
    """
    dp = dp_axes(mesh)
    t = TP_AXIS

    def walk(path, leaf):
        names = tuple(getattr(k, "key", getattr(k, "name", str(k))) for k in path)
        shape = leaf.shape
        name = names[-1] if names else ""
        if name == "pos" or len(shape) < 3:
            return P()
        # leading L (stacked layers), then batch
        if name in ("k", "v", "xk", "xv"):                 # (L, B, S, KVH, hd)
            # Sequence-sharding over 'model' is the default decode layout:
            # none of the assigned archs has kv_heads divisible by TP=16, and
            # a head_dim-sharded cache forces a full re-shard every step (the
            # dry-run measured a 2.1 GB/step all-gather on deepseek decode).
            cands = [
                P(None, dp, t, None, None),
                P(None, dp, None, t, None),
                P(None, dp, None, None, None),
            ]
            return best_spec(cands, shape, mesh)
        if name == "h":                                    # (L, B, nh, dk, dv)
            return best_spec(
                [P(None, dp, t, None, None), P(None, dp, None, None, t),
                 P(None, dp, None, None, None)],
                shape, mesh,
            )
        if name == "conv":                                 # (L, B, W, di)
            return best_spec(
                [P(None, dp, None, t), P(None, dp, None, None)], shape, mesh
            )
        # slstm states (L, B, nh, hd) etc.
        cands = [P(None, dp, None, t), P(None, dp, None, None)]
        if len(shape) == 3:
            cands = [P(None, dp, t), P(None, dp, None)]
        return best_spec(cands, shape, mesh)

    return jax.tree_util.tree_map_with_path(walk, cache_shape)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)
