"""Elastic scaling: restore any checkpoint onto any mesh.

Checkpoints store full (unsharded) arrays plus the logical parameter tree;
sharding is a pure function of (tree, mesh) — ``param_specs`` — so restoring
onto a larger/smaller mesh is just a different ``device_put`` placement.
Combined with the provisioning layer this implements the paper's dynamic
capacity at the *training* tier: pods join/leave the data-parallel axis and
training resumes from the latest step with a resharded state.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from repro.checkpoint import restore
from repro.distributed.sharding import param_shardings


def reshard_restore(directory: str, step: int, like: Any, mesh: Mesh) -> Any:
    """Restore ``like``-structured state placing it for ``mesh``."""
    shardings = param_shardings(jax.eval_shape(lambda: like), mesh)
    return restore(directory, step, like, shardings=shardings)


def global_batch_for(mesh: Mesh, per_replica_batch: int) -> int:
    """Elastic global batch: scales with the data-parallel extent."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    return per_replica_batch * dp
