"""Fault-tolerant checkpointing: atomic, async, elastic-reshard on restore."""
from .checkpointer import Checkpointer, latest_step, restore, save

__all__ = ["Checkpointer", "latest_step", "restore", "save"]
