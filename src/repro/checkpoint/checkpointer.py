"""Sharded, atomic, asynchronous checkpointing.

Layout: <dir>/step_<k>/
          manifest.json       tree structure + shapes/dtypes + step metadata
          arr_<i>.npy         one file per leaf (full array; per-host shards
                              in a true multi-host deployment — the manifest
                              carries the PartitionSpec so restore can place
                              shards on ANY mesh: elastic resharding is free)

Atomicity: everything is written into ``step_<k>.tmp`` and renamed — a crash
mid-write never corrupts the latest complete checkpoint.  ``Checkpointer``
runs saves on a background thread (training never blocks on I/O) and keeps
the most recent ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str | Path, step: int, tree: Any, extra: dict | None = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(tmp / f"arr_{i}.npy", arr)
        manifest["leaves"].append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(directory: str | Path, step: int, like: Any, shardings: Any | None = None) -> Any:
    """Restore into the structure of ``like``; optionally place with
    ``shardings`` (a matching tree of Shardings) — restoring onto a different
    mesh than the one that saved is the elastic-resize path."""
    path = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    like_leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(like_leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(like_leaves)}"
    )
    out = []
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(like_leaves)
    )
    for i, (ref, shd) in enumerate(zip(like_leaves, shard_leaves)):
        arr = np.load(path / f"arr_{i}.npy")
        expect = tuple(ref.shape)
        assert tuple(arr.shape) == expect, f"leaf {i}: {arr.shape} != {expect}"
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)


class Checkpointer:
    """Async checkpoint manager with retention."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()
        # materialize on host BEFORE handing to the thread (donated buffers
        # may be overwritten by the next step)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra)
                self._gc()
            except Exception as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.directory)
