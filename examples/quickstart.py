"""Quickstart: the paper's dynamic-provisioning algorithms in 60 seconds.

Runs the offline optimum and the three future-aware online algorithms
(A1/A2/A3) plus LCP(w) and DELAYEDOFF on a synthetic MSR-like one-week trace
(PMR ~ 4.63, 10-minute slots, Delta = 6 slots — the paper's Section V setup)
and prints cost reductions vs static peak provisioning.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    CostModel,
    fluid_cost,
    msr_like_trace,
    pmr,
    theoretical_ratio,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)   # Delta = 6 slots


def main() -> None:
    trace = msr_like_trace(np.random.default_rng(0))
    print(f"trace: {len(trace)} slots, peak={trace.max()}, "
          f"mean={trace.mean():.1f}, PMR={pmr(trace):.2f}")

    static = fluid_cost(trace, "static", COSTS).cost
    opt = fluid_cost(trace, "offline", COSTS).cost
    print(f"\nstatic provisioning cost : {static:,.0f}")
    print(f"offline optimal cost     : {opt:,.0f}  "
          f"({1 - opt / static:.1%} reduction)\n")

    print(f"{'policy':<12}{'window':>7}{'cost':>12}{'reduction':>11}"
          f"{'emp.ratio':>11}{'bound':>8}")
    for window in (0, 2, 4, 5):
        alpha = min(1.0, (window + 1) / COSTS.delta)
        for name in ("A1", "A2", "A3"):
            runs = 20 if name != "A1" else 1
            cost = np.mean([
                fluid_cost(trace, name, COSTS, window=window,
                           rng=np.random.default_rng(r)).cost
                for r in range(runs)
            ])
            print(f"{name:<12}{window:>7}{cost:>12,.0f}"
                  f"{1 - cost / static:>10.1%}{cost / opt:>11.3f}"
                  f"{theoretical_ratio(name, alpha):>8.3f}")
        if window >= 1:
            c = fluid_cost(trace, "lcp", COSTS, window=window).cost
            print(f"{'LCP(w)':<12}{window:>7}{c:>12,.0f}"
                  f"{1 - c / static:>10.1%}{c / opt:>11.3f}{'--':>8}")
    c = fluid_cost(trace, "delayedoff", COSTS).cost
    print(f"{'DELAYEDOFF':<12}{'--':>7}{c:>12,.0f}"
          f"{1 - c / static:>10.1%}{c / opt:>11.3f}{'2.000':>8}")
    print("\nNote: A1/A2/A3 reach the offline optimum at window = Delta-1 = 5 "
          "(paper Fig. 4b).")


if __name__ == "__main__":
    main()
