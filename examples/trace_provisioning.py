"""Paper experiments, interactive: competitive ratios, PMR sweep, and the
fleet-scale declarative provisioner (one `provision(spec)` program per
policy — batching, α-sweep, heterogeneous per-level costs, and shard_map
level sharding through the Pallas scan are all spec fields).

    PYTHONPATH=src python examples/trace_provisioning.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CostModel,
    PAPER_COSTS,
    PolicySpec,
    ProvisionSpec,
    Workload,
    fluid_cost,
    msr_like_trace,
    provision,
    scale_to_pmr,
    theoretical_ratio,
)

COSTS = PAPER_COSTS                       # P = 1, beta 3/3 => Delta = 6
DELTA = int(COSTS.delta)


def main() -> None:
    trace = msr_like_trace(np.random.default_rng(0))
    n_levels = int(trace.max()) + 1
    windows = jnp.arange(DELTA, dtype=jnp.int32)

    # --- Fig. 3: worst-case vs empirical ratios over alpha — the whole
    # (runs x alpha) grid per policy is ONE jitted device program.
    print("Fig.3 — competitive ratios (Delta = 6, declarative engine):")
    print(f"{'alpha':>6} {'A1 bound':>9} {'A1 emp':>8} {'A3 bound':>9} {'A3 emp':>8}")
    opt = fluid_cost(trace, "offline", COSTS).cost
    a1 = np.asarray(provision(ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=jnp.asarray(trace, jnp.int32)),
        policy=PolicySpec("A1", windows=windows),
        n_levels=n_levels,
    )).cost) / opt
    runs = 20
    batch = jnp.asarray(np.tile(trace, (runs, 1)), jnp.int32)
    a3 = np.asarray(provision(ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=batch),
        policy=PolicySpec("A3", windows=windows, key=jax.random.key(0)),
        n_levels=n_levels,
    )).cost).mean(axis=1) / opt
    for i, w in enumerate(range(DELTA)):
        alpha = min(1.0, (w + 1) / COSTS.delta)
        print(f"{alpha:>6.2f} {theoretical_ratio('A1', alpha):>9.3f} {a1[i]:>8.3f} "
              f"{theoretical_ratio('A3', alpha):>9.3f} {a3[i]:>8.3f}")

    # --- Fig. 4d: PMR sweep
    print("\nFig.4d — savings vs peak-to-mean ratio (offline optimum):")
    base = trace.astype(float)
    for target in (2, 4, 6, 8, 10):
        a = scale_to_pmr(base, float(target))
        a = np.maximum(np.rint(a / a.mean() * 40.0), 0).astype(np.int64)
        st = fluid_cost(a, "static", COSTS).cost
        op = fluid_cost(a, "offline", COSTS).cost
        print(f"  PMR={target:>2}: reduction {1 - op / st:6.1%}")

    # --- heterogeneous fleet: the bottom of the LIFO stack is cheap-to-idle
    # baseload (big Delta), the top is bursty spot capacity (small Delta) —
    # one (n_levels,) CostModel, same single program.
    print("\nHeterogeneous fleet (per-level Delta, one provision(spec) call):")
    frac_base = 0.5
    n_base = int(n_levels * frac_base)
    beta = np.where(np.arange(n_levels) < n_base, 4.5, 1.5)   # Delta 9 / 3
    het = CostModel(P=1.0, beta_on=beta, beta_off=beta)
    res = provision(ProvisionSpec(
        costs=het,
        workload=Workload(demand=jnp.asarray(trace, jnp.int32)),
        policy=PolicySpec("A1", window=2),
    ))
    lc = np.asarray(res.level_cost)
    print(f"  total={float(res.cost):,.0f}  energy={float(res.energy):,.0f} "
          f"toggles={float(res.toggle_cost):,.0f}")
    print(f"  baseload levels (Delta=9): {lc[:n_base].sum():,.0f}; "
          f"spot levels (Delta=3): {lc[n_base:].sum():,.0f}")

    # --- fleet-scale: same spec, levels sharded over the mesh (Pallas scan)
    print("\nJAX fleet provisioner (jit + shard_map over levels, Pallas scan):")
    a = jnp.asarray(trace, jnp.int32)
    spec = ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=a),
        policy=PolicySpec("A1", window=2),
        n_levels=n_levels,
    )
    res = provision(spec)
    print(f"  A1 x(t): max={int(res.x.max())}, mean={float(res.x.mean()):.1f} "
          f"(demand mean {trace.mean():.1f})")
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    import dataclasses
    res_sh = provision(dataclasses.replace(spec, mesh=mesh))
    assert (np.asarray(res.x) == np.asarray(res_sh.x)).all()
    print(f"  sharded over {len(jax.devices())} device(s): identical schedule ✓")
    res3 = provision(dataclasses.replace(
        spec, mesh=mesh,
        policy=PolicySpec("A3", window=2, key=jax.random.key(1)),
    ))
    print(f"  A3 (randomized, sharded Pallas scan): max={int(res3.x.max())}, "
          f"mean={float(res3.x.mean()):.1f}")


if __name__ == "__main__":
    main()
