"""Paper experiments, interactive: competitive ratios, PMR sweep, and the
fleet-scale declarative provisioner (one `provision(spec)` program per
policy — batching, α-sweep, prediction-noise sweep, heterogeneous per-level
costs, and shard_map level sharding through the Pallas scan are all spec
fields).  Traces come from the scenario registry (`repro.scenarios`); run
`benchmarks/cr_eval.py` for the full competitive-ratio grid.

    PYTHONPATH=src python examples/trace_provisioning.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CostModel,
    PAPER_COSTS,
    PolicySpec,
    ProvisionSpec,
    Workload,
    fluid_cost,
    provision,
    theoretical_ratio,
)
from repro.core.traces import WEEK_SLOTS
from repro.scenarios import Scenario, generate, make_workload

COSTS = PAPER_COSTS                       # P = 1, beta 3/3 => Delta = 6
DELTA = int(COSTS.delta)
MSR = Scenario("msr_diurnal", target_pmr=4.63, mean_jobs=40.0)


def main() -> None:
    trace = generate(MSR, 1, WEEK_SLOTS)[0]
    n_levels = int(trace.max()) + 1
    windows = jnp.arange(DELTA, dtype=jnp.int32)

    # --- Fig. 3: worst-case vs empirical ratios over alpha — the whole
    # (runs x alpha) grid per policy is ONE jitted device program.
    print("Fig.3 — competitive ratios (Delta = 6, declarative engine):")
    print(f"{'alpha':>6} {'A1 bound':>9} {'A1 emp':>8} {'A3 bound':>9} {'A3 emp':>8}")
    opt = fluid_cost(trace, "offline", COSTS).cost
    a1 = np.asarray(provision(ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=jnp.asarray(trace, jnp.int32)),
        policy=PolicySpec("A1", windows=windows),
        n_levels=n_levels,
    )).cost) / opt
    runs = 20
    batch = jnp.asarray(np.tile(trace, (runs, 1)), jnp.int32)
    a3 = np.asarray(provision(ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=batch),
        policy=PolicySpec("A3", windows=windows, key=jax.random.key(0)),
        n_levels=n_levels,
    )).cost).mean(axis=1) / opt
    for i, w in enumerate(range(DELTA)):
        alpha = min(1.0, (w + 1) / COSTS.delta)
        print(f"{alpha:>6.2f} {theoretical_ratio('A1', alpha):>9.3f} {a1[i]:>8.3f} "
              f"{theoretical_ratio('A3', alpha):>9.3f} {a3[i]:>8.3f}")

    # --- Fig. 4d: PMR sweep — the scenario's target_pmr knob (same seed =>
    # same base shape, only the Section V-D rescale differs)
    print("\nFig.4d — savings vs peak-to-mean ratio (offline optimum):")
    for target in (2, 4, 6, 8, 10):
        a = generate(dataclasses.replace(MSR, target_pmr=float(target)), 1, WEEK_SLOTS)[0]
        st = fluid_cost(a, "static", COSTS).cost
        op = fluid_cost(a, "offline", COSTS).cost
        print(f"  PMR={target:>2}: reduction {1 - op / st:6.1%}")

    # --- scenario bank + noise sweep: one Workload from the registry, the
    # prediction-error study as a (S,) sweep axis (common random numbers)
    print("\nFlash crowd under prediction error (PredictionNoise sweep axis):")
    stds = (0.0, 0.25, 0.5)
    wl = make_workload(
        Scenario("flash_crowd", target_pmr=4.63, mean_jobs=40.0),
        n_traces=8, n_slots=WEEK_SLOTS, noise_std=jnp.asarray(stds),
    )
    res = provision(ProvisionSpec(
        costs=COSTS,
        workload=wl,
        policy=PolicySpec("A1", window=2),
        n_levels=int(wl.demand.max()) + 1,
    ))
    opt = provision(ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=wl.demand),
        policy=PolicySpec("offline"),
        n_levels=int(wl.demand.max()) + 1,
    ))
    cr = np.asarray(res.cost) / np.asarray(opt.cost)[None, :]
    alpha = (2 + 1) / COSTS.delta
    for s, std in enumerate(stds):
        print(f"  std={std:4}: mean CR {cr[s].mean():.3f} "
              f"(A1 bound {theoretical_ratio('A1', alpha):.2f})")

    # --- heterogeneous fleet: the bottom of the LIFO stack is cheap-to-idle
    # baseload (big Delta), the top is bursty spot capacity (small Delta) —
    # one (n_levels,) CostModel, same single program.
    print("\nHeterogeneous fleet (per-level Delta, one provision(spec) call):")
    frac_base = 0.5
    n_base = int(n_levels * frac_base)
    beta = np.where(np.arange(n_levels) < n_base, 4.5, 1.5)   # Delta 9 / 3
    het = CostModel(P=1.0, beta_on=beta, beta_off=beta)
    res = provision(ProvisionSpec(
        costs=het,
        workload=Workload(demand=jnp.asarray(trace, jnp.int32)),
        policy=PolicySpec("A1", window=2),
    ))
    lc = np.asarray(res.level_cost)
    print(f"  total={float(res.cost):,.0f}  energy={float(res.energy):,.0f} "
          f"toggles={float(res.toggle_cost):,.0f}")
    print(f"  baseload levels (Delta=9): {lc[:n_base].sum():,.0f}; "
          f"spot levels (Delta=3): {lc[n_base:].sum():,.0f}")

    # --- fleet-scale: same spec, levels sharded over the mesh (Pallas scan)
    print("\nJAX fleet provisioner (jit + shard_map over levels, Pallas scan):")
    a = jnp.asarray(trace, jnp.int32)
    spec = ProvisionSpec(
        costs=COSTS,
        workload=Workload(demand=a),
        policy=PolicySpec("A1", window=2),
        n_levels=n_levels,
    )
    res = provision(spec)
    print(f"  A1 x(t): max={int(res.x.max())}, mean={float(res.x.mean()):.1f} "
          f"(demand mean {trace.mean():.1f})")
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    res_sh = provision(dataclasses.replace(spec, mesh=mesh))
    assert (np.asarray(res.x) == np.asarray(res_sh.x)).all()
    print(f"  sharded over {len(jax.devices())} device(s): identical schedule ✓")
    res3 = provision(dataclasses.replace(
        spec, mesh=mesh,
        policy=PolicySpec("A3", window=2, key=jax.random.key(1)),
    ))
    print(f"  A3 (randomized, sharded Pallas scan): max={int(res3.x.max())}, "
          f"mean={float(res3.x.mean()):.1f}")

    # --- the whole (noise-std x window) sweep rides the same fleet path:
    # one launch of the 2-D Pallas grid, one program per (s, w) cell and
    # level block — bit-exact against the unsharded engine
    from repro.core import PredictionNoise

    swept_spec = dataclasses.replace(
        spec, mesh=mesh,
        workload=Workload(demand=a, noise=PredictionNoise(
            std_frac=jnp.asarray([0.0, 0.25]), key=jax.random.key(2))),
        policy=PolicySpec("A1", windows=jnp.arange(3, dtype=jnp.int32)),
    )
    swept = provision(swept_spec)
    plain = provision(dataclasses.replace(swept_spec, mesh=None))
    assert (np.asarray(swept.x) == np.asarray(plain.x)).all()
    print("  (S=2 stds x W=3 windows) through the Pallas grid kernel, "
          "cost table (rows=std, cols=window):")
    print("  " + str(np.asarray(swept.cost).round(0)).replace("\n", "\n  "))


if __name__ == "__main__":
    main()
