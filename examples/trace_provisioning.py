"""Paper experiments, interactive: competitive ratios, PMR sweep, and the
fleet-scale jitted provisioner (levels sharded over the mesh via shard_map).

    PYTHONPATH=src python examples/trace_provisioning.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CostModel,
    fluid_cost,
    msr_like_trace,
    scale_to_pmr,
    theoretical_ratio,
)
from repro.core.jax_provision import provision_schedule, provision_schedule_sharded

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


def main() -> None:
    trace = msr_like_trace(np.random.default_rng(0))

    # --- Fig. 3: worst-case vs empirical ratios over alpha
    print("Fig.3 — competitive ratios (Delta = 6):")
    print(f"{'alpha':>6} {'A1 bound':>9} {'A1 emp':>8} {'A3 bound':>9} {'A3 emp':>8}")
    opt = fluid_cost(trace, "offline", COSTS).cost
    for w in (0, 1, 2, 3, 4, 5):
        alpha = min(1.0, (w + 1) / COSTS.delta)
        a1 = fluid_cost(trace, "A1", COSTS, window=w).cost / opt
        a3 = np.mean([
            fluid_cost(trace, "A3", COSTS, window=w,
                       rng=np.random.default_rng(r)).cost
            for r in range(20)
        ]) / opt
        print(f"{alpha:>6.2f} {theoretical_ratio('A1', alpha):>9.3f} {a1:>8.3f} "
              f"{theoretical_ratio('A3', alpha):>9.3f} {a3:>8.3f}")

    # --- Fig. 4d: PMR sweep
    print("\nFig.4d — savings vs peak-to-mean ratio (offline optimum):")
    base = trace.astype(float)
    for target in (2, 4, 6, 8, 10):
        a = scale_to_pmr(base, float(target))
        a = np.maximum(np.rint(a / a.mean() * 40.0), 0).astype(np.int64)
        st = fluid_cost(a, "static", COSTS).cost
        op = fluid_cost(a, "offline", COSTS).cost
        print(f"  PMR={target:>2}: reduction {1 - op / st:6.1%}")

    # --- fleet-scale jitted provisioner
    print("\nJAX fleet provisioner (A1, jit + shard_map over levels):")
    a = jnp.asarray(trace, jnp.int32)
    x = provision_schedule(a, n_levels=int(trace.max()) + 1,
                           delta=int(COSTS.delta), window=2, policy="A1")
    print(f"  x(t): max={int(x.max())}, mean={float(x.mean()):.1f} "
          f"(demand mean {trace.mean():.1f})")
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    xs = provision_schedule_sharded(mesh, a, n_levels=int(trace.max()) + 1,
                                    delta=int(COSTS.delta), window=2)
    assert (np.asarray(x) == np.asarray(xs)).all()
    print(f"  sharded over {len(jax.devices())} device(s): identical schedule ✓")


if __name__ == "__main__":
    main()
