"""Paper experiments, interactive: competitive ratios, PMR sweep, and the
fleet-scale jitted provisioner (batched multi-policy engine + Pallas scan,
levels sharded over the mesh via shard_map).

    PYTHONPATH=src python examples/trace_provisioning.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CostModel,
    fluid_cost,
    msr_like_trace,
    provision_schedule,
    provision_schedule_sharded,
    provision_sweep_costs,
    scale_to_pmr,
    theoretical_ratio,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)
DELTA = int(COSTS.delta)


def main() -> None:
    trace = msr_like_trace(np.random.default_rng(0))
    n_levels = int(trace.max()) + 1
    windows = jnp.arange(DELTA, dtype=jnp.int32)

    # --- Fig. 3: worst-case vs empirical ratios over alpha — the whole
    # (runs x alpha) grid per policy is ONE jitted device program.
    print("Fig.3 — competitive ratios (Delta = 6, batched engine):")
    print(f"{'alpha':>6} {'A1 bound':>9} {'A1 emp':>8} {'A3 bound':>9} {'A3 emp':>8}")
    opt = fluid_cost(trace, "offline", COSTS).cost
    cost_kw = dict(P=COSTS.P, beta_on=COSTS.beta_on, beta_off=COSTS.beta_off)
    a1 = np.asarray(provision_sweep_costs(
        jnp.asarray(trace, jnp.int32), n_levels=n_levels, delta=DELTA,
        windows=windows, policy="A1", **cost_kw)) / opt
    runs = 20
    batch = jnp.asarray(np.tile(trace, (runs, 1)), jnp.int32)
    a3 = np.asarray(provision_sweep_costs(
        batch, n_levels=n_levels, delta=DELTA, windows=windows, policy="A3",
        key=jax.random.key(0), **cost_kw)).mean(axis=1) / opt
    for i, w in enumerate(range(DELTA)):
        alpha = min(1.0, (w + 1) / COSTS.delta)
        print(f"{alpha:>6.2f} {theoretical_ratio('A1', alpha):>9.3f} {a1[i]:>8.3f} "
              f"{theoretical_ratio('A3', alpha):>9.3f} {a3[i]:>8.3f}")

    # --- Fig. 4d: PMR sweep
    print("\nFig.4d — savings vs peak-to-mean ratio (offline optimum):")
    base = trace.astype(float)
    for target in (2, 4, 6, 8, 10):
        a = scale_to_pmr(base, float(target))
        a = np.maximum(np.rint(a / a.mean() * 40.0), 0).astype(np.int64)
        st = fluid_cost(a, "static", COSTS).cost
        op = fluid_cost(a, "offline", COSTS).cost
        print(f"  PMR={target:>2}: reduction {1 - op / st:6.1%}")

    # --- fleet-scale jitted provisioner
    print("\nJAX fleet provisioner (jit + shard_map over levels, Pallas scan):")
    a = jnp.asarray(trace, jnp.int32)
    x = provision_schedule(a, n_levels=n_levels, delta=DELTA, window=2,
                           policy="A1")
    print(f"  A1 x(t): max={int(x.max())}, mean={float(x.mean()):.1f} "
          f"(demand mean {trace.mean():.1f})")
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    xs = provision_schedule_sharded(mesh, a, n_levels=n_levels, delta=DELTA,
                                    window=2)
    assert (np.asarray(x) == np.asarray(xs)).all()
    print(f"  sharded over {len(jax.devices())} device(s): identical schedule ✓")
    x3 = provision_schedule_sharded(mesh, a, n_levels=n_levels, delta=DELTA,
                                    window=2, policy="A3", key=jax.random.key(1))
    print(f"  A3 (randomized, sharded Pallas scan): max={int(x3.max())}, "
          f"mean={float(x3.mean()):.1f}")


if __name__ == "__main__":
    main()
