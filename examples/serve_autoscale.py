"""End-to-end serving driver: real model, batched requests, paper autoscaler.

A session stream (elephant jobs, concurrency follows an MSR-like trace) is
served by a pool of replicas running a reduced llama3.2 model.  Sessions are
dispatched last-empty-replica-first; idle replicas run the future-aware
ski-rental (A1) to decide off-vs-idle.  Real tokens are generated on the
pinned replica — no KV cache ever migrates.

    PYTHONPATH=src python examples/serve_autoscale.py [--sessions 40] [--alpha 0.5]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import RANDOMIZED_POLICIES, CostModel, DeferralSpec, PolicySpec
from repro.data.requests import generate_sessions
from repro.models import init_params
from repro.serving import (
    FleetProvisioner,
    InferenceEngine,
    make_window_max_predictor,
    run_cluster,
)

COSTS = CostModel(P=1.0, beta_on=3.0, beta_off=3.0)


def slot_concurrency(trace, n_slots: int) -> np.ndarray:
    """Per-slot peak session concurrency — planner input."""
    events = sorted(
        [(s.arrival, 1) for s in trace.sessions]
        + [(s.departure, -1) for s in trace.sessions]
    )
    a = np.zeros(n_slots, np.int64)
    cur, i = 0, 0
    for t in range(n_slots):
        a[t] = cur                      # concurrency carried in from slot start
        while i < len(events) and events[i][0] < t + 1:
            cur += events[i][1]
            a[t] = max(a[t], cur)
            i += 1
    return a


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=40)
    ap.add_argument("--concurrency", type=float, default=2.5)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args()

    trace = generate_sessions(
        np.random.default_rng(0), n_slots=args.slots,
        mean_concurrency=args.concurrency,
    )
    print(f"sessions: {len(trace.sessions)}, horizon {trace.horizon:.0f} slots, "
          f"peak concurrency {trace.to_brick().max_concurrency()}")

    # capacity planning on the batched jitted engine: evaluate every policy's
    # whole alpha-sweep as one device program, pick the cheapest window.
    demand = slot_concurrency(trace, args.slots)
    windows = np.arange(int(COSTS.delta))
    print("\nplanned cost by policy/window (batched engine, one program each):")
    for policy in ("A1", "A3"):
        planner = FleetProvisioner(
            COSTS,
            policy=PolicySpec(
                policy,
                key=jax.random.key(0) if policy in RANDOMIZED_POLICIES else None,
            ),
            max_replicas=int(demand.max()) + 1,
        )
        costs = planner.sweep_costs(demand, windows)
        best = int(np.argmin(costs))
        line = " ".join(f"w={w}:{c:,.0f}" for w, c in zip(windows, costs))
        print(f"  {policy}: {line}  -> best window {windows[best]} "
              f"(alpha={min(1.0, (windows[best] + 1) / COSTS.delta):.2f})")
    print()

    # deferrable sessions: grant the queue k slots of slack and let the
    # planner water-fill arrivals before provisioning — bursts are absorbed
    # by the backlog instead of replica toggles, and the plan reports the
    # latency actually paid (p99 queueing delay, deadline misses).
    print("planned cost by deferral slack (A1, defer-then-provision):")
    for slack in (0, 1, 2, 4):
        planner = FleetProvisioner(
            COSTS, policy="A1", max_replicas=int(demand.max()) + 1,
            deferral=DeferralSpec(slack=slack),
        )
        res = planner.plan(demand)
        x = np.asarray(res.x)
        toggles = int(np.maximum(np.diff(x, prepend=0), 0).sum())
        print(f"  slack={slack}: cost={float(res.cost):,.0f} "
              f"toggles(on)={toggles} p99_delay={int(res.p99_delay)} "
              f"misses={int(res.deadline_misses)}")
    print()

    cfg = get_config(args.arch, reduced=True).replace(remat="none")
    params = init_params(cfg, jax.random.key(0))

    def factory():
        return InferenceEngine(cfg, params, max_batch=1, max_seq=96)

    pred = make_window_max_predictor(trace)
    for alpha, use_engines in ((0.0, False), (args.alpha, False), (1.0, False),
                               (args.alpha, True)):
        rep = run_cluster(
            trace, COSTS, policy="A1", alpha=alpha,
            predictor=pred, engine_factory=factory if use_engines else None,
        )
        tag = " + real generation" if use_engines else ""
        print(
            f"A1(alpha={alpha:.2f}){tag}: cost={rep.total_cost:,.1f} "
            f"static={rep.static_cost:,.0f} reduction={rep.reduction:.1%} "
            f"toggles={rep.scaler.n_turn_on}/{rep.scaler.n_turn_off}"
            + (f" tokens={rep.tokens_generated}" if use_engines else "")
        )


if __name__ == "__main__":
    main()
