"""Train a small LM end-to-end with the fault-tolerant trainer.

Demonstrates: pjit'd train step (FSDP x TP on the host mesh), deterministic
data, async atomic checkpoints, auto-resume, optional int8 gradient
compression.  With --steps 300 on CPU this trains a ~5M-param llama-family
model to visibly decreasing loss.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--arch yi-9b]
    # kill it mid-run and re-run: it resumes from the last checkpoint.
"""
import argparse

from repro.configs import get_config
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True).replace(remat="none")
    tcfg = TrainerConfig(
        total_steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        log_every=10,
        grad_compression=args.compress_grads,
    )
    out = Trainer(cfg, tcfg).run()
    first = out["history"][0][1] if out["history"] else float("nan")
    last = out["history"][-1][1] if out["history"] else float("nan")
    print(f"\ntrained {args.arch} (reduced) to step {out['final_step']}: "
          f"loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
